"""L2 model correctness: entry points vs oracles + padding contracts.

These are the invariants the Rust runtime depends on:
- screen_utilities equals the pure-jnp Pearson |corr| and gives padded
  (zero) columns utility 0;
- iht_solve recovers a planted sparse support and never selects padded
  columns;
- lloyd_step equals the reference Lloyd iteration.
"""

import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def _sparse_problem(n, p, k, noise=0.05):
    x = RNG.standard_normal((n, p)).astype(np.float32)
    beta = np.zeros(p, np.float32)
    support = RNG.choice(p, size=k, replace=False)
    beta[support] = np.where(RNG.random(k) > 0.5, 1.0, -1.0)
    y = (x @ beta + noise * RNG.standard_normal(n)).astype(np.float32)
    return x, y, np.sort(support)


def test_screen_utilities_matches_ref():
    x = RNG.standard_normal((64, 256)).astype(np.float32)
    y = RNG.standard_normal(64).astype(np.float32)
    got = np.asarray(model.screen_utilities(x, y))
    want = np.asarray(ref.screen_utilities_ref(x, y))
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)
    assert got.min() >= 0.0 and got.max() <= 1.0 + 1e-5


def test_screen_utilities_padded_columns_zero():
    x = np.zeros((64, 256), np.float32)
    x[:, :100] = RNG.standard_normal((64, 100))
    y = RNG.standard_normal(64).astype(np.float32)
    u = np.asarray(model.screen_utilities(x, y))
    assert np.all(u[100:] == 0.0), "padded columns must screen to zero"


def test_screen_utilities_ranks_true_features():
    x, y, support = _sparse_problem(128, 256, 4)
    u = np.asarray(model.screen_utilities(x, y))
    top = np.argsort(-u)[:4]
    assert len(set(top) & set(support)) >= 3


def test_iht_solve_recovers_support_clean():
    x, y, support = _sparse_problem(128, 256, 4, noise=0.0)
    beta = np.asarray(model.iht_solve(x, y, k=4, iters=100, lambda2=1e-3))
    got = np.sort(np.nonzero(beta)[0])
    assert list(got) == list(support), f"{got} vs {support}"
    assert_allclose(np.abs(beta[support]), 1.0, atol=0.05)


def test_iht_solve_matches_reference_iteration():
    x, y, _ = _sparse_problem(64, 128, 3, noise=0.1)
    got = np.asarray(model.iht_solve(x, y, k=3, iters=50, lambda2=1e-3))
    want = np.asarray(ref.iht_solve_ref(x, y, k=3, iters=50, lambda2=1e-3))
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_iht_solve_never_selects_padded_columns():
    x, y, _ = _sparse_problem(64, 100, 3, noise=0.0)
    xpad = np.zeros((64, 128), np.float32)
    xpad[:, :100] = x
    beta = np.asarray(model.iht_solve(xpad, y, k=3, iters=60, lambda2=1e-3))
    assert np.all(beta[100:] == 0.0)
    # And the unpadded solve agrees on the real columns.
    beta0 = np.asarray(model.iht_solve(x, y, k=3, iters=60, lambda2=1e-3))
    assert_allclose(beta[:100], beta0, rtol=1e-3, atol=1e-3)


def test_iht_sparsity_never_exceeds_k():
    x, y, _ = _sparse_problem(64, 128, 5, noise=0.3)
    for k in (1, 3, 5):
        beta = np.asarray(model.iht_solve(x, y, k=k, iters=40, lambda2=1e-3))
        assert np.count_nonzero(beta) <= k


def test_lloyd_step_matches_ref():
    pts = RNG.standard_normal((128, 2)).astype(np.float32) * 3
    cts = RNG.standard_normal((4, 2)).astype(np.float32)
    nc, labels, inertia = model.lloyd_step(pts, cts)
    rnc, rlabels, rinertia = ref.lloyd_step_ref(pts, cts)
    assert_allclose(np.asarray(nc), np.asarray(rnc), rtol=1e-4, atol=1e-4)
    assert np.array_equal(np.asarray(labels), np.asarray(rlabels))
    assert_allclose(float(inertia), float(rinertia), rtol=1e-4)


def test_lloyd_step_converges_on_separated_blobs():
    c_true = np.array([[0.0, 0.0], [10.0, 10.0]], np.float32)
    pts = np.concatenate(
        [
            c_true[0] + 0.2 * RNG.standard_normal((64, 2)),
            c_true[1] + 0.2 * RNG.standard_normal((64, 2)),
        ]
    ).astype(np.float32)
    cts = np.array([[1.0, 1.0], [9.0, 9.0]], np.float32)
    for _ in range(5):
        cts, labels, inertia = model.lloyd_step(pts, cts)
        cts = np.asarray(cts)
    assert_allclose(cts, c_true, atol=0.2)
    labels = np.asarray(labels)
    assert set(labels[:64]) == {0} and set(labels[64:]) == {1}
