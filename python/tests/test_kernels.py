"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes (respecting the block-divisibility contract —
the L2 wrappers own padding) and value scales; fixed-seed numpy generates
the data so failures reproduce.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    corr_stats,
    matvec,
    matvec_t,
    pairwise_sqdist,
)
from compile.kernels import ref

RNG = np.random.default_rng(0)


def _randn(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# --- corr_stats -----------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 64),
    blocks=st.integers(1, 3),
    block_p=st.sampled_from([8, 16, 32]),
    scale=st.sampled_from([0.1, 1.0, 10.0]),
)
def test_corr_stats_matches_ref(n, blocks, block_p, scale):
    p = blocks * block_p
    xc = _randn(n, p, scale=scale)
    xc -= xc.mean(axis=0, keepdims=True)
    yc = _randn(n, scale=scale)
    yc -= yc.mean()
    dots, sq = corr_stats(xc, yc, block_p=block_p)
    rdots, rsq = ref.corr_stats_ref(xc, yc)
    assert_allclose(np.asarray(dots), np.asarray(rdots), rtol=2e-4, atol=2e-4)
    assert_allclose(np.asarray(sq), np.asarray(rsq), rtol=2e-4, atol=2e-4)


def test_corr_stats_zero_column_is_inert():
    xc = _randn(32, 64)
    xc[:, 10] = 0.0
    xc -= xc.mean(axis=0, keepdims=True)
    yc = _randn(32)
    dots, sq = corr_stats(xc, yc, block_p=32)
    assert abs(float(sq[10])) < 1e-5


# --- matvec / matvec_t -----------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    nblocks=st.integers(1, 3),
    block_n=st.sampled_from([8, 32]),
    p=st.integers(1, 50),
)
def test_matvec_matches_ref(nblocks, block_n, p):
    n = nblocks * block_n
    x = _randn(n, p)
    v = _randn(p)
    got = matvec(x, v, block_n=block_n)
    want = ref.matvec_ref(x, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    pblocks=st.integers(1, 3),
    block_p=st.sampled_from([8, 32]),
    n=st.integers(1, 50),
)
def test_matvec_t_matches_ref(pblocks, block_p, n):
    p = pblocks * block_p
    x = _randn(n, p)
    r = _randn(n)
    got = matvec_t(x, r, block_p=block_p)
    want = ref.matvec_t_ref(x, r)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_matvec_rejects_non_divisible_rows():
    with pytest.raises(AssertionError):
        matvec(_randn(10, 4), _randn(4), block_n=8)


# --- pairwise_sqdist --------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    nblocks=st.integers(1, 3),
    block_n=st.sampled_from([8, 16]),
    d=st.integers(1, 8),
    k=st.integers(1, 6),
)
def test_pairwise_sqdist_matches_ref(nblocks, block_n, d, k):
    n = nblocks * block_n
    pts = _randn(n, d, scale=3.0)
    cts = _randn(k, d, scale=3.0)
    got = pairwise_sqdist(pts, cts, block_n=block_n)
    want = ref.pairwise_sqdist_ref(pts, cts)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_pairwise_sqdist_self_distance_zero():
    pts = _randn(16, 3)
    d2 = pairwise_sqdist(pts, pts[:4], block_n=16)
    for i in range(4):
        assert abs(float(d2[i, i])) < 1e-4
