"""AOT lowering: JAX entry points → HLO *text* artifacts + manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --out-dir ../artifacts [--quick-only]

Each artifact is shape-specialized; ``manifest.json`` records, per entry:
kind, file, shapes, and static hyperparameters. The Rust runtime
(rust/src/runtime/) selects entries by kind + shape bucket and pads
inputs (zero columns are inert — proven in python/tests/test_model.py and
rust integration tests).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Shape buckets. Feature counts are multiples of the kernel block (256);
# row counts must match the data exactly (padding rows would corrupt the
# column means used by screening), so we emit one bucket per experiment n.
SCREEN_SHAPES = [
    # (n, p_padded)  — paper scale and quick scale
    (500, 5120),
    (500, 2560),
    (200, 1024),
    (150, 1024),
]
IHT_SHAPES = [
    # (n, p_padded, k, iters)
    (500, 2560, 10, 100),
    (500, 1280, 10, 100),
    (200, 512, 5, 100),
    (150, 512, 5, 100),
]
LLOYD_SHAPES = [
    # (n_padded, d, k) — n may be padded: the Rust driver masks labels of
    # padded rows and feeds the previous centroids back in, so inert rows
    # only shift counts it corrects for. Simpler: exact n buckets.
    (200, 2, 5),
    (128, 2, 4),
    (16, 2, 4),
]

QUICK = {"screen": [(200, 1024)], "iht": [(200, 512, 5, 100)], "lloyd": [(16, 2, 4)]}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir, name, lowered, meta):
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    meta = dict(meta)
    meta["file"] = fname
    print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")
    return meta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick-only",
        action="store_true",
        help="emit only the quick-scale buckets (fast CI artifact build)",
    )
    # Back-compat with the scaffold Makefile (`--out file` emits everything
    # into the file's directory).
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    screen_shapes = QUICK["screen"] if args.quick_only else SCREEN_SHAPES
    iht_shapes = QUICK["iht"] if args.quick_only else IHT_SHAPES
    lloyd_shapes = QUICK["lloyd"] if args.quick_only else LLOYD_SHAPES

    entries = []

    print("lowering screen_utilities:")
    for n, p in screen_shapes:
        spec_x = jax.ShapeDtypeStruct((n, p), jnp.float32)
        spec_y = jax.ShapeDtypeStruct((n,), jnp.float32)
        lowered = jax.jit(model.screen_utilities).lower(spec_x, spec_y)
        entries.append(
            emit(
                out_dir,
                f"screen__n{n}_p{p}",
                lowered,
                {"kind": "screen", "n": n, "p": p, "outputs": 1},
            )
        )

    print("lowering iht_solve:")
    for n, p, k, iters in iht_shapes:
        spec_x = jax.ShapeDtypeStruct((n, p), jnp.float32)
        spec_y = jax.ShapeDtypeStruct((n,), jnp.float32)
        fn = lambda x, y, k=k, iters=iters: model.iht_solve(
            x, y, k=k, iters=iters, lambda2=1e-3
        )
        lowered = jax.jit(fn).lower(spec_x, spec_y)
        entries.append(
            emit(
                out_dir,
                f"iht__n{n}_p{p}_k{k}_t{iters}",
                lowered,
                {
                    "kind": "iht",
                    "n": n,
                    "p": p,
                    "k": k,
                    "iters": iters,
                    "lambda2": 1e-3,
                    "outputs": 1,
                },
            )
        )

    print("lowering lloyd_step:")
    for n, d, k in lloyd_shapes:
        spec_p = jax.ShapeDtypeStruct((n, d), jnp.float32)
        spec_c = jax.ShapeDtypeStruct((k, d), jnp.float32)
        lowered = jax.jit(model.lloyd_step).lower(spec_p, spec_c)
        entries.append(
            emit(
                out_dir,
                f"lloyd__n{n}_d{d}_k{k}",
                lowered,
                {"kind": "lloyd", "n": n, "d": d, "k": k, "outputs": 3},
            )
        )

    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} entries → {out_dir}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
