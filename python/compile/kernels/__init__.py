"""Layer-1 Pallas kernels (build-time only; lowered into HLO by aot.py).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO ops
that the Rust runtime's CPU client executes directly. The TPU mapping
(BlockSpec tiling for VMEM, MXU-shaped matmuls) is preserved structurally;
see DESIGN.md §Hardware-Adaptation and §Perf.
"""

from .corr import corr_stats, CORR_BLOCK_P
from .distance import pairwise_sqdist, DIST_BLOCK_N
from .matvec import matvec, matvec_t, MATVEC_BLOCK_N, MATVEC_BLOCK_P

__all__ = [
    "corr_stats",
    "pairwise_sqdist",
    "matvec",
    "matvec_t",
    "CORR_BLOCK_P",
    "DIST_BLOCK_N",
    "MATVEC_BLOCK_N",
    "MATVEC_BLOCK_P",
]
