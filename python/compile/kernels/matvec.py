"""Tiled matrix–vector kernels (L1) — the IHT inner ops.

Iterative hard thresholding alternates two contractions:

    forward:  r = y − X β      → needs  X @ β        (matvec, row-tiled)
    gradient: g = Xᵀ r          → needs  Xᵀ @ r       (matvec_t, col-tiled)

Each is a Pallas kernel tiled so one slab of X fits in VMEM-equivalent
scratch; the contraction is a matmul against a (len × 1) operand, which
is the MXU-friendly formulation (vector ops would waste the systolic
array).

VMEM accounting (f32): matvec slab BN×p = 128·2560·4 ≈ 1.25 MiB;
matvec_t slab n×BP = 500·256·4 ≈ 0.5 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MATVEC_BLOCK_N = 128  # row-block for X @ v
MATVEC_BLOCK_P = 256  # column-block for Xᵀ @ r


def _matvec_kernel(x_ref, v_ref, o_ref):
    """One row block: o = X_block @ v."""
    x = x_ref[...]  # (BN, p)
    v = v_ref[...]  # (p, 1)
    o_ref[...] = jnp.dot(x, v, preferred_element_type=jnp.float32)[:, 0]


@functools.partial(jax.jit, static_argnames=("block_n",))
def matvec(x, v, block_n: int = MATVEC_BLOCK_N):
    """``X @ v`` with the row axis tiled. Requires n % block_n == 0."""
    n, p = x.shape
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),
            pl.BlockSpec((p, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), v.reshape(p, 1).astype(jnp.float32))
    return out


def _matvec_t_kernel(x_ref, r_ref, o_ref):
    """One column block: o = X_blockᵀ @ r."""
    x = x_ref[...]  # (n, BP)
    r = r_ref[...]  # (n, 1)
    o_ref[...] = jnp.dot(x.T, r, preferred_element_type=jnp.float32)[:, 0]


@functools.partial(jax.jit, static_argnames=("block_p",))
def matvec_t(x, r, block_p: int = MATVEC_BLOCK_P):
    """``Xᵀ @ r`` with the feature axis tiled. Requires p % block_p == 0."""
    n, p = x.shape
    assert p % block_p == 0, f"p={p} not a multiple of block_p={block_p}"
    out = pl.pallas_call(
        _matvec_t_kernel,
        grid=(p // block_p,),
        in_specs=[
            pl.BlockSpec((n, block_p), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), r.reshape(n, 1).astype(jnp.float32))
    return out
