"""Pairwise squared-distance kernel (L1) — the Lloyd assignment hot spot.

For points P (n × d) and centroids C (k × d), computes D (n × k) with
``D[i, c] = ‖P[i] − C[c]‖²`` via the Gram expansion

    D = ‖P‖²[:, None] + ‖C‖²[None, :] − 2 · P @ Cᵀ

so the dominant FLOPs are in the (BN × d) @ (d × k) matmul (MXU), not in
elementwise broadcasting. The grid tiles the point axis.

VMEM accounting (f32, BN = 128, d ≤ 64, k ≤ 64): point slab ≤ 32 KiB,
centroid block ≤ 16 KiB, output tile ≤ 32 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DIST_BLOCK_N = 128


def _dist_kernel(p_ref, c_ref, o_ref):
    p = p_ref[...]  # (BN, d)
    c = c_ref[...]  # (k, d)
    pn = jnp.sum(p * p, axis=1, keepdims=True)  # (BN, 1)
    cn = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, k)
    cross = jnp.dot(p, c.T, preferred_element_type=jnp.float32)  # (BN, k)
    # Clamp tiny negatives from cancellation.
    o_ref[...] = jnp.maximum(pn + cn - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n",))
def pairwise_sqdist(points, centroids, block_n: int = DIST_BLOCK_N):
    """(n × k) squared distances. Requires n % block_n == 0."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, "dimension mismatch"
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    out = pl.pallas_call(
        _dist_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,
    )(points.astype(jnp.float32), centroids.astype(jnp.float32))
    return out
