"""Correlation-screening kernel (L1).

Computes, for *centered* design ``xc`` (n × p) and *centered* response
``yc`` (n,), the per-feature statistics the screener needs:

    dots[j] = Σ_i xc[i, j] · yc[i]        (numerator of the correlation)
    sq[j]   = Σ_i xc[i, j]²               (column squared norm)

The grid tiles the feature axis in blocks of ``CORR_BLOCK_P``; each
program loads an (n × BP) slab of X plus the full response into
VMEM-equivalent scratch and issues one (BP × n) @ (n × 1) matmul — the
MXU-shaped inner op — plus an elementwise square-reduce for the norms.

VMEM accounting (f32, n = 500, BP = 256): slab 500·256·4 ≈ 0.5 MiB,
response 2 KiB, outputs 2 KiB — comfortably under a ~16 MiB VMEM budget,
leaving room for double-buffering the HBM→VMEM stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Feature-axis block size. 256 keeps the slab ≤ ~0.5 MiB at n = 500 and is
# a multiple of the 128-lane MXU tile.
CORR_BLOCK_P = 256


def _corr_kernel(x_ref, y_ref, dots_ref, sq_ref):
    """One feature block: dots = X_blockᵀ y;  sq = Σ X_block²."""
    x = x_ref[...]  # (n, BP)
    y = y_ref[...]  # (n, 1)
    # MXU-shaped contraction: (BP, n) @ (n, 1) → (BP, 1).
    dots_ref[...] = jnp.dot(x.T, y, preferred_element_type=jnp.float32)[:, 0]
    sq_ref[...] = jnp.sum(x * x, axis=0)


@functools.partial(jax.jit, static_argnames=("block_p",))
def corr_stats(xc, yc, block_p: int = CORR_BLOCK_P):
    """Per-column (dots, sq) statistics of a centered design.

    ``xc.shape[1]`` must be a multiple of ``block_p`` (the L2 wrapper pads
    with zero columns, which produce dots = sq = 0 and are screened out).
    """
    n, p = xc.shape
    assert p % block_p == 0, f"p={p} not a multiple of block_p={block_p}"
    grid = (p // block_p,)
    y2 = yc.reshape(n, 1)
    dots, sq = pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_p), lambda j: (0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda j: (j,)),
            pl.BlockSpec((block_p,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=True,
    )(xc.astype(jnp.float32), y2.astype(jnp.float32))
    return dots, sq
