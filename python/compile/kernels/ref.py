"""Pure-jnp oracles for every L1 kernel and L2 entry point.

pytest asserts ``assert_allclose(kernel(...), ref(...))`` across a
hypothesis-driven sweep of shapes/dtypes — this file is the correctness
contract of the compile path.
"""

import jax.numpy as jnp


def corr_stats_ref(xc, yc):
    """(dots, sq) per column of a centered design."""
    dots = xc.T @ yc
    sq = jnp.sum(xc * xc, axis=0)
    return dots.astype(jnp.float32), sq.astype(jnp.float32)


def matvec_ref(x, v):
    return (x @ v).astype(jnp.float32)


def matvec_t_ref(x, r):
    return (x.T @ r).astype(jnp.float32)


def pairwise_sqdist_ref(points, centroids):
    diff = points[:, None, :] - centroids[None, :, :]
    return jnp.sum(diff * diff, axis=-1).astype(jnp.float32)


def screen_utilities_ref(x, y):
    """|Pearson correlation| per column (the L2 wrapper's contract)."""
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    yc = y - jnp.mean(y)
    dots = xc.T @ yc
    sq = jnp.sum(xc * xc, axis=0)
    ynorm2 = jnp.sum(yc * yc)
    denom = jnp.sqrt(sq * ynorm2)
    return jnp.where(denom > 1e-12, jnp.abs(dots) / denom, 0.0).astype(jnp.float32)


def iht_solve_ref(x, y, k, iters, lambda2):
    """Reference IHT: projected gradient on the k-sparse ball."""
    n, p = x.shape
    # Power iteration for the Lipschitz constant (matches model.py).
    v = jnp.ones((p,), jnp.float32) / jnp.sqrt(p)
    for _ in range(12):
        w = x.T @ (x @ v)
        norm = jnp.linalg.norm(w)
        v = w / jnp.maximum(norm, 1e-12)
    lip = jnp.maximum(norm, 1e-6) + lambda2
    step = 1.0 / lip
    beta = jnp.zeros((p,), jnp.float32)
    for _ in range(iters):
        r = y - x @ beta
        g = x.T @ r - lambda2 * beta
        z = beta + step * g
        thr = -jnp.sort(-jnp.abs(z))[k - 1]
        beta = jnp.where(jnp.abs(z) >= thr, z, 0.0)
    return beta


def lloyd_step_ref(points, centroids):
    """One Lloyd iteration: (new_centroids, labels, inertia)."""
    d2 = pairwise_sqdist_ref(points, centroids)
    labels = jnp.argmin(d2, axis=1)
    k = centroids.shape[0]
    one_hot = (labels[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    counts = jnp.sum(one_hot, axis=0)
    sums = one_hot.T @ points
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return new_c.astype(jnp.float32), labels.astype(jnp.int32), inertia.astype(jnp.float32)
