"""Layer-2 JAX entry points (compute graphs lowered to HLO by aot.py).

Three entry points back the Rust coordinator's hot paths:

- :func:`screen_utilities` — |Pearson correlation| screening utilities
  (sparse-regression `screen` of Algorithm 1);
- :func:`iht_solve` — a full iterative-hard-thresholding subproblem fit
  (`fit_subproblem`) as a `lax.scan`, returning the final coefficient
  vector whose support the coordinator extracts;
- :func:`lloyd_step` — one k-means Lloyd iteration (`fit_subproblem` for
  clustering); the coordinator drives the convergence loop.

All three call the L1 Pallas kernels so the kernels lower into the same
HLO module. Shapes are static per artifact; padding conventions are part
of the contract (zero columns are inert for screening/IHT — the Rust side
relies on this, and python/tests/test_model.py proves it).
"""

import jax
import jax.numpy as jnp

from .kernels import (
    corr_stats,
    matvec,
    matvec_t,
    pairwise_sqdist,
    CORR_BLOCK_P,
    DIST_BLOCK_N,
    MATVEC_BLOCK_N,
    MATVEC_BLOCK_P,
)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two divisor of `dim` not exceeding `preferred`.

    The AOT shape buckets are multiples of the preferred block, so
    artifacts always get the full tile; tests and odd shapes degrade
    gracefully instead of asserting.
    """
    b = preferred
    while b > 1 and dim % b != 0:
        b //= 2
    return max(b, 1)


def screen_utilities(x, y):
    """|corr(x_j, y)| per column; 0 for zero-variance (incl. padded) cols."""
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    yc = y - jnp.mean(y)
    dots, sq = corr_stats(xc, yc, block_p=_pick_block(x.shape[1], CORR_BLOCK_P))
    ynorm2 = jnp.sum(yc * yc)
    denom = jnp.sqrt(sq * ynorm2)
    return jnp.where(denom > 1e-12, jnp.abs(dots) / denom, 0.0)


def _lipschitz(x, iters: int = 12):
    """Power-iteration bound on λ_max(XᵀX) using the L1 kernels."""
    p = x.shape[1]
    v = jnp.ones((p,), jnp.float32) / jnp.sqrt(p)

    bn = _pick_block(x.shape[0], MATVEC_BLOCK_N)
    bp = _pick_block(x.shape[1], MATVEC_BLOCK_P)

    def body(v, _):
        w = matvec_t(x, matvec(x, v, block_n=bn), block_p=bp)
        norm = jnp.linalg.norm(w)
        return w / jnp.maximum(norm, 1e-12), norm

    _, norms = jax.lax.scan(body, v, None, length=iters)
    return jnp.maximum(norms[-1], 1e-6)


def iht_solve(x, y, *, k: int, iters: int, lambda2: float):
    """IHT for `min ‖y − Xβ‖² + λ₂‖β‖²  s.t. ‖β‖₀ ≤ k` (static k, iters).

    Returns the final β (length p, exactly ≤ k nonzeros). The coordinator
    polishes the support with an exact ridge refit in Rust, so β's values
    only need to identify the support reliably.
    """
    p = x.shape[1]
    step = 1.0 / (_lipschitz(x) + lambda2)
    bn = _pick_block(x.shape[0], MATVEC_BLOCK_N)
    bp = _pick_block(x.shape[1], MATVEC_BLOCK_P)

    def body(beta, _):
        r = y - matvec(x, beta, block_n=bn)
        g = matvec_t(x, r, block_p=bp) - lambda2 * beta
        z = beta + step * g
        # Hard-threshold to the k largest magnitudes. NOTE: jnp.sort, not
        # jax.lax.top_k — the modern `topk(..., largest=true)` HLO op is
        # rejected by the xla_extension 0.5.1 text parser the Rust runtime
        # uses; `sort` round-trips cleanly.
        thr = jnp.sort(jnp.abs(z))[p - k]  # kth largest |z|
        beta_next = jnp.where(jnp.abs(z) >= thr, z, 0.0)
        return beta_next, None

    beta0 = jnp.zeros((p,), jnp.float32)
    beta, _ = jax.lax.scan(body, beta0, None, length=iters)
    return beta


def lloyd_step(points, centroids):
    """One Lloyd iteration → (new_centroids, labels:int32, inertia)."""
    d2 = pairwise_sqdist(points, centroids, block_n=_pick_block(points.shape[0], DIST_BLOCK_N))
    labels = jnp.argmin(d2, axis=1)
    k = centroids.shape[0]
    one_hot = (labels[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    counts = jnp.sum(one_hot, axis=0)
    sums = one_hot.T @ points
    new_c = jnp.where(
        counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids
    )
    inertia = jnp.sum(jnp.min(d2, axis=1))
    return new_c.astype(jnp.float32), labels.astype(jnp.int32), inertia.astype(jnp.float32)
