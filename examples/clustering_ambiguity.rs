//! Clustering under ambiguity — Table 1's third block: the target number
//! of clusters (k = 4) exceeds the true blob count (2), and the backbone
//! (k-means subproblems → exact clique partitioning restricted to B)
//! resolves the ambiguity where raw k-means over-segments.
//!
//! Run: `cargo run --release --example clustering_ambiguity`

use backbone_learn::data::blobs::{generate, BlobsConfig};
use backbone_learn::metrics::{adjusted_rand_index, silhouette_score};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::clique::{clique_solve, CliqueConfig};
use backbone_learn::solvers::kmeans::{kmeans_fit, KMeansConfig};
use backbone_learn::util::{Budget, Stopwatch};
use backbone_learn::Backbone;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from_u64(11);
    let true_k = 2;
    let target_k = 4; // deliberately wrong: creates the ambiguity
    let data = generate(
        &BlobsConfig {
            n: 16,
            p: 2,
            true_clusters: true_k,
            cluster_std: 0.8,
            center_box: 8.0,
            min_center_dist: 6.0,
        },
        &mut rng,
    );
    println!("clustering ambiguity: n=16, true clusters = {true_k}, target k = {target_k}\n");

    // --- KMeans at the (wrong) target k. ---------------------------------
    let watch = Stopwatch::start();
    let km = kmeans_fit(
        &data.x,
        &KMeansConfig { k: target_k, ..Default::default() },
        &mut rng,
    );
    println!(
        "KMeans  (k={target_k}): silhouette {:.4}  ARI vs truth {:.4}  [{:.2}s]",
        silhouette_score(&data.x, &km.labels),
        adjusted_rand_index(&km.labels, &data.labels_true),
        watch.elapsed_secs()
    );

    // --- Exact clique partitioning (≤ k clusters allowed). ---------------
    let watch = Stopwatch::start();
    let exact = clique_solve(
        &data.x,
        &CliqueConfig { k: target_k, min_cluster_size: 2, ..Default::default() },
        &Budget::seconds(120.0),
    )?;
    println!(
        "Exact   (≤{target_k}, b=2): silhouette {:.4}  ARI vs truth {:.4}  obj {:.1} gap {:.3} {:?} [{:.2}s]",
        silhouette_score(&data.x, &exact.labels),
        adjusted_rand_index(&exact.labels, &data.labels_true),
        exact.objective,
        exact.gap,
        exact.status,
        watch.elapsed_secs()
    );

    // --- Backbone: M k-means subproblems → exact solve within B. ---------
    let watch = Stopwatch::start();
    let mut bb = Backbone::clustering()
        .beta(1.0)
        .num_subproblems(5)
        .n_clusters(target_k)
        .min_cluster_size(2)
        .build()?;
    let model = bb.fit_with_budget(&data.x, &Budget::seconds(120.0))?.clone();
    let d = bb.last_diagnostics.as_ref().unwrap();
    println!(
        "BbLearn (M=5)    : silhouette {:.4}  ARI vs truth {:.4}  obj {:.1} gap {:.3} {:?} [{:.2}s]",
        silhouette_score(&data.x, &model.labels),
        adjusted_rand_index(&model.labels, &data.labels_true),
        model.objective,
        model.gap,
        model.status,
        watch.elapsed_secs()
    );
    println!(
        "  backbone: {} of {} possible pairs allowed into the exact solve",
        d.backbone_size,
        16 * 15 / 2
    );
    println!(
        "  clusters used: {} (k-means was forced to use {target_k})",
        model.labels.iter().collect::<std::collections::BTreeSet<_>>().len()
    );
    Ok(())
}
