//! Decision trees with cross-validated depth — Table 1's second block on
//! one dataset: greedy CART, the ODTLearn-style exact tree, and the
//! backbone (CART subproblems → exact tree on the backbone features).
//!
//! Run: `cargo run --release --example decision_tree_cv`

use backbone_learn::data::classification::{generate, ClassificationConfig};
use backbone_learn::data::{binarize, train_test_split};
use backbone_learn::metrics::auc;
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cart::{cart_fit, CartConfig};
use backbone_learn::solvers::exact_tree::{exact_tree_solve, BinNode, ExactTreeConfig};
use backbone_learn::util::{Budget, Stopwatch};
use backbone_learn::Backbone;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::seed_from_u64(3);
    let data = generate(
        &ClassificationConfig {
            n: 450,
            p: 40,
            k: 5,
            n_redundant: 4,
            n_clusters: 4,
            class_sep: 1.5,
            flip_y: 0.05,
        },
        &mut rng,
    );
    let split = train_test_split(&data.x, &data.y, 1.0 / 3.0, &mut rng);
    println!(
        "decision trees: n_train={} n_test={} p={} informative={:?}\n",
        split.x_train.rows(),
        split.x_test.rows(),
        data.x.cols(),
        data.informative
    );

    // --- CART with depth selected on a validation split. -----------------
    let watch = Stopwatch::start();
    let inner = train_test_split(&split.x_train, &split.y_train, 0.25, &mut rng);
    let mut best = (f64::NEG_INFINITY, 2usize);
    for depth in [1, 2, 3, 4, 5, 6] {
        let m = cart_fit(
            &inner.x_train,
            &inner.y_train,
            &CartConfig { max_depth: depth, ..Default::default() },
        );
        let a = auc(&inner.y_test, &m.predict_proba(&inner.x_test));
        println!("  CART depth {depth}: validation AUC {a:.4}");
        if a > best.0 {
            best = (a, depth);
        }
    }
    let cart = cart_fit(
        &split.x_train,
        &split.y_train,
        &CartConfig { max_depth: best.1, ..Default::default() },
    );
    let cart_auc = auc(&split.y_test, &cart.predict_proba(&split.x_test));
    println!(
        "CART (cv depth {}): test AUC {:.4} [{:.2}s]\n",
        best.1,
        cart_auc,
        watch.elapsed_secs()
    );

    // --- Exact tree over all binarized features (time-budgeted). ---------
    let watch = Stopwatch::start();
    let bz = binarize(&split.x_train, 2);
    let exact = exact_tree_solve(
        &bz.x_bin,
        &split.y_train,
        &ExactTreeConfig { depth: 2, min_leaf: 1, feature_subset: None },
        &Budget::seconds(60.0),
    );
    let proba: Vec<f64> = (0..split.x_test.rows())
        .map(|i| {
            let row = split.x_test.row(i);
            let mut node = &exact.root;
            loop {
                match node {
                    BinNode::Leaf { prob, .. } => return *prob,
                    BinNode::Split { feature, left, right } => {
                        node = if row[bz.feature_of[*feature]] <= bz.thresholds[*feature] {
                            right
                        } else {
                            left
                        };
                    }
                }
            }
        })
        .collect();
    println!(
        "Exact tree (depth 2, all {} binary features): test AUC {:.4}, {} errors, {:?} [{:.2}s]",
        bz.x_bin.cols(),
        auc(&split.y_test, &proba),
        exact.errors,
        exact.status,
        watch.elapsed_secs()
    );

    // --- Backbone: CART subproblems → exact tree on backbone features. ---
    let watch = Stopwatch::start();
    let mut bb = Backbone::decision_tree()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .depth(2)
        .build()?;
    bb.fit_with_budget(&split.x_train, &split.y_train, &Budget::seconds(60.0))?;
    let bb_auc = auc(&split.y_test, &bb.predict_proba(&split.x_test));
    let d = bb.last_diagnostics.as_ref().unwrap();
    let model = bb.model().unwrap();
    println!(
        "BbLearn (backbone {} of {} features): test AUC {:.4}, {} errors, {:?} [{:.2}s]",
        d.backbone_size,
        data.x.cols(),
        bb_auc,
        model.errors,
        model.status,
        watch.elapsed_secs()
    );
    println!("  final tree splits on original features {:?}", model.features_used());
    Ok(())
}
