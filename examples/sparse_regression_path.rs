//! Sparse regression shoot-out — the workload behind Table 1's first
//! block, on one dataset: GLMNet (lasso path), exact L0BnB, and the
//! backbone, with timing and support recovery.
//!
//! Run: `cargo run --release --example sparse_regression_path [-- n p k]`

use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::metrics::{r2_score, support_recovery};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cd::{elastic_net_path, ElasticNetConfig};
use backbone_learn::solvers::l0bnb::{l0bnb_solve, L0BnbConfig};
use backbone_learn::util::{Budget, Stopwatch};
use backbone_learn::Backbone;

fn main() -> anyhow::Result<()> {
    let args: Vec<usize> =
        std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
    let (n, p, k) = match args.as_slice() {
        [n, p, k, ..] => (*n, *p, *k),
        _ => (200, 1000, 5),
    };
    println!("sparse regression shoot-out: n={n} p={p} k={k}\n");

    let mut rng = Rng::seed_from_u64(1);
    let data = generate(
        &SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
        &mut rng,
    );
    // Fresh test set from the same ground truth.
    let test = {
        let mut d2 = generate(
            &SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
            &mut rng,
        );
        let signal = d2.x.matvec(&data.beta_true);
        for (yi, s) in d2.y.iter_mut().zip(&signal) {
            *yi = s + data.sigma * rng.normal();
        }
        d2
    };

    println!(
        "{:<22} {:>9} {:>9} {:>10} {:>8}",
        "method", "train R²", "test R²", "support F1", "time"
    );

    // --- GLMNet: full lasso path, best model by training R². ------------
    let watch = Stopwatch::start();
    let path = elastic_net_path(&data.x, &data.y, &ElasticNetConfig::default());
    let best = path.select_best(&data.x, &data.y);
    let t = watch.elapsed_secs();
    report("GLMNet (lasso path)", best.predict(&data.x), best.predict(&test.x),
           &best.support(), &data, &test, t);

    // --- Exact L0BnB at the true k. --------------------------------------
    let watch = Stopwatch::start();
    let exact = l0bnb_solve(
        &data.x,
        &data.y,
        &L0BnbConfig { k, lambda2: 1e-3, gap_tol: 0.01, max_nodes: 0 },
        &Budget::seconds(600.0),
    );
    let t = watch.elapsed_secs();
    report("L0BnB (exact)", exact.predict(&data.x), exact.predict(&test.x),
           &exact.support, &data, &test, t);

    // --- Backbone. --------------------------------------------------------
    let watch = Stopwatch::start();
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(k)
        .backend(
            backbone_learn::runtime::Backend::pjrt_from_dir("artifacts")
                .unwrap_or(backbone_learn::runtime::Backend::Native),
        )
        .build()?;
    let model = bb.fit(&data.x, &data.y)?.clone();
    let t = watch.elapsed_secs();
    report("BbLearn (backbone)", model.predict(&data.x), model.predict(&test.x),
           &model.support, &data, &test, t);
    let d = bb.last_diagnostics.as_ref().unwrap();
    println!(
        "\nbackbone: screened {} → |B| = {} → exact solve over {} features (vs {} originally)",
        d.screened_universe, d.backbone_size, d.backbone_size, p
    );
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn report(
    name: &str,
    train_pred: Vec<f64>,
    test_pred: Vec<f64>,
    support: &[usize],
    data: &backbone_learn::data::sparse_regression::SparseRegressionData,
    test: &backbone_learn::data::sparse_regression::SparseRegressionData,
    secs: f64,
) {
    let rec = support_recovery(support, &data.support_true);
    println!(
        "{:<22} {:>9.4} {:>9.4} {:>10.3} {:>7.2}s",
        name,
        r2_score(&data.y, &train_pred),
        r2_score(&test.y, &test_pred),
        rec.f1,
        secs
    );
}
