//! Custom backbone algorithm — the paper's extensibility story
//! (`CustomBackboneAlgorithm` via `set_solvers()` in the Python package)
//! mapped onto this crate's trait: implement [`BackboneLearner`] with your
//! own screen / heuristic / exact solver and get Algorithm 1 for free.
//!
//! Here: **sparse logistic regression** (not shipped in the core library).
//! - screen:   point-biserial |correlation| with the labels;
//! - heuristic: logistic IHT (projected gradient, k-sparse) per subproblem;
//! - exact:    best-subset enumeration over the backbone (≤ k features),
//!             each candidate fit by Newton-polished logistic regression.
//!
//! Run: `cargo run --release --example custom_backbone`

use backbone_learn::backbone::{
    BackboneLearner, BackboneParams, ExecutionPolicy, FitPipeline, SubproblemStrategy,
};
use backbone_learn::data::classification::{generate, ClassificationConfig};
use backbone_learn::linalg::Matrix;
use backbone_learn::metrics::{auc, support_recovery};
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;
use anyhow::Result;

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Gradient-descent logistic fit on a feature subset; returns (beta, b0).
fn logistic_fit(x: &Matrix, y: &[f64], cols: &[usize], iters: usize) -> (Vec<f64>, f64) {
    let xs = x.select_columns(cols);
    let (n, p) = (xs.rows(), xs.cols());
    let mut beta = vec![0.0; p];
    let mut b0 = 0.0;
    let lr = 4.0 / n as f64;
    for _ in 0..iters {
        let mut grad = vec![0.0; p];
        let mut grad0 = 0.0;
        for i in 0..n {
            let z = backbone_learn::linalg::dot(xs.row(i), &beta) + b0;
            let e = sigmoid(z) - y[i];
            grad0 += e;
            for (g, &v) in grad.iter_mut().zip(xs.row(i)) {
                *g += e * v;
            }
        }
        for (b, g) in beta.iter_mut().zip(&grad) {
            *b -= lr * g;
        }
        b0 -= lr * grad0;
    }
    (beta, b0)
}

/// Log-loss of a fitted subset model (for exact best-subset comparison).
fn log_loss(x: &Matrix, y: &[f64], cols: &[usize], beta: &[f64], b0: f64) -> f64 {
    let xs = x.select_columns(cols);
    let mut loss = 0.0;
    for i in 0..xs.rows() {
        let z = backbone_learn::linalg::dot(xs.row(i), beta) + b0;
        let p = sigmoid(z).clamp(1e-12, 1.0 - 1e-12);
        loss -= y[i] * p.ln() + (1.0 - y[i]) * (1.0 - p).ln();
    }
    loss
}

/// The final model our custom learner produces.
#[derive(Clone, Debug)]
struct SparseLogitModel {
    support: Vec<usize>,
    beta: Vec<f64>,
    intercept: f64,
}

impl SparseLogitModel {
    fn predict_proba(&self, x: &Matrix) -> Vec<f64> {
        let xs = x.select_columns(&self.support);
        (0..x.rows())
            .map(|i| sigmoid(backbone_learn::linalg::dot(xs.row(i), &self.beta) + self.intercept))
            .collect()
    }
}

/// The custom learner: all three application-specific pieces in ~80 lines.
struct SparseLogisticBackbone {
    k: usize,
    iht_iters: usize,
}

/// Per-task scratch of `fit_subproblem` — the workspace contract:
/// configuration lives on the (shared, `&self`) learner; every mutable
/// buffer lives here. The pipeline `Default`-constructs one workspace per
/// worker thread under `ExecutionPolicy::Parallel` (one total for
/// `Sequential`), so buffers are reused across subproblems and the
/// learner itself never needs `&mut`. Learners with no scratch can use
/// `type Workspace = ();`.
#[derive(Default)]
struct IhtScratch {
    xs: Matrix,
    beta: Vec<f64>,
    grad: Vec<f64>,
    idx: Vec<usize>,
}

impl BackboneLearner for SparseLogisticBackbone {
    type Data = backbone_learn::backbone::sparse_regression::SupervisedData;
    type Indicator = usize;
    type Model = SparseLogitModel;
    type Workspace = IhtScratch;

    fn num_entities(&self, data: &Self::Data) -> usize {
        data.x.cols()
    }

    fn utilities(&mut self, data: &Self::Data) -> Vec<f64> {
        // Point-biserial correlation = Pearson correlation with 0/1 labels.
        backbone_learn::backbone::screen::correlation_utilities(&data.x, &data.y)
    }

    fn fit_subproblem(
        &self,
        data: &Self::Data,
        entities: &[usize],
        _rng: &mut Rng,
        ws: &mut IhtScratch,
    ) -> Result<Vec<usize>> {
        // Logistic IHT on the subproblem columns. All scratch lives in
        // `ws`, so results are a pure function of (data, entities) and the
        // batch can run on any thread count with bit-identical output.
        data.x.select_columns_into(entities, &mut ws.xs);
        let (n, p) = (ws.xs.rows(), ws.xs.cols());
        ws.beta.clear();
        ws.beta.resize(p, 0.0);
        let lr = 4.0 / n as f64;
        for _ in 0..self.iht_iters {
            ws.grad.clear();
            ws.grad.resize(p, 0.0);
            for i in 0..n {
                let z = backbone_learn::linalg::dot(ws.xs.row(i), &ws.beta);
                let e = sigmoid(z) - data.y[i];
                for (g, &v) in ws.grad.iter_mut().zip(ws.xs.row(i)) {
                    *g += e * v;
                }
            }
            for (b, g) in ws.beta.iter_mut().zip(&ws.grad) {
                *b -= lr * g;
            }
            // Project to the k-sparse ball.
            ws.idx.clear();
            ws.idx.extend(0..p);
            let beta = &mut ws.beta;
            ws.idx
                .sort_by(|&a, &b| beta[b].abs().partial_cmp(&beta[a].abs()).unwrap());
            for &j in ws.idx.iter().skip(self.k) {
                beta[j] = 0.0;
            }
        }
        Ok(ws
            .beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, _)| entities[j])
            .collect())
    }

    fn indicator_entities(&self, indicator: &usize) -> Vec<usize> {
        vec![*indicator]
    }

    fn fit_reduced(
        &mut self,
        data: &Self::Data,
        backbone: &[usize],
        budget: &Budget,
    ) -> Result<SparseLogitModel> {
        // Exact best-subset over the backbone: enumerate all C(|B|, k)
        // supports (|B| is small — that is the whole point).
        let mut best: Option<(f64, Vec<usize>, Vec<f64>, f64)> = None;
        let mut subset = vec![0usize; self.k.min(backbone.len())];
        enumerate_subsets(backbone, subset.len(), 0, &mut subset, 0, &mut |cols| {
            if budget.expired() {
                return;
            }
            let (beta, b0) = logistic_fit(&data.x, &data.y, cols, 150);
            let loss = log_loss(&data.x, &data.y, cols, &beta, b0);
            if best.as_ref().map_or(true, |(l, ..)| loss < *l) {
                best = Some((loss, cols.to_vec(), beta, b0));
            }
        });
        let (_, support, beta, intercept) =
            best.expect("backbone non-empty → at least one subset evaluated");
        Ok(SparseLogitModel { support, beta, intercept })
    }
}

/// Enumerate all size-`k` subsets of `pool` (lexicographic).
fn enumerate_subsets(
    pool: &[usize],
    k: usize,
    start: usize,
    current: &mut Vec<usize>,
    depth: usize,
    f: &mut impl FnMut(&[usize]),
) {
    if depth == k {
        f(current);
        return;
    }
    for i in start..pool.len() {
        current[depth] = pool[i];
        enumerate_subsets(pool, k, i + 1, current, depth + 1, f);
    }
}

fn main() -> Result<()> {
    let mut rng = Rng::seed_from_u64(5);
    let data = generate(
        &ClassificationConfig {
            n: 300,
            p: 60,
            k: 3,
            n_redundant: 0,
            n_clusters: 2,
            class_sep: 2.0,
            flip_y: 0.02,
        },
        &mut rng,
    );
    println!("custom backbone: sparse logistic regression, n=300 p=60 k=3");
    println!("informative features: {:?}\n", data.informative);

    let sd = backbone_learn::backbone::sparse_regression::SupervisedData {
        x: data.x.clone(),
        y: data.y.clone(),
    };
    let mut learner = SparseLogisticBackbone { k: 3, iht_iters: 120 };
    let params = BackboneParams {
        num_subproblems: 5,
        beta: 0.5,
        alpha: 0.5,
        b_max: 12,
        max_iterations: 3,
        strategy: SubproblemStrategy::UniformCoverage,
        // The workspace split makes the custom learner `&self` in the
        // batch, so the subproblems run on all cores — bit-identical to
        // `ExecutionPolicy::Sequential`.
        execution: ExecutionPolicy::Parallel,
        threads: 0, // 0 = all available cores
        seed: 1,
    };
    // FitPipeline validates the params (typed BackboneError, no panics)
    // and runs Algorithm 1 with the batch-structured subproblem stage.
    let pipeline = FitPipeline::new(params)?;
    let fit = pipeline.run(&mut learner, &sd, &Budget::seconds(60.0))?;

    let d = &fit.diagnostics;
    println!(
        "screened universe {} → backbone {:?} ({} worker threads)",
        d.screened_universe, fit.backbone, d.threads_used
    );
    let model = &fit.model;
    let a = auc(&data.y, &model.predict_proba(&data.x));
    let rec = support_recovery(&model.support, &data.informative);
    println!("selected support  : {:?}", model.support);
    println!("in-sample AUC     : {a:.4}");
    println!("support F1        : {:.3}", rec.f1);
    assert!(a > 0.8, "custom backbone should separate the classes");
    Ok(())
}
