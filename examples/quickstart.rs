//! Quickstart — the paper's §3 usage snippet, reproduced end to end on
//! the unified estimator API:
//!
//! ```text
//! bb = Backbone::sparse_regression()
//!        .alpha(0.5).beta(0.5).num_subproblems(5)
//!        .max_nonzeros(5).lambda2(0.001)
//!        .build()?
//! bb.fit(X, y)?;  y_pred = bb.predict(X)
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::metrics::{r2_score, support_recovery};
use backbone_learn::rng::Rng;
use backbone_learn::runtime::Backend;
use backbone_learn::Backbone;

fn main() -> anyhow::Result<()> {
    // Synthetic high-dimensional sparse regression: 200 samples, 1000
    // features, 5 of which are truly relevant.
    let mut rng = Rng::seed_from_u64(7);
    let data = generate(
        &SparseRegressionConfig { n: 200, p: 1000, k: 5, rho: 0.1, snr: 5.0 },
        &mut rng,
    );

    // Use the AOT JAX/Pallas artifacts when available (falls back to the
    // pure-Rust hot path otherwise).
    let backend = Backend::pjrt_from_dir("artifacts").unwrap_or(Backend::Native);
    println!(
        "backend: {}",
        if backend.is_pjrt() { "PJRT (AOT artifacts)" } else { "native Rust" }
    );

    // The typed builder: every knob named, validated at build() time.
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(5)
        .lambda2(0.001)
        .backend(backend)
        .build()?;

    let model = bb.fit(&data.x, &data.y)?.clone();
    let y_pred = bb.predict(&data.x);

    let diag = bb.last_diagnostics.as_ref().unwrap();
    println!("screened universe : {}", diag.screened_universe);
    println!("backbone size     : {}", diag.backbone_size);
    println!("phase 1 (screen + subproblems): {:.3}s", diag.phase1_secs);
    println!("phase 2 (exact reduced solve) : {:.3}s", diag.phase2_secs);
    println!("selected support  : {:?}", model.support);
    println!("true support      : {:?}", data.support_true);
    let rec = support_recovery(&model.support, &data.support_true);
    println!("support F1        : {:.3}", rec.f1);
    println!("in-sample R²      : {:.4}", r2_score(&data.y, &y_pred));
    println!("exact-phase gap   : {:.4} ({:?})", model.gap, model.status);
    Ok(())
}
