//! End-to-end driver — proves all layers compose: generates the paper's
//! three synthetic workloads, runs every Table-1 method through the full
//! stack (Rust coordinator + exact MIO solvers + AOT JAX/Pallas artifacts
//! via PJRT where shape buckets match), and prints the Table-1 rows, plus
//! shape checks that assert the paper's qualitative findings.
//!
//! All BbLearn rows are fitted through the `Backbone::<problem>()`
//! builders (see `bench_support`), so this driver also exercises the
//! unified estimator API end to end.
//!
//! Results of this driver are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example end_to_end_table1 [-- --reps N]`

use backbone_learn::bench_support::{default_backend, render_table, run_block, TableRow};
use backbone_learn::config::{ExperimentConfig, Problem};
use backbone_learn::util::Stopwatch;

fn get_row<'a>(rows: &'a [TableRow], method: &str) -> &'a TableRow {
    rows.iter().find(|r| r.method == method).unwrap()
}

fn best_bblearn(rows: &[TableRow]) -> &TableRow {
    rows.iter()
        .filter(|r| r.method == "BbLearn")
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let reps: usize = std::env::args()
        .skip_while(|a| a != "--reps")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    let backend = default_backend();
    println!(
        "end-to-end Table 1 (quick scale, {} reps, backend = {})\n",
        reps,
        if backend.is_pjrt() { "PJRT artifacts" } else { "native" }
    );
    let watch = Stopwatch::start();

    // --- Sparse regression block ------------------------------------------
    let mut cfg = ExperimentConfig::quick_defaults(Problem::SparseRegression);
    cfg.repetitions = reps;
    let sr = run_block(&cfg)?;
    println!(
        "{}",
        render_table(
            &format!("Sparse Regression (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &sr
        )
    );
    // Shape checks (Table 1): BbLearn ≈ L0BnB accuracy, ≥ GLMNet; backbone ≪ p.
    let glmnet = get_row(&sr, "GLMNet");
    let l0bnb = get_row(&sr, "L0BnB");
    let bb = best_bblearn(&sr);
    assert!(
        bb.accuracy >= glmnet.accuracy - 0.02,
        "BbLearn ({:.3}) should match/beat GLMNet ({:.3})",
        bb.accuracy,
        glmnet.accuracy
    );
    assert!(
        (bb.accuracy - l0bnb.accuracy).abs() < 0.05,
        "BbLearn ({:.3}) should track exact L0BnB ({:.3})",
        bb.accuracy,
        l0bnb.accuracy
    );
    let bsize = bb.backbone_size.unwrap();
    assert!(
        bsize < cfg.p as f64 / 5.0,
        "backbone ({bsize}) should be ≪ p ({})",
        cfg.p
    );
    println!("✓ SR shape holds: BbLearn ≈ L0BnB ≥ GLMNet, |B| = {bsize:.0} ≪ p = {}\n", cfg.p);

    // --- Decision-tree block ------------------------------------------------
    let mut cfg = ExperimentConfig::quick_defaults(Problem::DecisionTrees);
    cfg.repetitions = reps;
    let dt = run_block(&cfg)?;
    println!(
        "{}",
        render_table(
            &format!("Decision Trees (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &dt
        )
    );
    let cart = get_row(&dt, "CART");
    let bb = best_bblearn(&dt);
    assert!(
        bb.accuracy >= cart.accuracy - 0.05,
        "BbLearn AUC ({:.3}) should be comparable to CART ({:.3})",
        bb.accuracy,
        cart.accuracy
    );
    println!(
        "✓ DT shape holds: BbLearn AUC {:.3} vs CART {:.3}, exact trees on a {}-feature backbone\n",
        bb.accuracy,
        cart.accuracy,
        bb.backbone_size.unwrap()
    );

    // --- Clustering block ----------------------------------------------------
    let mut cfg = ExperimentConfig::quick_defaults(Problem::Clustering);
    cfg.repetitions = reps;
    let cl = run_block(&cfg)?;
    println!(
        "{}",
        render_table(
            &format!("Clustering (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &cl
        )
    );
    let kmeans = get_row(&cl, "KMeans");
    let bb = best_bblearn(&cl);
    assert!(
        bb.accuracy >= kmeans.accuracy - 0.02,
        "BbLearn silhouette ({:.3}) should match/beat KMeans ({:.3})",
        bb.accuracy,
        kmeans.accuracy
    );
    println!(
        "✓ CL shape holds: BbLearn silhouette {:.3} ≥ KMeans {:.3}\n",
        bb.accuracy, kmeans.accuracy
    );

    println!("all three blocks complete in {:.1}s — stack verified end to end", watch.elapsed_secs());
    Ok(())
}
