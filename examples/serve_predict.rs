//! Persistence & serving walkthrough: fit a sparse-regression backbone,
//! freeze it as a `backbone-model/v1` artifact, load it back, and verify
//! the loaded model predicts **bit-identically** — then run the loopback
//! serving self-test against it (the same harness as
//! `backbone-learn serve --self-test`).
//!
//! Run: `cargo run --release --example serve_predict`
//!
//! The CLI equivalent of the first half:
//! ```text
//! backbone-learn save    --learner sr --out model.json --data-out rows.csv
//! backbone-learn predict --model model.json --data rows.csv
//! backbone-learn serve   --model model.json --port 8787
//! curl -s localhost:8787/healthz
//! ```

use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::persist::ModelArtifact;
use backbone_learn::rng::Rng;
use backbone_learn::serve::selftest::{run_self_test, SelfTestConfig};
use backbone_learn::{Backbone, Predict};

fn main() -> anyhow::Result<()> {
    // 1. Fit: the standard quickstart problem.
    let mut rng = Rng::seed_from_u64(7);
    let data = generate(
        &SparseRegressionConfig { n: 200, p: 500, k: 5, rho: 0.1, snr: 5.0 },
        &mut rng,
    );
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(5)
        .seed(7)
        .build()?;
    bb.fit(&data.x, &data.y)?;
    println!("fitted: support = {:?}", bb.model().unwrap().support);

    // 2. Save: fitted state + provenance → versioned JSON artifact.
    let path = std::env::temp_dir().join("serve_predict_example.json");
    let path = path.to_string_lossy().into_owned();
    let artifact = ModelArtifact::from_sparse_regression(&bb)?;
    artifact.save(&path)?;
    println!(
        "saved:  {path} ({} bytes, crate {})",
        std::fs::metadata(&path)?.len(),
        artifact.provenance.crate_version
    );

    // 3. Load: the artifact alone is enough to predict — no refit, no
    //    training data.
    let loaded = ModelArtifact::load(&path)?;
    let in_memory = bb.try_predict(&data.x)?;
    let from_disk = loaded.model.try_predict(&data.x)?;
    let identical = in_memory
        .iter()
        .zip(&from_disk)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!("loaded: predictions bit-identical to the fitted estimator: {identical}");
    assert!(identical, "round-trip must be exact");

    // 4. Serve: loopback load test over real HTTP (the `--self-test`
    //    harness; `cli serve` runs the same server as a daemon). The
    //    full harness also measures close-mode for comparison and
    //    hot-swaps the model mid-load to prove zero drops.
    let report = run_self_test(
        loaded.model,
        &SelfTestConfig {
            requests: 100,
            connections: 4,
            batch_rows: 16,
            threads: 2,
            ..SelfTestConfig::quick()
        },
    )?;
    let ka = &report.keep_alive;
    println!(
        "served: {} requests, {} failed, {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms",
        ka.requests,
        ka.failed,
        ka.req_per_sec,
        ka.p50_ms,
        ka.p99_ms
    );
    if let Some(speedup) = report.keepalive_speedup {
        println!("served: keep-alive is {speedup:.2}x close-mode throughput");
    }
    if let Some(swap) = &report.swap {
        println!(
            "served: hot swap under load — {} on v1, {} on v2, {} boundary violations",
            swap.served_old, swap.served_new, swap.boundary_violations
        );
    }
    assert!(report.passed(), "self-test must pass");
    std::fs::remove_file(&path).ok();
    Ok(())
}
