//! Fit-as-a-service walkthrough: fit a sparse-regression instance cold,
//! let the warm-start store learn from it, re-fit a sibling instance
//! **warm** (nearest-neighbor warm start + shrunken screening universe),
//! serve an exact repeat straight from the cache, and finally drive the
//! whole loop over HTTP through `POST /fit`.
//!
//! Run: `cargo run --release --example fit_service`
//!
//! The CLI equivalent:
//! ```text
//! backbone-learn fit   --problem sr --warm-cache store.json   # cold, learns
//! backbone-learn fit   --problem sr --warm-cache store.json   # exact hit
//! backbone-learn serve --model model.json --fit --warm-cache store.json
//! curl -s -X POST localhost:8787/fit \
//!      -d '{"x": [[...], ...], "y": [...], "k": 5}'
//! ```

use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::rng::Rng;
use backbone_learn::warmstart::{featurize, suggested_alpha, WarmStartStore};
use backbone_learn::Backbone;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let cfg = SparseRegressionConfig { n: 150, p: 600, k: 5, rho: 0.1, snr: 5.0 };
    let mut rng = Rng::seed_from_u64(7);
    let data = generate(&cfg, &mut rng);

    // 1. Cold fit: nothing cached yet, the full two-phase backbone runs.
    let clock = Instant::now();
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(5)
        .seed(7)
        .build()?;
    let cold = bb.fit(&data.x, &data.y)?.clone();
    let cold_secs = clock.elapsed().as_secs_f64();
    println!("cold fit: {:.3}s, support {:?}", cold_secs, cold.support);

    // 2. Learn: remember (features → support + coefficients + alpha).
    let mut store = WarmStartStore::new(64);
    let features = featurize(&data.x, &data.y, 5);
    let coeffs: Vec<f64> = cold.support.iter().map(|&j| cold.beta[j]).collect();
    store.record(&features, &cold.support, &coeffs, cold.intercept, cold.objective, 0.5);
    let path = std::env::temp_dir().join("fit_service_example_store.json");
    store.save(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("learned: {} entry → {}", store.len(), path.display());

    // 3. Warm re-fit: a sibling instance from the same family gets a
    //    nearest-neighbor warm start and a much smaller screening
    //    universe (suggested alpha keeps ~4k of p columns).
    let sibling = generate(&cfg, &mut rng);
    let f2 = featurize(&sibling.x, &sibling.y, 5);
    let warm = store.suggest(&f2).expect("neighbor hit");
    println!(
        "suggest: distance {:.3e}, exact = {}, α → {:.4}",
        warm.distance,
        warm.exact,
        suggested_alpha(600, 5)
    );
    let clock = Instant::now();
    let mut warm_bb = Backbone::sparse_regression()
        .alpha(suggested_alpha(600, 5))
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(5)
        .seed(7)
        .warm_start(warm.beta)
        .build()?;
    let warm_fit = warm_bb.fit(&sibling.x, &sibling.y)?.clone();
    let warm_secs = clock.elapsed().as_secs_f64();
    println!(
        "warm fit: {:.3}s ({:.1}× vs cold), support {:?}",
        warm_secs,
        cold_secs / warm_secs.max(1e-12),
        warm_fit.support
    );

    // 4. Exact repeat: the original instance is a distance-zero hit, so
    //    the cached solution is served without solving at all.
    let clock = Instant::now();
    let exact = store.suggest(&features).expect("exact hit");
    assert!(exact.exact);
    println!(
        "exact hit: {:.6}s, objective {:.6} (bit-identical to the cold fit: {})",
        clock.elapsed().as_secs_f64(),
        exact.objective,
        exact.objective.to_bits() == cold.objective.to_bits()
    );

    // 5. The same loop over HTTP: `serve --fit` exposes POST /fit, which
    //    consults and updates this exact store (see the README's curl
    //    example). Here we just show the store round-trips from disk.
    let (reloaded, err) = WarmStartStore::load_or_empty(&path, 64);
    assert!(err.is_none());
    println!("reloaded: {} entries — a fresh `serve --fit` starts warm", reloaded.len());
    let _ = std::fs::remove_file(&path);
    Ok(())
}
