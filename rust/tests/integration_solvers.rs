//! Cross-solver integration: the exact solvers agree with each other and
//! with the heuristics they bound, on shared synthetic workloads.

use backbone_learn::data::blobs;
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::Matrix;
use backbone_learn::metrics::adjusted_rand_index;
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cd::{l0_fit, L0Config};
use backbone_learn::solvers::clique::{
    brute_force_clustering, clique_solve, labels_objective, CliqueConfig,
};
use backbone_learn::solvers::kmeans::{kmeans_fit, KMeansConfig};
use backbone_learn::solvers::l0bnb::{l0bnb_solve, L0BnbConfig};
use backbone_learn::solvers::lp::{self, LinearProgram, Sense};
use backbone_learn::solvers::mip::{mip_solve, Callbacks, Mip, MipConfig};
use backbone_learn::solvers::SolveStatus;
use backbone_learn::util::Budget;

#[test]
fn exact_l0bnb_objective_never_worse_than_heuristic() {
    for seed in 0..5 {
        let data = generate(
            &SparseRegressionConfig { n: 60, p: 40, k: 5, rho: 0.5, snr: 2.0 },
            &mut Rng::seed_from_u64(seed),
        );
        let heur = l0_fit(&data.x, &data.y, &L0Config { k: 5, lambda2: 1e-3, ..Default::default() });
        let exact = l0bnb_solve(
            &data.x,
            &data.y,
            &L0BnbConfig { k: 5, lambda2: 1e-3, gap_tol: 1e-9, max_nodes: 0 },
            &Budget::seconds(120.0),
        );
        assert!(
            exact.objective <= heur.objective + 1e-6,
            "seed {seed}: exact {} > heuristic {}",
            exact.objective,
            heur.objective
        );
    }
}

#[test]
fn exact_clustering_objective_never_worse_than_kmeans() {
    for seed in 0..3 {
        let data = blobs::generate(
            &blobs::BlobsConfig {
                n: 10,
                p: 2,
                true_clusters: 3,
                cluster_std: 0.8,
                center_box: 6.0,
                min_center_dist: 3.0,
            },
            &mut Rng::seed_from_u64(seed),
        );
        let km = kmeans_fit(
            &data.x,
            &KMeansConfig { k: 3, ..Default::default() },
            &mut Rng::seed_from_u64(seed + 100),
        );
        let km_obj = labels_objective(&data.x, &km.labels);
        let exact = clique_solve(
            &data.x,
            &CliqueConfig { k: 3, min_cluster_size: 1, ..Default::default() },
            &Budget::seconds(120.0),
        )
        .unwrap();
        assert_eq!(exact.status, SolveStatus::Optimal, "seed {seed}");
        assert!(
            exact.objective <= km_obj + 1e-6,
            "seed {seed}: exact {} > kmeans {}",
            exact.objective,
            km_obj
        );
        // And equals brute force.
        let (_, bf_obj) = brute_force_clustering(&data.x, 3, 1);
        assert!((exact.objective - bf_obj).abs() < 1e-6, "seed {seed}");
    }
}

#[test]
fn milp_assignment_formulation_agrees_with_clique_solver() {
    // Model a tiny clustering instance directly as a MILP over pair
    // variables with explicit (non-lazy) triangle constraints, solve with
    // the generic mip solver, and cross-check the clique solver.
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 6,
            p: 2,
            true_clusters: 2,
            cluster_std: 0.4,
            center_box: 6.0,
            min_center_dist: 4.0,
        },
        &mut Rng::seed_from_u64(17),
    );
    let n = 6;
    let n_pairs = n * (n - 1) / 2;
    let pidx = |i: usize, j: usize| backbone_learn::solvers::clique::pair_index(n, i, j);

    let mut lpm = LinearProgram::new(n_pairs);
    lpm.bounds = vec![(0.0, 1.0); n_pairs];
    for i in 0..n {
        for j in (i + 1)..n {
            lpm.objective[pidx(i, j)] =
                backbone_learn::linalg::sqdist(data.x.row(i), data.x.row(j));
        }
    }
    // All triangle inequalities, explicitly.
    for i in 0..n {
        for j in (i + 1)..n {
            for l in (j + 1)..n {
                for (a, b, c) in [
                    (pidx(i, j), pidx(j, l), pidx(i, l)),
                    (pidx(i, j), pidx(i, l), pidx(j, l)),
                    (pidx(j, l), pidx(i, l), pidx(i, j)),
                ] {
                    lpm.add_constraint(
                        vec![(a, 1.0), (b, 1.0), (c, -1.0)],
                        Sense::Le,
                        1.0,
                    );
                }
            }
        }
    }
    // ≤ 2 clusters ⇒ ≥ n − 2 co-clustered pairs (spanning-forest bound)…
    lpm.add_constraint(
        (0..n_pairs).map(|idx| (idx, 1.0)).collect(),
        Sense::Ge,
        (n - 2) as f64,
    );
    // …plus the exact pigeonhole constraints: every 3-subset of points
    // must contain at least one co-clustered pair (the clique solver
    // generates these lazily; here we enumerate them all).
    for a in 0..n {
        for b in (a + 1)..n {
            for c in (b + 1)..n {
                lpm.add_constraint(
                    vec![(pidx(a, b), 1.0), (pidx(a, c), 1.0), (pidx(b, c), 1.0)],
                    Sense::Ge,
                    1.0,
                );
            }
        }
    }
    let mip = Mip { lp: lpm, binaries: (0..n_pairs).collect() };
    let res = mip_solve(&mip, &MipConfig::default(), &Budget::seconds(120.0), &Callbacks::default())
        .unwrap();
    assert_eq!(res.status, SolveStatus::Optimal);

    let clique = clique_solve(
        &data.x,
        &CliqueConfig { k: 2, min_cluster_size: 1, ..Default::default() },
        &Budget::seconds(120.0),
    )
    .unwrap();
    assert_eq!(clique.status, SolveStatus::Optimal);
    assert!(
        (res.objective - clique.objective).abs() < 1e-6,
        "explicit MILP {} vs lazy clique {}",
        res.objective,
        clique.objective
    );
}

#[test]
fn lp_duality_gap_zero_on_random_feasible_lps() {
    // Weak-duality sanity: for max-form LPs converted to min form, the
    // simplex optimum equals the optimum of the equivalent re-solve after
    // perturbation-free round trip (determinism), and is stable across
    // constraint reordering.
    let mut rng = Rng::seed_from_u64(23);
    for _ in 0..10 {
        let nv = 5;
        let mut lpm = LinearProgram::new(nv);
        lpm.bounds = vec![(0.0, 2.0); nv];
        for j in 0..nv {
            lpm.objective[j] = rng.uniform(-1.0, 1.0);
        }
        let mut rows = Vec::new();
        for _ in 0..4 {
            let coeffs: Vec<(usize, f64)> =
                (0..nv).map(|j| (j, rng.uniform(-1.0, 1.0))).collect();
            rows.push((coeffs, rng.uniform(0.5, 2.0)));
        }
        for (coeffs, rhs) in &rows {
            lpm.add_constraint(coeffs.clone(), Sense::Le, *rhs);
        }
        let a = lp::solve(&lpm).unwrap();
        // Reorder constraints; optimum must be identical.
        let mut lpm2 = LinearProgram::new(nv);
        lpm2.bounds = lpm.bounds.clone();
        lpm2.objective = lpm.objective.clone();
        for (coeffs, rhs) in rows.iter().rev() {
            lpm2.add_constraint(coeffs.clone(), Sense::Le, *rhs);
        }
        let b = lp::solve(&lpm2).unwrap();
        assert_eq!(a.status, SolveStatus::Optimal);
        assert!((a.objective - b.objective).abs() < 1e-7);
    }
}

#[test]
fn kmeans_and_exact_agree_on_well_separated_data() {
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 9,
            p: 2,
            true_clusters: 3,
            cluster_std: 0.15,
            center_box: 10.0,
            min_center_dist: 8.0,
        },
        &mut Rng::seed_from_u64(31),
    );
    let km = kmeans_fit(
        &data.x,
        &KMeansConfig { k: 3, ..Default::default() },
        &mut Rng::seed_from_u64(32),
    );
    let exact = clique_solve(
        &data.x,
        &CliqueConfig { k: 3, min_cluster_size: 1, ..Default::default() },
        &Budget::seconds(120.0),
    )
    .unwrap();
    // On trivially-separable data both must recover the ground truth.
    assert_eq!(adjusted_rand_index(&km.labels, &data.labels_true), 1.0);
    assert_eq!(adjusted_rand_index(&exact.labels, &data.labels_true), 1.0);
}

#[test]
fn binarized_exact_tree_consistent_with_continuous_cart_on_axis_aligned_truth() {
    // Ground truth is an axis-aligned depth-1 rule; both solvers must
    // reach zero training error.
    let mut rng = Rng::seed_from_u64(37);
    let n = 120;
    let mut x = Matrix::zeros(n, 3);
    let mut y = vec![0.0; n];
    for i in 0..n {
        for j in 0..3 {
            x.set(i, j, rng.uniform(0.0, 1.0));
        }
        y[i] = if x.get(i, 1) <= 0.5 { 1.0 } else { 0.0 };
    }
    let cart = backbone_learn::solvers::cart::cart_fit(
        &x,
        &y,
        &backbone_learn::solvers::cart::CartConfig { max_depth: 1, ..Default::default() },
    );
    let cart_err = cart
        .predict(&x)
        .iter()
        .zip(&y)
        .filter(|(a, b)| a != b)
        .count();
    assert_eq!(cart_err, 0);

    let bz = backbone_learn::data::binarize(&x, 7);
    let exact = backbone_learn::solvers::exact_tree::exact_tree_solve(
        &bz.x_bin,
        &y,
        &backbone_learn::solvers::exact_tree::ExactTreeConfig {
            depth: 1,
            min_leaf: 1,
            feature_subset: None,
        },
        &Budget::seconds(60.0),
    );
    // Quantile thresholds may not hit exactly 0.5; allow a small slack.
    assert!(
        exact.errors <= n / 10,
        "exact binarized tree errors too high: {}",
        exact.errors
    );
}
