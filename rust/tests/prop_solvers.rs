//! Property tests on solver invariants (DESIGN.md §6): LP optimality
//! conditions, MILP bound sandwiching, L0BnB vs brute force, exact-tree
//! optimality vs CART, k-means inertia monotonicity.

use backbone_learn::linalg::Matrix;
use backbone_learn::prop::property;
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cart::{cart_fit, CartConfig};
use backbone_learn::solvers::exact_tree::{exact_tree_solve, ExactTreeConfig};
use backbone_learn::solvers::kmeans::{kmeans_fit, KMeansConfig};
use backbone_learn::solvers::l0bnb::{brute_force, l0bnb_solve, L0BnbConfig};
use backbone_learn::solvers::lp::{self, LinearProgram, Sense};
use backbone_learn::solvers::mip::{mip_solve, Callbacks, Mip, MipConfig};
use backbone_learn::solvers::SolveStatus;
use backbone_learn::util::Budget;

#[test]
fn prop_lp_solution_feasible_and_beats_feasible_corners() {
    property("LP optimality vs box corners", 60, |g| {
        let nv = g.usize_in(2..6);
        let mut lp = LinearProgram::new(nv);
        for j in 0..nv {
            lp.objective[j] = g.f64_in(-1.0..1.0);
            lp.bounds[j] = (0.0, 1.0);
        }
        for _ in 0..g.usize_in(1..4) {
            let coeffs: Vec<(usize, f64)> =
                (0..nv).map(|j| (j, g.f64_in(-1.0..1.0))).collect();
            lp.add_constraint(coeffs, Sense::Le, g.f64_in(0.3..2.0));
        }
        let sol = lp::solve(&lp).unwrap();
        // x = 0 is always feasible here (rhs > 0), so LP must be Optimal.
        assert_eq!(sol.status, SolveStatus::Optimal);
        // Feasibility of the solution.
        for c in &lp.constraints {
            let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * sol.x[j]).sum();
            assert!(lhs <= c.rhs + 1e-6);
        }
        for (j, &(l, u)) in lp.bounds.iter().enumerate() {
            assert!(sol.x[j] >= l - 1e-7 && sol.x[j] <= u + 1e-7);
        }
        // Optimality: no feasible box corner does better.
        for mask in 0u32..(1 << nv) {
            let corner: Vec<f64> =
                (0..nv).map(|j| if mask & (1 << j) != 0 { 1.0 } else { 0.0 }).collect();
            let feasible = lp.constraints.iter().all(|c| {
                c.coeffs.iter().map(|&(j, a)| a * corner[j]).sum::<f64>() <= c.rhs + 1e-9
            });
            if feasible {
                let obj: f64 = lp.objective.iter().zip(&corner).map(|(c, v)| c * v).sum();
                assert!(sol.objective <= obj + 1e-6);
            }
        }
    });
}

#[test]
fn prop_mip_matches_brute_force_and_bounds_sandwich() {
    property("MILP = brute force", 40, |g| {
        let nv = g.usize_in(2..8);
        let mut lpm = LinearProgram::new(nv);
        lpm.bounds = vec![(0.0, 1.0); nv];
        for j in 0..nv {
            lpm.objective[j] = g.f64_in(-1.0..1.0);
        }
        for _ in 0..g.usize_in(1..4) {
            let coeffs: Vec<(usize, f64)> =
                (0..nv).map(|j| (j, g.f64_in(-1.0..1.0))).collect();
            lpm.add_constraint(coeffs, Sense::Le, g.f64_in(-0.5..1.5));
        }
        let mip = Mip { lp: lpm.clone(), binaries: (0..nv).collect() };
        let res =
            mip_solve(&mip, &MipConfig::default(), &Budget::unlimited(), &Callbacks::default())
                .unwrap();

        // Brute force over all binary points.
        let mut best: Option<f64> = None;
        for mask in 0u32..(1 << nv) {
            let x: Vec<f64> =
                (0..nv).map(|j| if mask & (1 << j) != 0 { 1.0 } else { 0.0 }).collect();
            let feasible = lpm.constraints.iter().all(|c| {
                c.coeffs.iter().map(|&(j, a)| a * x[j]).sum::<f64>() <= c.rhs + 1e-9
            });
            if feasible {
                let obj: f64 = lpm.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
                best = Some(best.map_or(obj, |b: f64| b.min(obj)));
            }
        }
        match best {
            Some(bobj) => {
                assert_eq!(res.status, SolveStatus::Optimal);
                assert!(
                    (res.objective - bobj).abs() < 1e-6,
                    "mip {} vs brute {bobj}",
                    res.objective
                );
                // Bound sandwich: lower ≤ objective.
                assert!(res.lower_bound <= res.objective + 1e-6);
            }
            None => assert_eq!(res.status, SolveStatus::Infeasible),
        }
    });
}

#[test]
fn prop_l0bnb_matches_brute_force_small() {
    property("L0BnB = brute force", 15, |g| {
        let n = g.usize_in(15..40);
        let p = g.usize_in(4..10);
        let k = g.usize_in(1..4).min(p);
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, g.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let cfg = L0BnbConfig { k, lambda2: 0.01, gap_tol: 1e-9, max_nodes: 0 };
        let res = l0bnb_solve(&x, &y, &cfg, &Budget::unlimited());
        let (_, bf_obj) = brute_force(&x, &y, &cfg);
        assert_eq!(res.status, SolveStatus::Optimal);
        assert!(
            res.objective <= bf_obj * (1.0 + 1e-6) + 1e-9,
            "bnb {} worse than brute {bf_obj}",
            res.objective
        );
        assert!(res.lower_bound <= res.objective + 1e-9);
    });
}

#[test]
fn prop_exact_tree_never_worse_than_cart() {
    property("exact tree ≤ CART errors", 25, |g| {
        let n = g.usize_in(20..80);
        let p = g.usize_in(2..7);
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, if g.bool_with(0.5) { 1.0 } else { 0.0 });
            }
        }
        let y: Vec<f64> = (0..n).map(|_| if g.bool_with(0.5) { 1.0 } else { 0.0 }).collect();
        let depth = g.usize_in(1..3);
        let exact = exact_tree_solve(
            &x,
            &y,
            &ExactTreeConfig { depth, min_leaf: 1, feature_subset: None },
            &Budget::unlimited(),
        );
        let cart = cart_fit(
            &x,
            &y,
            &CartConfig { max_depth: depth, min_samples_leaf: 1, min_samples_split: 2, feature_subset: None },
        );
        let cart_pred = cart.predict(&x);
        let cart_errors = cart_pred.iter().zip(&y).filter(|(a, b)| a != b).count();
        assert_eq!(exact.status, SolveStatus::Optimal);
        assert!(
            exact.errors <= cart_errors,
            "exact {} > CART {cart_errors} at depth {depth}",
            exact.errors
        );
        // Exact errors consistent with its own predictions.
        let pred = exact.predict(&x);
        let errs = pred.iter().zip(&y).filter(|(a, b)| a != b).count();
        assert_eq!(errs, exact.errors);
    });
}

#[test]
fn prop_kmeans_inertia_monotone_in_k_and_labels_valid() {
    property("k-means inertia monotone in k", 20, |g| {
        let n = g.usize_in(10..50);
        let d = g.usize_in(1..4);
        let mut x = Matrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, g.normal() * 3.0);
            }
        }
        let mut rng = Rng::seed_from_u64(g.case_seed);
        let mut prev = f64::INFINITY;
        for k in 1..=n.min(6) {
            let m = kmeans_fit(&x, &KMeansConfig { k, n_init: 4, ..Default::default() }, &mut rng);
            assert_eq!(m.labels.len(), n);
            assert!(m.labels.iter().all(|&l| l < k));
            // Inertia = Σ d²(x_i, c_{l_i}) — verify against definition.
            let manual: f64 = (0..n)
                .map(|i| backbone_learn::linalg::sqdist(x.row(i), m.centroids.row(m.labels[i])))
                .sum();
            assert!((manual - m.inertia).abs() < 1e-6 * manual.max(1.0));
            // Monotone non-increasing in k (with restarts, near-monotone;
            // allow 1% slack for local optima).
            assert!(m.inertia <= prev * 1.01 + 1e-9, "k={k}: {} > {prev}", m.inertia);
            prev = m.inertia.min(prev);
        }
    });
}

#[test]
fn prop_elastic_net_kkt_conditions() {
    use backbone_learn::solvers::cd::{elastic_net_fit, ElasticNetConfig};
    property("lasso KKT on standardized problem", 20, |g| {
        let n = g.usize_in(20..60);
        let p = g.usize_in(2..10);
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, g.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let lambda = g.f64_in(0.01..0.5);
        let cfg = ElasticNetConfig { alpha: 1.0, tol: 1e-10, max_iter: 5000, ..Default::default() };
        let m = elastic_net_fit(&x, &y, lambda, &cfg);
        // KKT for the lasso on the *standardized* problem: re-standardize
        // and check |(1/n) x̃_jᵀ r̃| ≤ λ (+tol) for zero coords, = λ for
        // active coords.
        let mut xs = x.clone();
        let scale = xs.standardize_columns();
        let y_mean = backbone_learn::linalg::mean(&y);
        let beta_std: Vec<f64> =
            m.beta.iter().zip(&scale).map(|(b, (_, s))| b * s).collect();
        let pred_std = xs.matvec(&beta_std);
        let resid: Vec<f64> = y
            .iter()
            .zip(&pred_std)
            .map(|(yi, pi)| (yi - y_mean) - pi)
            .collect();
        let grad = xs.matvec_t(&resid);
        for j in 0..p {
            let gj = grad[j] / n as f64;
            if beta_std[j] == 0.0 {
                assert!(gj.abs() <= lambda + 1e-5, "KKT violated at zero coord {j}: {gj}");
            } else {
                assert!(
                    (gj - lambda * beta_std[j].signum()).abs() < 1e-5,
                    "KKT violated at active coord {j}: {gj} vs {}",
                    lambda * beta_std[j].signum()
                );
            }
        }
    });
}
