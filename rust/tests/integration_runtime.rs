//! Integration tests of the PJRT runtime against the pure-Rust oracles.
//!
//! These tests require `make artifacts` to have produced `artifacts/`;
//! they are skipped (with a loud message) otherwise, so `cargo test`
//! stays green on a fresh checkout.

use backbone_learn::backbone::screen::correlation_utilities;
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::Matrix;
use backbone_learn::rng::Rng;
use backbone_learn::runtime::{Backend, Engine};
use backbone_learn::solvers::cd::{l0_fit, L0Config, L0Workspace};
use backbone_learn::solvers::kmeans::{KMeansConfig, KMeansWorkspace};

fn engine() -> Option<Engine> {
    match Engine::load("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: no artifacts ({err}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn pjrt_screen_matches_native_within_f32_tolerance() {
    let Some(engine) = engine() else { return };
    let cfg = SparseRegressionConfig { n: 200, p: 1000, k: 5, rho: 0.2, snr: 5.0 };
    let data = generate(&cfg, &mut Rng::seed_from_u64(1));
    let pjrt = engine
        .screen_utilities(&data.x, &data.y)
        .expect("pjrt screen failed")
        .expect("no bucket for (200, 1000) — rebuild artifacts");
    let native = correlation_utilities(&data.x, &data.y);
    assert_eq!(pjrt.len(), native.len());
    for (j, (a, b)) in pjrt.iter().zip(&native).enumerate() {
        assert!((a - b).abs() < 5e-4, "feature {j}: pjrt {a} vs native {b}");
    }
}

#[test]
fn pjrt_screen_ranks_true_support_first() {
    let Some(engine) = engine() else { return };
    let cfg = SparseRegressionConfig { n: 200, p: 1000, k: 5, rho: 0.0, snr: 20.0 };
    let data = generate(&cfg, &mut Rng::seed_from_u64(2));
    let u = engine.screen_utilities(&data.x, &data.y).unwrap().unwrap();
    let mut ranked: Vec<usize> = (0..1000).collect();
    ranked.sort_by(|&a, &b| u[b].partial_cmp(&u[a]).unwrap());
    let top: std::collections::BTreeSet<usize> = ranked[..5].iter().copied().collect();
    for j in &data.support_true {
        assert!(top.contains(j), "true feature {j} not in top-5 by PJRT screen");
    }
}

#[test]
fn pjrt_iht_support_matches_native_heuristic_quality() {
    let Some(engine) = engine() else { return };
    // Shape chosen to hit the (n=200, p≤512, k=5) bucket.
    let cfg = SparseRegressionConfig { n: 200, p: 400, k: 5, rho: 0.1, snr: 10.0 };
    let data = generate(&cfg, &mut Rng::seed_from_u64(3));
    let support = engine
        .iht_support(&data.x, &data.y, 5)
        .expect("pjrt iht failed")
        .expect("no bucket for (200, 400, k=5)");
    assert!(support.len() <= 5);
    assert!(support.iter().all(|&j| j < 400), "padded column selected: {support:?}");
    let rec = backbone_learn::metrics::support_recovery(&support, &data.support_true);
    assert!(rec.f1 >= 0.8, "f1={} (support {support:?})", rec.f1);
    // Native heuristic on the same data for comparison: PJRT support must
    // be comparable to native.
    let native = l0_fit(&data.x, &data.y, &L0Config { k: 5, ..Default::default() });
    let native_rec =
        backbone_learn::metrics::support_recovery(&native.support, &data.support_true);
    assert!(rec.f1 >= native_rec.f1 - 0.4, "pjrt {} vs native {}", rec.f1, native_rec.f1);
}

#[test]
fn pjrt_backend_equals_native_backend_on_subproblem_fit() {
    let Some(engine) = engine() else { return };
    let backend = Backend::Pjrt(std::sync::Arc::new(engine));
    let cfg = SparseRegressionConfig { n: 200, p: 300, k: 4, rho: 0.0, snr: 50.0 };
    let data = generate(&cfg, &mut Rng::seed_from_u64(4));
    let l0cfg = L0Config { k: 4, ..Default::default() };
    let via_pjrt =
        backend.l0_subproblem_fit(&data.x, &data.y, &l0cfg, &mut L0Workspace::default());
    let via_native = Backend::Native.l0_subproblem_fit(
        &data.x,
        &data.y,
        &l0cfg,
        &mut L0Workspace::default(),
    );
    // Clean signal: both must find the exact true support, and the
    // polished coefficients then agree to f32 precision.
    assert_eq!(via_pjrt.support, data.support_true);
    assert_eq!(via_native.support, data.support_true);
    for (a, b) in via_pjrt.beta.iter().zip(&via_native.beta) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}

#[test]
fn pjrt_lloyd_step_matches_native_assignment() {
    let Some(engine) = engine() else { return };
    // Bucket (n=16, d=2, k=4).
    let mut rng = Rng::seed_from_u64(5);
    let mut pts = Matrix::zeros(16, 2);
    for i in 0..16 {
        let cx = if i < 8 { 0.0 } else { 10.0 };
        pts.set(i, 0, cx + rng.normal() * 0.3);
        pts.set(i, 1, cx + rng.normal() * 0.3);
    }
    let mut cents = Matrix::zeros(4, 2);
    cents.row_mut(0).copy_from_slice(&[0.0, 0.0]);
    cents.row_mut(1).copy_from_slice(&[10.0, 10.0]);
    cents.row_mut(2).copy_from_slice(&[5.0, 5.0]);
    cents.row_mut(3).copy_from_slice(&[-5.0, -5.0]);
    let (new_c, labels, inertia) = engine
        .lloyd_step(&pts, &cents)
        .expect("pjrt lloyd failed")
        .expect("no bucket for (16, 2, 4)");
    // Points near (0,0) label 0, near (10,10) label 1.
    for (i, &l) in labels.iter().enumerate().take(8) {
        assert_eq!(l, 0, "point {i}");
    }
    for (i, &l) in labels.iter().enumerate().skip(8) {
        assert_eq!(l, 1, "point {i}");
    }
    assert!(inertia > 0.0 && inertia < 50.0, "inertia={inertia}");
    // Updated centroids moved towards the blob means.
    assert!((new_c.get(0, 0) - 0.0).abs() < 0.5);
    assert!((new_c.get(1, 0) - 10.0).abs() < 0.5);
}

#[test]
fn pjrt_kmeans_equals_native_quality() {
    let Some(engine) = engine() else { return };
    let data = backbone_learn::data::blobs::generate(
        &backbone_learn::data::blobs::BlobsConfig {
            n: 16,
            p: 2,
            true_clusters: 4,
            cluster_std: 0.3,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(6),
    );
    let backend = Backend::Pjrt(std::sync::Arc::new(engine));
    let cfg = KMeansConfig { k: 4, n_init: 5, ..Default::default() };
    let pjrt = backend.kmeans(
        &data.x,
        &cfg,
        &mut Rng::seed_from_u64(7),
        &mut KMeansWorkspace::default(),
    );
    let native = Backend::Native.kmeans(
        &data.x,
        &cfg,
        &mut Rng::seed_from_u64(7),
        &mut KMeansWorkspace::default(),
    );
    let ari_pjrt =
        backbone_learn::metrics::adjusted_rand_index(&pjrt.labels, &data.labels_true);
    let ari_native =
        backbone_learn::metrics::adjusted_rand_index(&native.labels, &data.labels_true);
    assert!(ari_pjrt > 0.9, "pjrt ari={ari_pjrt}");
    assert!(ari_native > 0.9, "native ari={ari_native}");
    // Same inertia up to f32 noise (same blobs, both converged).
    assert!((pjrt.inertia - native.inertia).abs() < 0.05 * native.inertia.max(1e-9));
}

#[test]
fn backend_falls_back_when_no_bucket_matches() {
    let Some(engine) = engine() else { return };
    let backend = Backend::Pjrt(std::sync::Arc::new(engine));
    // n = 73 matches no bucket → must silently fall back to native.
    let cfg = SparseRegressionConfig { n: 73, p: 50, k: 3, rho: 0.0, snr: 5.0 };
    let data = generate(&cfg, &mut Rng::seed_from_u64(8));
    let u = backend.correlation_utilities(&data.x, &data.y);
    let native = correlation_utilities(&data.x, &data.y);
    assert_eq!(u, native, "fallback must be bit-identical to native");
}

#[test]
fn describe_artifacts_lists_entries() {
    let Some(engine) = engine() else { return };
    let desc = engine.describe();
    assert!(desc.contains("screen"));
    assert!(desc.contains("iht"));
    assert!(desc.contains("lloyd"));
}
