//! End-to-end tests of the prediction server over real TCP sockets:
//! boot a `Server` on an ephemeral port, speak actual HTTP/1.1 to it,
//! and check `/predict`, `/healthz`, `/stats`, error handling, and
//! shutdown. Also drives the full artifact path: fit → save → load →
//! serve → compare served predictions against the in-memory model.

use backbone_learn::backbone::sparse_regression::SparseRegressionModel;
use backbone_learn::backbone::{Backbone, Predict};
use backbone_learn::data::sparse_regression;
use backbone_learn::json::Json;
use backbone_learn::linalg::Matrix;
use backbone_learn::persist::{LoadedModel, ModelArtifact};
use backbone_learn::rng::Rng;
use backbone_learn::serve::http::parse_response;
use backbone_learn::serve::selftest::{run_self_test, SelfTestConfig};
use backbone_learn::serve::{ServeConfig, Server};
use backbone_learn::solvers::SolveStatus;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

fn toy_model() -> LoadedModel {
    LoadedModel::SparseRegression(SparseRegressionModel {
        beta: vec![1.0, -1.0],
        intercept: 0.5,
        support: vec![0, 1],
        objective: 1.0,
        gap: 0.0,
        status: SolveStatus::Optimal,
    })
}

/// One raw request/response exchange against `addr`.
fn exchange(addr: SocketAddr, raw: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(raw.as_bytes()).expect("write");
    let mut response = Vec::new();
    stream.read_to_end(&mut response).expect("read");
    let (status, body) = parse_response(&response).expect("parse response");
    let body = String::from_utf8(body).expect("utf8 body");
    (status, Json::parse(&body).expect("json body"))
}

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, Json) {
    exchange(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Boot a server, run `f` against it, shut it down.
fn with_server(model: LoadedModel, f: impl FnOnce(SocketAddr)) {
    with_server_cfg(model, ServeConfig { threads: 2, ..Default::default() }, f);
}

/// Same, with an explicit config (fit service, warm cache, ...).
fn with_server_cfg(model: LoadedModel, cfg: ServeConfig, f: impl FnOnce(SocketAddr)) {
    let server = Server::bind("127.0.0.1:0", model, &cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    let shutdown = server.shutdown_handle().expect("handle");
    std::thread::scope(|scope| {
        scope.spawn(move || server.run());
        f(addr);
        shutdown.shutdown();
    });
}

#[test]
fn healthz_reports_model_identity() {
    with_server(toy_model(), |addr| {
        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(body.get("schema").and_then(Json::as_str), Some("backbone-model/v1"));
        assert_eq!(
            body.get("learner").and_then(Json::as_str),
            Some("sparse_regression")
        );
        assert_eq!(body.get("num_features").and_then(Json::as_usize), Some(2));
    });
}

#[test]
fn predict_serves_batches_and_stats_count_them() {
    with_server(toy_model(), |addr| {
        let (status, body) = post(addr, "/predict", r#"{"rows": [[1, 0], [0, 1], [2, 2]]}"#);
        assert_eq!(status, 200, "{body:?}");
        let preds: Vec<f64> = body
            .get("predictions")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|p| p.as_f64().unwrap())
            .collect();
        assert_eq!(preds, vec![1.5, -0.5, 0.5]);
        assert_eq!(body.get("rows").and_then(Json::as_usize), Some(3));

        let (status, stats) = get(addr, "/stats");
        assert_eq!(status, 200);
        assert_eq!(stats.get("predict_requests").and_then(Json::as_usize), Some(1));
        assert_eq!(stats.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(stats.get("failures").and_then(Json::as_usize), Some(0));
        let lat = stats.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(1));
    });
}

#[test]
fn bad_requests_get_4xx_json_errors() {
    with_server(toy_model(), |addr| {
        let (status, body) = post(addr, "/predict", "this is not json");
        assert_eq!(status, 400);
        assert!(body.get("error").is_some());

        let (status, _) = post(addr, "/predict", r#"{"rows": [[1, 2, 3]]}"#);
        assert_eq!(status, 400, "shape mismatch must be a client error");

        let (status, _) = get(addr, "/predict");
        assert_eq!(status, 405);

        let (status, _) = get(addr, "/nothing-here");
        assert_eq!(status, 404);

        let (_, stats) = get(addr, "/stats");
        assert_eq!(stats.get("failures").and_then(Json::as_usize), Some(4));
        // Failed requests never enter the latency profile.
        let lat = stats.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(0));
    });
}

#[test]
fn fitted_artifact_serves_bit_identical_predictions() {
    // The full path the CLI wires together: fit → artifact → load → serve.
    let gen_cfg = sparse_regression::SparseRegressionConfig {
        n: 60,
        p: 80,
        k: 3,
        rho: 0.1,
        snr: 5.0,
    };
    let data = sparse_regression::generate(&gen_cfg, &mut Rng::seed_from_u64(21));
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(3)
        .seed(2)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    let artifact = ModelArtifact::from_sparse_regression(&bb).unwrap();
    // Through the wire format, not just the in-memory struct.
    let served_model =
        ModelArtifact::parse(&artifact.to_json().to_string_pretty()).unwrap().model;

    let rows: Vec<Vec<f64>> = (0..4).map(|i| data.x.row(i).to_vec()).collect();
    let x = Matrix::from_rows(&rows);
    let expected = bb.try_predict(&x).unwrap();

    with_server(served_model, |addr| {
        let body = {
            let rows_json: Vec<Json> = rows
                .iter()
                .map(|r| Json::Array(r.iter().map(|&v| Json::from_f64(v)).collect()))
                .collect();
            let mut m = std::collections::BTreeMap::new();
            m.insert("rows".to_string(), Json::Array(rows_json));
            Json::Object(m).to_string_compact()
        };
        let (status, response) = post(addr, "/predict", &body);
        assert_eq!(status, 200, "{response:?}");
        let served: Vec<f64> = response
            .get("predictions")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|p| p.as_f64_tagged().unwrap())
            .collect();
        assert_eq!(served.len(), expected.len());
        for (s, e) in served.iter().zip(&expected) {
            assert_eq!(s.to_bits(), e.to_bits(), "served prediction differs");
        }
    });
}

#[test]
fn fit_service_learns_and_serves_warm_starts_end_to_end() {
    // The full online loop over real sockets: POST /fit solves cold and
    // registers the model, /predict serves it by id, a repeat submission
    // is an exact warm hit with a bit-identical objective, and the
    // learned store persists across server restarts.
    let cache = std::env::temp_dir()
        .join(format!("backbone_warm_e2e_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_file(&cache);
    let body = concat!(
        r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1],"#,
        r#" [5, 0, 0], [6, 1, 0], [7, 0, 1], [8, 1, 1]],"#,
        r#" "y": [2, 4, 6, 8, 10, 12, 14, 16], "k": 1, "m": 2}"#
    );
    let cfg = ServeConfig {
        threads: 2,
        enable_fit: true,
        warm_cache_path: Some(cache.clone()),
        ..Default::default()
    };
    with_server_cfg(toy_model(), cfg.clone(), |addr| {
        let (status, first) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{first:?}");
        let warm = first.get("warm").unwrap();
        assert_eq!(warm.get("hit").and_then(Json::as_str), Some("none"));
        let id = first.get("model_id").and_then(Json::as_str).unwrap().to_string();

        // Served immediately by the registry path. y = 2·x₀; the small
        // default ridge penalty shrinks the slope slightly.
        let (status, pred) = post(
            addr,
            "/predict",
            &format!(r#"{{"model": "{id}", "rows": [[10, 0, 0]]}}"#),
        );
        assert_eq!(status, 200, "{pred:?}");
        let p = pred.get("predictions").unwrap().as_array().unwrap()[0]
            .as_f64_tagged()
            .unwrap();
        assert!((p - 20.0).abs() < 0.1, "prediction {p}");

        let (status, second) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{second:?}");
        assert_eq!(
            second.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
        let o1 = first.get("objective").and_then(Json::as_f64_tagged).unwrap();
        let o2 = second.get("objective").and_then(Json::as_f64_tagged).unwrap();
        assert_eq!(o1.to_bits(), o2.to_bits(), "exact hit must reproduce the objective");

        // Per-route accounting: two fits, one predict.
        let (_, stats) = get(addr, "/stats");
        let routes = stats.get("routes").unwrap();
        let fit_route = routes.get("fit").unwrap();
        assert_eq!(fit_route.get("requests").and_then(Json::as_usize), Some(2));
        assert_eq!(fit_route.get("models_fitted").and_then(Json::as_usize), Some(2));
        assert_eq!(fit_route.get("failures").and_then(Json::as_usize), Some(0));
        assert_eq!(
            routes.get("predict").unwrap().get("requests").and_then(Json::as_usize),
            Some(1)
        );
    });

    // A fresh server over the same cache path starts warm: the first
    // submission of the already-seen instance is an exact hit.
    with_server_cfg(toy_model(), cfg, |addr| {
        let (status, resp) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(
            resp.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
    });
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn self_test_harness_reports_zero_failures() {
    let report = run_self_test(
        toy_model(),
        &SelfTestConfig { requests: 16, concurrency: 2, batch_rows: 8, threads: 2 },
    )
    .unwrap();
    assert_eq!(report.failed, 0);
    assert_eq!(report.requests, 16);
    assert!(report.req_per_sec > 0.0);
    assert!(report.p99_ms >= report.p50_ms);
}
