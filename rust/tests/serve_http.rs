//! End-to-end tests of the model server over real TCP sockets: boot a
//! `Server` on an ephemeral port, speak actual HTTP/1.1 to it, and check
//! keep-alive reuse, path-routed multi-model predict, atomic hot swap
//! under concurrent load, fit backpressure (429 + `Retry-After`), the
//! versioned `/stats` document, and the full fit → save → load → serve
//! artifact path.

use backbone_learn::backbone::sparse_regression::SparseRegressionModel;
use backbone_learn::backbone::{Backbone, Predict};
use backbone_learn::data::sparse_regression;
use backbone_learn::json::Json;
use backbone_learn::linalg::Matrix;
use backbone_learn::persist::{LoadedModel, ModelArtifact, Provenance};
use backbone_learn::rng::Rng;
use backbone_learn::serve::http::{parse_response, read_response};
use backbone_learn::serve::selftest::{run_self_test, SelfTestConfig};
use backbone_learn::serve::{ServeConfig, Server};
use backbone_learn::solvers::SolveStatus;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

fn toy_model_with_intercept(intercept: f64) -> LoadedModel {
    LoadedModel::SparseRegression(SparseRegressionModel {
        beta: vec![1.0, -1.0],
        intercept,
        support: vec![0, 1],
        objective: 1.0,
        gap: 0.0,
        status: SolveStatus::Optimal,
    })
}

fn toy_model() -> LoadedModel {
    toy_model_with_intercept(0.5)
}

/// Wrap a model as a `backbone-model/v1` artifact document (the
/// `PUT /models/<id>` hot-swap payload).
fn artifact_doc(model: LoadedModel) -> String {
    ModelArtifact {
        model,
        provenance: Provenance {
            crate_version: "test".into(),
            seed: 0,
            params: Json::Object(BTreeMap::new()),
            config: Json::Object(BTreeMap::new()),
            diagnostics: None,
        },
    }
    .to_json()
    .to_string_compact()
}

/// One connection-per-request exchange against `addr`. Sends
/// `Connection: close` — against a keep-alive server, a `read_to_end`
/// client that leaves the connection open would hang until the idle
/// timeout.
fn exchange(addr: SocketAddr, raw: &str) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.write_all(raw.as_bytes()).expect("write");
    let mut response = Vec::new();
    stream.read_to_end(&mut response).expect("read");
    let (status, body) = parse_response(&response).expect("parse response");
    let body = String::from_utf8(body).expect("utf8 body");
    (status, Json::parse(&body).expect("json body"))
}

fn get(addr: SocketAddr, path: &str) -> (u16, Json) {
    exchange(
        addr,
        &format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    )
}

/// GET returning the raw body and headers — for `/metrics`, whose body
/// is Prometheus text, not JSON.
fn get_text(addr: SocketAddr, path: &str) -> (u16, Vec<(String, String)>, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .write_all(
            format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").as_bytes(),
        )
        .expect("write");
    let mut response = Vec::new();
    stream.read_to_end(&mut response).expect("read");
    let (status, headers, body) = read_response(&mut &response[..]).expect("read response");
    (status, headers, String::from_utf8(body).expect("utf8 body"))
}

fn request_raw(method: &str, path: &str, body: &str, close: bool) -> String {
    format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}{}\r\n\r\n{body}",
        body.len(),
        if close { "\r\nConnection: close" } else { "" },
    )
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, Json) {
    exchange(addr, &request_raw("POST", path, body, true))
}

fn put(addr: SocketAddr, path: &str, body: &str) -> (u16, Json) {
    exchange(addr, &request_raw("PUT", path, body, true))
}

/// Boot a server, run `f` against it, shut it down.
fn with_server(model: LoadedModel, f: impl FnOnce(SocketAddr)) {
    let cfg = ServeConfig::builder().threads(2).build().unwrap();
    with_server_cfg(model, cfg, f);
}

/// Same, with an explicit config (fit service, warm cache, ...).
fn with_server_cfg(model: LoadedModel, cfg: ServeConfig, f: impl FnOnce(SocketAddr)) {
    with_registry(vec![("default".to_string(), model)], cfg, f);
}

/// Same, with a named multi-model registry.
fn with_registry(
    models: Vec<(String, LoadedModel)>,
    cfg: ServeConfig,
    f: impl FnOnce(SocketAddr),
) {
    let server = Server::bind_registry("127.0.0.1:0", models, &cfg).expect("bind");
    let addr = server.local_addr().expect("addr");
    let shutdown = server.shutdown_handle().expect("handle");
    std::thread::scope(|scope| {
        scope.spawn(move || server.run());
        f(addr);
        shutdown.shutdown();
    });
}

#[test]
fn healthz_reports_model_identity() {
    with_server(toy_model(), |addr| {
        let (status, body) = get(addr, "/healthz");
        assert_eq!(status, 200);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(body.get("schema").and_then(Json::as_str), Some("backbone-model/v1"));
        assert_eq!(
            body.get("learner").and_then(Json::as_str),
            Some("sparse_regression")
        );
        assert_eq!(body.get("num_features").and_then(Json::as_usize), Some(2));
        assert_eq!(body.get("default_model").and_then(Json::as_str), Some("default"));
        assert_eq!(body.get("model_version").and_then(Json::as_usize), Some(1));
    });
}

#[test]
fn predict_serves_batches_and_stats_count_them() {
    with_server(toy_model(), |addr| {
        let (status, body) = post(addr, "/predict", r#"{"rows": [[1, 0], [0, 1], [2, 2]]}"#);
        assert_eq!(status, 200, "{body:?}");
        let preds: Vec<f64> = body
            .get("predictions")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|p| p.as_f64().unwrap())
            .collect();
        assert_eq!(preds, vec![1.5, -0.5, 0.5]);
        assert_eq!(body.get("rows").and_then(Json::as_usize), Some(3));
        assert_eq!(body.get("model").and_then(Json::as_str), Some("default"));
        assert_eq!(body.get("model_version").and_then(Json::as_usize), Some(1));

        let (status, stats) = get(addr, "/stats");
        assert_eq!(status, 200);
        // Versioned document with the pre-PR-7 flat keys still in place.
        assert_eq!(
            stats.get("schema").and_then(Json::as_str),
            Some("backbone-serve-stats/v1")
        );
        assert_eq!(stats.get("predict_requests").and_then(Json::as_usize), Some(1));
        assert_eq!(stats.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(stats.get("failures").and_then(Json::as_usize), Some(0));
        let lat = stats.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(1));
        // New PR-7 sections: per-model accounting + connection counter.
        let default = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(default.get("requests").and_then(Json::as_usize), Some(1));
        assert_eq!(default.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(default.get("version").and_then(Json::as_usize), Some(1));
        assert!(stats.get("connections").and_then(Json::as_usize).unwrap() >= 1);
        assert_eq!(stats.get("swaps").and_then(Json::as_usize), Some(0));
    });
}

#[test]
fn bad_requests_get_4xx_json_errors() {
    with_server(toy_model(), |addr| {
        let (status, body) = post(addr, "/predict", "this is not json");
        assert_eq!(status, 400);
        assert!(body.get("error").is_some());

        let (status, _) = post(addr, "/predict", r#"{"rows": [[1, 2, 3]]}"#);
        assert_eq!(status, 400, "shape mismatch must be a client error");

        let (status, _) = get(addr, "/predict");
        assert_eq!(status, 405);

        let (status, _) = get(addr, "/nothing-here");
        assert_eq!(status, 404);

        let (_, stats) = get(addr, "/stats");
        assert_eq!(stats.get("failures").and_then(Json::as_usize), Some(4));
        // Failed requests never enter the latency profile.
        let lat = stats.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(0));
    });
}

#[test]
fn keep_alive_serves_many_requests_on_one_connection() {
    with_server(toy_model(), |addr| {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let predict = request_raw("POST", "/predict", r#"{"rows": [[1, 0]]}"#, false);
        for i in 0..5 {
            stream.write_all(predict.as_bytes()).expect("write");
            let (status, headers, body) = read_response(&mut stream).expect("response");
            assert_eq!(status, 200, "request {i} on the shared connection");
            assert!(
                headers
                    .iter()
                    .any(|(k, v)| k == "connection" && v.eq_ignore_ascii_case("keep-alive")),
                "server must advertise keep-alive: {headers:?}"
            );
            let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(
                doc.get("predictions").unwrap().as_array().unwrap()[0].as_f64(),
                Some(1.5)
            );
        }
        // /stats over the SAME socket: everything so far was one
        // connection carrying six requests.
        stream
            .write_all(b"GET /stats HTTP/1.1\r\nHost: t\r\n\r\n")
            .expect("write stats");
        let (status, _, body) = read_response(&mut stream).expect("stats response");
        assert_eq!(status, 200);
        let stats = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(
            stats.get("connections").and_then(Json::as_usize),
            Some(1),
            "5 predicts + 1 stats over one socket must count one connection"
        );
        assert_eq!(stats.get("requests_total").and_then(Json::as_usize), Some(6));
    });
}

#[test]
fn idle_keep_alive_connections_do_not_starve_new_clients() {
    // Regression for the PR-7 review: with workers pinned to keep-alive
    // connections, two idle clients monopolized a threads(2) server and
    // parked every later connection (health probes, the hot-swap PUT) in
    // the accept backlog. Handler-per-connection makes `threads`
    // irrelevant to serving concurrency.
    let cfg = ServeConfig::builder().threads(1).build().unwrap();
    with_server_cfg(toy_model(), cfg, |addr| {
        let predict = request_raw("POST", "/predict", r#"{"rows": [[1, 0]]}"#, false);
        let mut held: Vec<TcpStream> = (0..2)
            .map(|i| {
                let mut s = TcpStream::connect(addr).expect("connect held");
                s.write_all(predict.as_bytes()).expect("write held");
                let (status, _, _) = read_response(&mut s).expect("held response");
                assert_eq!(status, 200, "held connection {i}");
                s
            })
            .collect();

        // A third client must be answered while both keep-alive sockets
        // stay open and idle. The read timeout turns a starvation
        // regression into a clean failure instead of a hung test.
        let mut probe = TcpStream::connect(addr).expect("connect probe");
        probe
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .unwrap();
        probe
            .write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .expect("write probe");
        let (status, _, _) =
            read_response(&mut probe).expect("healthz while keep-alive clients idle");
        assert_eq!(status, 200);

        // The held connections are still live request channels afterward.
        for s in &mut held {
            s.write_all(predict.as_bytes()).expect("re-write");
            let (status, _, _) = read_response(s).expect("re-response");
            assert_eq!(status, 200);
        }
    });
}

#[test]
fn connection_cap_rejects_with_503_then_recovers() {
    let cfg = ServeConfig::builder()
        .max_connections(1)
        .retry_after_secs(3)
        .build()
        .unwrap();
    with_server_cfg(toy_model(), cfg, |addr| {
        let predict = request_raw("POST", "/predict", r#"{"rows": [[1, 0]]}"#, false);

        // Occupy the single admission slot with a keep-alive client.
        let mut held = TcpStream::connect(addr).expect("connect held");
        held.write_all(predict.as_bytes()).expect("write held");
        let (status, _, _) = read_response(&mut held).expect("held response");
        assert_eq!(status, 200);

        // The next connection must get the full backpressure contract —
        // 503, Retry-After header, JSON error body — instead of queueing
        // invisibly in the accept backlog.
        let mut rejected = TcpStream::connect(addr).expect("connect rejected");
        rejected
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))
            .unwrap();
        rejected.write_all(predict.as_bytes()).expect("write rejected");
        let (status, headers, body) = read_response(&mut rejected).expect("503 response");
        assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
        assert!(
            headers.iter().any(|(k, v)| k == "retry-after" && v == "3"),
            "Retry-After header missing: {headers:?}"
        );
        let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(doc.get("error").is_some());

        // Dropping the held connection frees the slot; the server must
        // recover without restart. Poll: the handler needs a moment to
        // observe the close and release the admission gate.
        drop(held);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let stats = loop {
            let mut retry = TcpStream::connect(addr).expect("reconnect");
            retry
                .set_read_timeout(Some(std::time::Duration::from_secs(10)))
                .unwrap();
            retry
                .write_all(request_raw("POST", "/predict", r#"{"rows": [[1, 0]]}"#, true).as_bytes())
                .expect("write retry");
            match read_response(&mut retry) {
                Ok((200, _, _)) => {
                    // Same polling story for the /stats read: it needs
                    // the slot the retry connection just vacated.
                    let (status, stats) = get(addr, "/stats");
                    if status == 200 {
                        break stats;
                    }
                }
                Ok((503, _, _)) | Err(_) => {}
                Ok((status, _, body)) => {
                    panic!("unexpected {status}: {}", String::from_utf8_lossy(&body))
                }
            }
            assert!(
                std::time::Instant::now() < deadline,
                "server never recovered after the admission slot freed"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        };
        assert!(
            stats.get("connections_rejected").and_then(Json::as_usize) >= Some(1),
            "rejections must surface in /stats: {stats:?}"
        );
    });
}

#[test]
fn path_routed_predict_and_models_listing() {
    let cfg = ServeConfig::builder().threads(2).build().unwrap();
    let models = vec![
        ("alpha".to_string(), toy_model_with_intercept(0.5)),
        ("beta".to_string(), toy_model_with_intercept(2.5)),
    ];
    with_registry(models, cfg, |addr| {
        // Unqualified /predict goes to the first registration.
        let (status, body) = post(addr, "/predict", r#"{"rows": [[1, 0]]}"#);
        assert_eq!(status, 200, "{body:?}");
        assert_eq!(body.get("model").and_then(Json::as_str), Some("alpha"));
        assert_eq!(
            body.get("predictions").unwrap().as_array().unwrap()[0].as_f64(),
            Some(1.5)
        );

        // Path routing addresses each model by id.
        let (status, body) = post(addr, "/models/beta/predict", r#"{"rows": [[1, 0]]}"#);
        assert_eq!(status, 200, "{body:?}");
        assert_eq!(body.get("model").and_then(Json::as_str), Some("beta"));
        assert_eq!(
            body.get("predictions").unwrap().as_array().unwrap()[0].as_f64(),
            Some(3.5)
        );

        let (status, body) = post(addr, "/models/gone/predict", r#"{"rows": [[1, 0]]}"#);
        assert_eq!(status, 404, "{body:?}");

        // The registry listing names both, with alpha as default.
        let (status, listing) = get(addr, "/models");
        assert_eq!(status, 200);
        assert_eq!(
            listing.get("schema").and_then(Json::as_str),
            Some("backbone-models/v1")
        );
        assert_eq!(listing.get("default").and_then(Json::as_str), Some("alpha"));
        assert_eq!(listing.get("count").and_then(Json::as_usize), Some(2));
        let ids: Vec<&str> = listing
            .get("models")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|m| m.get("id").and_then(Json::as_str).unwrap())
            .collect();
        assert_eq!(ids, vec!["alpha", "beta"]);
    });
}

#[test]
fn hot_swap_is_atomic_under_concurrent_load() {
    // Every connection gets its own handler thread, so three persistent
    // keep-alive clients plus the mid-load swap PUT need no thread
    // budget — the default config is enough.
    let cfg = ServeConfig::builder().build().unwrap();
    with_server_cfg(toy_model(), cfg, |addr| {
        // Baseline: v1 serves intercept 0.5 → [1.5].
        let (status, body) = post(addr, "/predict", r#"{"rows": [[1, 0]]}"#);
        assert_eq!(status, 200, "{body:?}");
        assert_eq!(body.get("model_version").and_then(Json::as_usize), Some(1));

        // Hammer /predict from several keep-alive connections while the
        // main thread swaps in an artifact with intercept +1. Every
        // response must be 200, carry a prediction consistent with its
        // reported version, and versions must never go backwards on a
        // connection.
        let swap_body = artifact_doc(toy_model_with_intercept(1.5));
        std::thread::scope(|scope| {
            let clients: Vec<_> = (0..3)
                .map(|_| {
                    scope.spawn(move || {
                        let mut stream = TcpStream::connect(addr).expect("connect");
                        let predict =
                            request_raw("POST", "/predict", r#"{"rows": [[1, 0]]}"#, false);
                        let mut max_version = 0usize;
                        let mut served = [0usize; 2]; // [old, new]
                        for _ in 0..40 {
                            stream.write_all(predict.as_bytes()).expect("write");
                            let (status, _, body) =
                                read_response(&mut stream).expect("response");
                            assert_eq!(status, 200, "a request dropped during hot swap");
                            let doc =
                                Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
                            let version =
                                doc.get("model_version").and_then(Json::as_usize).unwrap();
                            let pred = doc.get("predictions").unwrap().as_array().unwrap()
                                [0]
                            .as_f64()
                            .unwrap();
                            // Prediction must match the version the
                            // response claims — the old Arc serves old
                            // numbers, the new Arc new ones, never a mix.
                            let expected = if version >= 2 { 2.5 } else { 1.5 };
                            assert_eq!(pred, expected, "version {version} served {pred}");
                            assert!(version >= max_version, "version went backwards");
                            max_version = version;
                            served[usize::from(version >= 2)] += 1;
                        }
                        served
                    })
                })
                .collect();

            // Let the clients get going, then swap mid-flight.
            std::thread::sleep(std::time::Duration::from_millis(5));
            let (status, body) = put(addr, "/models/default", &swap_body);
            assert_eq!(status, 200, "{body:?}");
            assert_eq!(body.get("version").and_then(Json::as_usize), Some(2));
            assert_eq!(body.get("swapped").and_then(Json::as_bool), Some(true));

            for client in clients {
                client.join().expect("client panicked");
            }
        });

        // After the dust settles the new version serves everywhere.
        let (status, body) = post(addr, "/predict", r#"{"rows": [[1, 0]]}"#);
        assert_eq!(status, 200);
        assert_eq!(body.get("model_version").and_then(Json::as_usize), Some(2));
        assert_eq!(
            body.get("predictions").unwrap().as_array().unwrap()[0].as_f64(),
            Some(2.5)
        );

        // Stats: exactly one swap, model section at version 2.
        let (_, stats) = get(addr, "/stats");
        assert_eq!(stats.get("swaps").and_then(Json::as_usize), Some(1));
        let default = stats.get("models").unwrap().get("default").unwrap();
        assert_eq!(default.get("version").and_then(Json::as_usize), Some(2));
        assert_eq!(default.get("source").and_then(Json::as_str), Some("swapped"));

        // Reserved fitted ids reject swaps; garbage bodies are 400s.
        let (status, _) = put(addr, "/models/m1", &artifact_doc(toy_model()));
        assert_eq!(status, 409, "m<N> ids are reserved for fitted models");
        let (status, _) = put(addr, "/models/default", "{}");
        assert_eq!(status, 400);
    });
}

#[test]
fn fit_backpressure_replies_429_with_retry_after() {
    // One fit slot; a deliberately heavy fit occupies it while a second
    // submission must bounce with 429 + Retry-After (header and body).
    // A single solver thread keeps the heavy fit slow enough to probe
    // (`threads` now sizes the fit scheduler, not serving concurrency).
    let cfg = ServeConfig::builder()
        .threads(1)
        .enable_fit(true)
        .max_concurrent_fits(1)
        .retry_after_secs(7)
        .build()
        .unwrap();
    with_server_cfg(toy_model(), cfg, |addr| {
        // ~160×1200 dense instance: big enough that the solve is still
        // running while we probe, small enough to finish in seconds.
        let (n, p) = (160usize, 1200usize);
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let row: Vec<Json> = (0..p)
                .map(|j| Json::from_f64(((i * 31 + j * 7) % 11) as f64 * 0.25 - 1.25))
                .collect();
            y.push(Json::from_f64((i % 13) as f64 * 0.5 - 3.0));
            rows.push(Json::Array(row));
        }
        let mut doc = BTreeMap::new();
        doc.insert("x".to_string(), Json::Array(rows));
        doc.insert("y".to_string(), Json::Array(y));
        doc.insert("k".to_string(), Json::Number(3.0));
        doc.insert("m".to_string(), Json::Number(4.0));
        let slow_body = Json::Object(doc).to_string_compact();

        std::thread::scope(|scope| {
            let slow = scope.spawn(|| post(addr, "/fit", &slow_body));

            // Wait until the slow fit holds the slot.
            loop {
                let (_, stats) = get(addr, "/stats");
                if stats.get("fits_in_flight").and_then(Json::as_usize) >= Some(1) {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }

            // Second fit while the slot is held: full 429 contract.
            let tiny = r#"{"x": [[1, 0], [2, 1], [3, 0], [4, 1]], "y": [2, 4, 6, 8], "k": 1}"#;
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream
                .write_all(request_raw("POST", "/fit", tiny, true).as_bytes())
                .expect("write");
            let (status, headers, body) = read_response(&mut stream).expect("response");
            assert_eq!(status, 429, "{}", String::from_utf8_lossy(&body));
            assert!(
                headers.iter().any(|(k, v)| k == "retry-after" && v == "7"),
                "Retry-After header missing: {headers:?}"
            );
            let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert!(doc.get("error").is_some());
            assert_eq!(doc.get("retry_after_secs").and_then(Json::as_usize), Some(7));

            let (status, first) = slow.join().expect("slow fit panicked");
            assert_eq!(status, 200, "{first:?}");
        });
    });
}

#[test]
fn fitted_artifact_serves_bit_identical_predictions() {
    // The full path the CLI wires together: fit → artifact → load → serve.
    let gen_cfg = sparse_regression::SparseRegressionConfig {
        n: 60,
        p: 80,
        k: 3,
        rho: 0.1,
        snr: 5.0,
    };
    let data = sparse_regression::generate(&gen_cfg, &mut Rng::seed_from_u64(21));
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(3)
        .seed(2)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    let artifact = ModelArtifact::from_sparse_regression(&bb).unwrap();
    // Through the wire format, not just the in-memory struct.
    let served_model =
        ModelArtifact::parse(&artifact.to_json().to_string_pretty()).unwrap().model;

    let rows: Vec<Vec<f64>> = (0..4).map(|i| data.x.row(i).to_vec()).collect();
    let x = Matrix::from_rows(&rows);
    let expected = bb.try_predict(&x).unwrap();

    with_server(served_model, |addr| {
        let body = {
            let rows_json: Vec<Json> = rows
                .iter()
                .map(|r| Json::Array(r.iter().map(|&v| Json::from_f64(v)).collect()))
                .collect();
            let mut m = std::collections::BTreeMap::new();
            m.insert("rows".to_string(), Json::Array(rows_json));
            Json::Object(m).to_string_compact()
        };
        let (status, response) = post(addr, "/predict", &body);
        assert_eq!(status, 200, "{response:?}");
        let served: Vec<f64> = response
            .get("predictions")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|p| p.as_f64_tagged().unwrap())
            .collect();
        assert_eq!(served.len(), expected.len());
        for (s, e) in served.iter().zip(&expected) {
            assert_eq!(s.to_bits(), e.to_bits(), "served prediction differs");
        }
    });
}

#[test]
fn fit_service_learns_and_serves_warm_starts_end_to_end() {
    // The full online loop over real sockets: POST /fit solves cold and
    // registers the model, /predict serves it by id, a repeat submission
    // is an exact warm hit with a bit-identical objective, and the
    // learned store persists across server restarts.
    let cache = std::env::temp_dir()
        .join(format!("backbone_warm_e2e_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let _ = std::fs::remove_file(&cache);
    let body = concat!(
        r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1],"#,
        r#" [5, 0, 0], [6, 1, 0], [7, 0, 1], [8, 1, 1]],"#,
        r#" "y": [2, 4, 6, 8, 10, 12, 14, 16], "k": 1, "m": 2}"#
    );
    let cfg = ServeConfig::builder()
        .threads(2)
        .enable_fit(true)
        .warm_cache_path(Some(cache.clone()))
        .build()
        .unwrap();
    with_server_cfg(toy_model(), cfg.clone(), |addr| {
        let (status, first) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{first:?}");
        let warm = first.get("warm").unwrap();
        assert_eq!(warm.get("hit").and_then(Json::as_str), Some("none"));
        let id = first.get("model_id").and_then(Json::as_str).unwrap().to_string();

        // Served by the body-field route (pre-PR-7 contract)...
        let (status, pred) = post(
            addr,
            "/predict",
            &format!(r#"{{"model": "{id}", "rows": [[10, 0, 0]]}}"#),
        );
        assert_eq!(status, 200, "{pred:?}");
        let p = pred.get("predictions").unwrap().as_array().unwrap()[0]
            .as_f64_tagged()
            .unwrap();
        assert!((p - 20.0).abs() < 0.1, "prediction {p}");

        // ...and by the PR-7 path route.
        let (status, pred) =
            post(addr, &format!("/models/{id}/predict"), r#"{"rows": [[10, 0, 0]]}"#);
        assert_eq!(status, 200, "{pred:?}");
        assert_eq!(pred.get("model").and_then(Json::as_str), Some(id.as_str()));

        let (status, second) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{second:?}");
        assert_eq!(
            second.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
        let o1 = first.get("objective").and_then(Json::as_f64_tagged).unwrap();
        let o2 = second.get("objective").and_then(Json::as_f64_tagged).unwrap();
        assert_eq!(o1.to_bits(), o2.to_bits(), "exact hit must reproduce the objective");

        // Per-route accounting: two fits, two predicts.
        let (_, stats) = get(addr, "/stats");
        let routes = stats.get("routes").unwrap();
        let fit_route = routes.get("fit").unwrap();
        assert_eq!(fit_route.get("requests").and_then(Json::as_usize), Some(2));
        assert_eq!(fit_route.get("models_fitted").and_then(Json::as_usize), Some(2));
        assert_eq!(fit_route.get("failures").and_then(Json::as_usize), Some(0));
        assert_eq!(
            routes.get("predict").unwrap().get("requests").and_then(Json::as_usize),
            Some(2)
        );
    });

    // A fresh server over the same cache path starts warm: the first
    // submission of the already-seen instance is an exact hit.
    with_server_cfg(toy_model(), cfg, |addr| {
        let (status, resp) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(
            resp.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
    });
    let _ = std::fs::remove_file(&cache);
}

#[test]
fn self_test_harness_reports_zero_failures() {
    let report = run_self_test(
        toy_model(),
        &SelfTestConfig {
            requests: 16,
            connections: 2,
            batch_rows: 8,
            threads: 2,
            ..SelfTestConfig::quick()
        },
    )
    .unwrap();
    assert_eq!(report.total_failed(), 0);
    assert_eq!(report.keep_alive.requests, 16);
    assert!(report.keep_alive.req_per_sec > 0.0);
    assert!(report.keep_alive.p99_ms >= report.keep_alive.p50_ms);
    assert!(report.passed());
}

#[test]
fn metrics_serves_prometheus_exposition_and_reconciles_with_stats() {
    use backbone_learn::obs::metric_value;
    with_server(toy_model(), |addr| {
        // Move the counters: one good predict, one bad request.
        let (status, _) = post(addr, "/predict", r#"{"rows": [[1, 2], [3, 4]]}"#);
        assert_eq!(status, 200);
        let (status, _) = post(addr, "/predict", "not json");
        assert_eq!(status, 400);

        let (status, headers, text) = get_text(addr, "/metrics");
        assert_eq!(status, 200);
        let content_type = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-type"))
            .map(|(_, v)| v.as_str())
            .unwrap_or("");
        assert!(
            content_type.starts_with("text/plain"),
            "content type {content_type:?}"
        );

        // Exposition-format golden: HELP/TYPE pairs precede the series,
        // counters end in _total, gauges don't.
        for family in [
            ("backbone_http_requests_total", "counter"),
            ("backbone_route_requests_total", "counter"),
            ("backbone_route_failures_total", "counter"),
            ("backbone_model_rows_predicted_total", "counter"),
            ("backbone_models_loaded", "gauge"),
            ("backbone_serve_uptime_seconds", "gauge"),
            ("backbone_build_info", "gauge"),
            // Process-global registry families, preregistered at zero.
            ("backbone_fit_total", "counter"),
            ("backbone_pipeline_stage_seconds_total", "counter"),
            ("backbone_warmstart_lookups_total", "counter"),
            ("backbone_persist_write_seconds", "histogram"),
        ] {
            assert!(
                text.contains(&format!("# HELP {} ", family.0)),
                "missing HELP for {}", family.0
            );
            assert!(
                text.contains(&format!("# TYPE {} {}", family.0, family.1)),
                "missing TYPE for {}", family.0
            );
        }

        // Every non-comment line is `name[{labels}] value` with a
        // parseable value — the format a Prometheus scraper accepts.
        let mut series = 0usize;
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, value) = line.rsplit_once(' ').expect("series line has a value");
            assert!(
                value.parse::<f64>().is_ok() || value == "NaN" || value == "+Inf",
                "unparseable sample value in {line:?}"
            );
            series += 1;
        }
        assert!(series >= 25, "only {series} series exposed");

        // The server-derived section reads the same atomics as /stats,
        // so the two endpoints must agree exactly.
        let (_, stats) = get(addr, "/stats");
        let routes = stats.get("routes").unwrap();
        let predict = routes.get("predict").unwrap();
        for (metric, labels, key) in [
            ("backbone_route_requests_total", ("route", "predict"), "requests"),
            ("backbone_route_failures_total", ("route", "predict"), "failures"),
            ("backbone_route_units_total", ("route", "predict"), "rows_predicted"),
        ] {
            assert_eq!(
                metric_value(&text, metric, &[labels]),
                predict.get(key).and_then(Json::as_f64_tagged),
                "{metric} disagrees with /stats routes.predict.{key}"
            );
        }
        assert_eq!(
            metric_value(&text, "backbone_model_rows_predicted_total", &[("model", "default")]),
            stats
                .get("models")
                .and_then(|m| m.get("default"))
                .and_then(|d| d.get("rows_predicted"))
                .and_then(Json::as_f64_tagged),
        );
        assert_eq!(metric_value(&text, "backbone_models_loaded", &[]), Some(1.0));
        assert_eq!(metric_value(&text, "backbone_build_info", &[("backend", backbone_learn::linalg::backend_name())]), Some(1.0));
    });
}

#[test]
fn metrics_counters_are_monotonic_across_requests() {
    use backbone_learn::obs::metric_value;
    with_server(toy_model(), |addr| {
        let scrape = |addr| {
            let (status, _, text) = get_text(addr, "/metrics");
            assert_eq!(status, 200);
            text
        };
        let before = scrape(addr);
        for _ in 0..3 {
            let (status, _) = post(addr, "/predict", r#"{"rows": [[1, 2]]}"#);
            assert_eq!(status, 200);
        }
        let after = scrape(addr);
        let requests = |text: &str| {
            metric_value(text, "backbone_route_requests_total", &[("route", "predict")]).unwrap()
        };
        assert_eq!(requests(&after), requests(&before) + 3.0);
        // Scrapes themselves never count as route traffic, and every
        // exposed counter is nondecreasing between the two scrapes.
        let total = |text: &str| {
            metric_value(text, "backbone_http_requests_total", &[]).unwrap()
        };
        assert!(total(&after) >= total(&before) + 3.0);
        for name in [
            "backbone_http_failures_total",
            "backbone_route_failures_total",
            "backbone_model_swaps_total",
        ] {
            let labels: &[(&str, &str)] =
                if name.starts_with("backbone_route") { &[("route", "predict")] } else { &[] };
            let (a, b) = (metric_value(&before, name, labels), metric_value(&after, name, labels));
            assert!(b >= a, "{name} went backwards: {a:?} -> {b:?}");
        }
    });
}

#[test]
fn traced_fit_returns_nested_trace_tree() {
    let body = concat!(
        r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1],"#,
        r#" [5, 0, 0], [6, 1, 0], [7, 0, 1], [8, 1, 1]],"#,
        r#" "y": [2, 4, 6, 8, 10, 12, 14, 16], "k": 1, "m": 2,"#,
        r#" "warm": false, "trace": true}"#
    );
    let cfg = ServeConfig::builder().threads(2).enable_fit(true).build().unwrap();
    with_server_cfg(toy_model(), cfg, |addr| {
        let (status, resp) = post(addr, "/fit", body);
        assert_eq!(status, 200, "{resp:?}");
        let trace = resp.get("trace").expect("trace requested but absent");
        assert_eq!(trace.get("name").and_then(Json::as_str), Some("fit"));
        let root_secs = trace.get("secs").and_then(Json::as_f64_tagged).unwrap();
        assert!(root_secs >= 0.0);
        let children = trace.get("children").and_then(Json::as_array).expect("children");
        let names: Vec<&str> =
            children.iter().filter_map(|c| c.get("name").and_then(Json::as_str)).collect();
        assert!(names.contains(&"screen"), "stages traced: {names:?}");
        assert!(names.contains(&"reduced"), "stages traced: {names:?}");
        // Iterations nest their own stage children.
        let iteration = children
            .iter()
            .find(|c| c.get("name").and_then(Json::as_str) == Some("iteration"))
            .expect("iteration span");
        let inner: Vec<&str> = iteration
            .get("children")
            .and_then(Json::as_array)
            .map(|cs| cs.iter().filter_map(|c| c.get("name").and_then(Json::as_str)).collect())
            .unwrap_or_default();
        assert!(inner.contains(&"subproblems"), "iteration children: {inner:?}");

        // An untraced fit carries no trace payload.
        let untraced = body.replace(r#""trace": true"#, r#""trace": false"#);
        let (status, resp) = post(addr, "/fit", &untraced);
        assert_eq!(status, 200, "{resp:?}");
        assert!(resp.get("trace").is_none());
    });
}
