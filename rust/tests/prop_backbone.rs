//! Property tests on coordinator invariants (DESIGN.md §6): the
//! Algorithm-1 loop's contracts hold for arbitrary learners, data sizes
//! and hyperparameters.

use backbone_learn::backbone::{
    run_backbone, subproblems::construct_subproblems, Backbone, BackboneError,
    BackboneLearner, BackboneParams, ExecutionPolicy, SubproblemStrategy,
};
use backbone_learn::prop::{property, Gen};
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;

/// Learner with a random relevant set; subproblems report the relevant
/// entities they see (the idealized-oracle model of the paper's analysis).
struct OracleLearner {
    n_entities: usize,
    relevant: Vec<usize>,
    reduced_backbone: Vec<usize>,
}

impl OracleLearner {
    fn new(n_entities: usize, relevant: Vec<usize>) -> Self {
        Self { n_entities, relevant, reduced_backbone: vec![] }
    }
}

impl BackboneLearner for OracleLearner {
    type Data = ();
    type Indicator = usize;
    type Model = usize; // backbone length
    type Workspace = ();

    fn num_entities(&self, _d: &()) -> usize {
        self.n_entities
    }

    fn utilities(&mut self, _d: &()) -> Vec<f64> {
        // Utilities loosely correlated with relevance (relevant get 2x).
        (0..self.n_entities)
            .map(|j| if self.relevant.contains(&j) { 2.0 } else { 1.0 })
            .collect()
    }

    fn fit_subproblem(
        &self,
        _d: &(),
        entities: &[usize],
        _rng: &mut Rng,
        _ws: &mut (),
    ) -> anyhow::Result<Vec<usize>> {
        // Invariant: entities are sorted, unique.
        assert!(entities.windows(2).all(|w| w[0] < w[1]), "unsorted subproblem");
        Ok(entities.iter().copied().filter(|j| self.relevant.contains(j)).collect())
    }

    fn indicator_entities(&self, i: &usize) -> Vec<usize> {
        vec![*i]
    }

    fn fit_reduced(&mut self, _d: &(), backbone: &[usize], _b: &Budget) -> anyhow::Result<usize> {
        self.reduced_backbone = backbone.to_vec();
        Ok(backbone.len())
    }
}

fn random_params(g: &mut Gen) -> BackboneParams {
    BackboneParams {
        num_subproblems: g.usize_in(1..12),
        beta: g.f64_in(0.1..1.0),
        alpha: g.f64_in(0.1..1.0),
        b_max: if g.bool_with(0.5) { g.usize_in(1..30) } else { 0 },
        max_iterations: g.usize_in(1..5),
        strategy: if g.bool_with(0.5) {
            SubproblemStrategy::UniformCoverage
        } else {
            SubproblemStrategy::UtilityWeighted
        },
        // Both policies — and any worker count, including 0 = all cores —
        // must satisfy every coordinator invariant (the batch contract
        // guarantees identical results).
        execution: if g.bool_with(0.5) {
            ExecutionPolicy::Sequential
        } else {
            ExecutionPolicy::Parallel
        },
        threads: g.usize_in(0..6),
        seed: g.usize_in(0..1_000_000) as u64,
        trace: false,
    }
}

#[test]
fn prop_backbone_subset_of_universe_and_bmax_respected() {
    property("backbone ⊆ relevant, |B| ≤ B_max", 150, |g| {
        let n = g.usize_in(5..120);
        let n_rel = g.usize_in(1..n.max(2)).min(n);
        let relevant = g.subset(n, n_rel);
        let params = random_params(g);
        let mut learner = OracleLearner::new(n, relevant.clone());
        let fit = run_backbone(&mut learner, &(), &params, &Budget::unlimited()).unwrap();

        // 1. Backbone is sorted & unique.
        assert!(fit.backbone.windows(2).all(|w| w[0] < w[1]));
        // 2. Backbone only contains relevant entities (oracle learner).
        for &b in &fit.backbone {
            assert!(relevant.contains(&b), "non-relevant {b} in backbone");
        }
        // 3. B_max honoured.
        if params.b_max > 0 {
            assert!(fit.backbone.len() <= params.b_max);
        }
        // 4. Diagnostics consistent.
        let d = &fit.diagnostics;
        assert_eq!(d.backbone_size, fit.backbone.len());
        assert!(d.screened_universe <= n);
        assert!(d.screened_universe >= 1);
        assert!(!d.iterations.is_empty());
        assert!(d.iterations.len() <= params.max_iterations);
        // 5. Reduced fit saw exactly the final backbone.
        assert_eq!(learner.reduced_backbone, fit.backbone);
        // 6. Model = |B| (oracle learner contract).
        assert_eq!(fit.model, fit.backbone.len());
    });
}

#[test]
fn prop_subproblem_counts_follow_m_over_2t() {
    property("⌈M/2^t⌉ schedule", 100, |g| {
        let n = g.usize_in(10..80);
        let params = BackboneParams {
            num_subproblems: g.usize_in(1..16),
            beta: g.f64_in(0.2..1.0),
            alpha: 1.0,
            b_max: 1, // unreachable → runs to the iteration cap
            max_iterations: g.usize_in(1..5),
            strategy: SubproblemStrategy::UniformCoverage,
            seed: 7,
            ..Default::default()
        };
        // Everything relevant → the universe never shrinks.
        let mut learner = OracleLearner::new(n, (0..n).collect());
        let fit = run_backbone(&mut learner, &(), &params, &Budget::unlimited()).unwrap();
        for (t, it) in fit.diagnostics.iterations.iter().enumerate() {
            let expected = (((params.num_subproblems as f64) / 2f64.powi(t as i32)).ceil()
                as usize)
                .max(1);
            assert_eq!(it.num_subproblems, expected, "iteration {t}");
            // Subproblem size = ⌈β · |U_t|⌉ clamped.
            let expect_size = (((params.beta * it.universe_size as f64).ceil()) as usize)
                .clamp(1, it.universe_size);
            assert_eq!(it.subproblem_size, expect_size, "iteration {t}");
        }
    });
}

#[test]
fn prop_determinism_same_seed_same_backbone() {
    property("determinism", 60, |g| {
        let n = g.usize_in(5..60);
        let n_rel = g.usize_in(1..n.max(2)).min(n);
        let relevant = g.subset(n, n_rel);
        let params = random_params(g);
        let run = |relevant: Vec<usize>| {
            let mut l = OracleLearner::new(n, relevant);
            run_backbone(&mut l, &(), &params, &Budget::unlimited()).unwrap().backbone
        };
        assert_eq!(run(relevant.clone()), run(relevant));
    });
}

#[test]
fn prop_parallel_bit_identical_to_sequential_for_any_batch_and_thread_count() {
    // The satellite determinism property: randomize batch size (M, β, n)
    // against worker count; the parallel scheduler must reproduce the
    // sequential schedule bit for bit — same backbone, same model, same
    // reduced-fit input — for every combination.
    property("parallel ≡ sequential under random batch/thread shapes", 60, |g| {
        let n = g.usize_in(5..80);
        let n_rel = g.usize_in(1..n.max(2)).min(n);
        let relevant = g.subset(n, n_rel);
        let mut params = random_params(g);
        params.execution = ExecutionPolicy::Sequential;
        params.threads = 1;
        let run = |params: &BackboneParams| {
            let mut l = OracleLearner::new(n, relevant.clone());
            let fit = run_backbone(&mut l, &(), params, &Budget::unlimited()).unwrap();
            (fit.backbone, fit.model, l.reduced_backbone)
        };
        let sequential = run(&params);
        params.execution = ExecutionPolicy::Parallel;
        params.threads = g.usize_in(0..6); // 0 = all available cores
        assert_eq!(sequential, run(&params), "threads={}", params.threads);
    });
}

#[test]
fn prop_construct_subproblems_invariants() {
    property("construct_subproblems invariants", 200, |g| {
        let pool = g.usize_in(1..100) + 50;
        let universe_n = g.usize_in(1..50);
        let universe = g.subset(pool, universe_n);
        let utilities: Vec<f64> = (0..pool).map(|_| g.f64_in(0.0..1.0)).collect();
        let m = g.usize_in(1..10);
        let size = g.usize_in(1..universe.len() + 1);
        let strategy = if g.bool_with(0.5) {
            SubproblemStrategy::UniformCoverage
        } else {
            SubproblemStrategy::UtilityWeighted
        };
        let sps = construct_subproblems(&universe, &utilities, m, size, strategy, g.rng());
        assert_eq!(sps.len(), m);
        for sp in &sps {
            assert_eq!(sp.len(), size);
            assert!(sp.windows(2).all(|w| w[0] < w[1]), "unsorted/dup");
            for e in sp {
                assert!(universe.contains(e), "entity outside universe");
            }
        }
        // Coverage property for the coverage strategy.
        if strategy == SubproblemStrategy::UniformCoverage && m * size >= universe.len() {
            let mut seen: Vec<usize> = sps.iter().flatten().copied().collect();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen, universe, "coverage violated");
        }
    });
}

#[test]
fn prop_sparse_regression_model_consistency() {
    use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};

    property("sparse-regression model invariants", 15, |g| {
        let n = g.usize_in(30..80);
        let p = g.usize_in(20..120);
        let k = g.usize_in(1..6).min(p);
        let data = generate(
            &SparseRegressionConfig {
                n,
                p,
                k,
                rho: g.f64_in(0.0..0.6),
                snr: g.f64_in(1.0..10.0),
            },
            g.rng(),
        );
        let mut bb = Backbone::sparse_regression()
            .alpha(g.f64_in(0.2..1.0))
            .beta(g.f64_in(0.2..1.0))
            .num_subproblems(g.usize_in(1..6))
            .max_nonzeros(k)
            .seed(g.usize_in(0..1000) as u64)
            .build()
            .unwrap();
        let model = bb.fit(&data.x, &data.y).unwrap().clone();
        // Support ≤ k, beta zero off-support.
        assert!(model.support.len() <= k);
        for (j, &b) in model.beta.iter().enumerate() {
            if model.support.contains(&j) {
                assert!(b != 0.0);
            } else {
                assert_eq!(b, 0.0, "beta[{j}] nonzero outside support");
            }
        }
        // Gap within the solver tolerance when optimal.
        if model.status == backbone_learn::solvers::SolveStatus::Optimal {
            assert!(model.gap <= bb.gap_tol + 1e-9);
        }
    });
}

#[test]
fn prop_clustering_labels_valid_and_pairs_respected() {
    use backbone_learn::data::blobs::{generate, BlobsConfig};

    property("clustering label invariants", 8, |g| {
        let n = g.usize_in(8..14);
        let k = g.usize_in(2..4);
        let data = generate(
            &BlobsConfig {
                n,
                p: 2,
                true_clusters: k,
                cluster_std: g.f64_in(0.2..0.8),
                center_box: 8.0,
                min_center_dist: 5.0,
            },
            g.rng(),
        );
        let mut bb = Backbone::clustering()
            .beta(g.f64_in(0.6..1.0))
            .num_subproblems(g.usize_in(1..4))
            .n_clusters(k)
            .seed(g.usize_in(0..1000) as u64)
            .build()
            .unwrap();
        let model = bb.fit_with_budget(&data.x, &Budget::seconds(30.0)).unwrap().clone();
        assert_eq!(model.labels.len(), n);
        let kk = model.labels.iter().max().unwrap() + 1;
        assert!(kk <= n);
        if model.status == backbone_learn::solvers::SolveStatus::Optimal {
            let clusters = model
                .labels
                .iter()
                .collect::<std::collections::BTreeSet<_>>()
                .len();
            assert!(clusters <= k, "{clusters} clusters with k={k}");
        }
        assert!(model.objective.is_finite());
    });
}

#[test]
fn prop_invalid_hyperparameters_error_instead_of_panicking() {
    property("invalid hyperparameters → typed BackboneError", 120, |g| {
        let which = g.usize_in(0..6);
        let err = match which {
            // α > 1, α ≤ 0, β = 0 / β > 1, M = 0, k = 0.
            0 => Backbone::sparse_regression()
                .alpha(1.0 + g.f64_in(0.001..10.0))
                .build()
                .map(|_| ())
                .unwrap_err(),
            1 => Backbone::sparse_regression()
                .alpha(-g.f64_in(0.0..5.0))
                .build()
                .map(|_| ())
                .unwrap_err(),
            2 => Backbone::sparse_logistic().beta(0.0).build().map(|_| ()).unwrap_err(),
            3 => Backbone::decision_tree()
                .beta(1.0 + g.f64_in(0.001..10.0))
                .build()
                .map(|_| ())
                .unwrap_err(),
            4 => Backbone::clustering()
                .n_clusters(2)
                .num_subproblems(0)
                .build()
                .map(|_| ())
                .unwrap_err(),
            _ => Backbone::sparse_regression()
                .max_nonzeros(0)
                .build()
                .map(|_| ())
                .unwrap_err(),
        };
        match which {
            0 | 1 => assert!(matches!(err, BackboneError::InvalidAlpha { .. }), "{err}"),
            2 | 3 => assert!(matches!(err, BackboneError::InvalidBeta { .. }), "{err}"),
            4 => assert!(matches!(err, BackboneError::ZeroSubproblems), "{err}"),
            _ => assert!(
                matches!(err, BackboneError::InvalidHyperparameter { .. }),
                "{err}"
            ),
        }
    });
}
