//! Integration tests of the unified estimator API.
//!
//! Covers the acceptance surface of the API redesign:
//! - **construction determinism** — identically-configured builders
//!   produce bit-identical fits (the seeds are inputs, not state);
//! - **typed validation** — invalid hyperparameters and malformed data
//!   return `BackboneError` from `build()`/`fit()` instead of panicking,
//!   including params hand-mutated after `build()`;
//! - **budget exhaustion** — a zero budget short-circuits the subproblem
//!   batch and is surfaced in `BackboneDiagnostics::budget_exhausted`;
//! - **diagnostics JSON** — `BackboneDiagnostics::to_json()` round-trips
//!   through the crate's `json` module (the `cli fit --out` payload).

use backbone_learn::backbone::sparse_regression::SupervisedData;
use backbone_learn::backbone::{Backbone, BackboneError, ExecutionPolicy, Fit, Predict};
use backbone_learn::data::{blobs, classification, sparse_regression};
use backbone_learn::json::Json;
use backbone_learn::linalg::Matrix;
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;

fn sr_data(seed: u64) -> sparse_regression::SparseRegressionData {
    sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 80, p: 120, k: 3, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(seed),
    )
}

fn cls_data(seed: u64) -> classification::ClassificationData {
    classification::generate(
        &classification::ClassificationConfig {
            n: 150,
            p: 25,
            k: 3,
            n_redundant: 0,
            n_clusters: 2,
            class_sep: 2.0,
            flip_y: 0.02,
        },
        &mut Rng::seed_from_u64(seed),
    )
}

// ---------------------------------------------------------------------------
// Construction determinism: two identically-configured builders agree
// ---------------------------------------------------------------------------

#[test]
fn identically_configured_builders_fit_identically() {
    let data = sr_data(1);
    let build = || {
        Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(3)
            .max_nonzeros(3)
            .seed(9)
            .build()
            .unwrap()
    };
    let mut a = build();
    let mut b = build();
    let m1 = a.fit(&data.x, &data.y).unwrap().clone();
    let m2 = b.fit(&data.x, &data.y).unwrap().clone();
    assert_eq!(m1.support, m2.support);
    assert_eq!(m1.beta, m2.beta);
    assert_eq!(m1.intercept, m2.intercept);
    let d1 = a.last_diagnostics.as_ref().unwrap();
    let d2 = b.last_diagnostics.as_ref().unwrap();
    assert_eq!(d1.screened_universe, d2.screened_universe);
    assert_eq!(d1.backbone_size, d2.backbone_size);
    assert_eq!(d1.iterations.len(), d2.iterations.len());
}

// ---------------------------------------------------------------------------
// The Fit/Predict trait pair drives all four learners uniformly
// ---------------------------------------------------------------------------

#[test]
fn fit_predict_traits_cover_all_four_learners() {
    fn fit_supervised<E>(est: &mut E, data: &SupervisedData) -> usize
    where
        E: Fit<Data = SupervisedData> + Predict<Output = Vec<f64>>,
    {
        est.try_fit(data, &Budget::unlimited()).unwrap();
        let preds = est.try_predict(&data.x).unwrap();
        assert_eq!(preds.len(), data.x.rows());
        est.diagnostics().unwrap().backbone_size
    }

    let reg = sr_data(5);
    let sup = SupervisedData { x: reg.x.clone(), y: reg.y.clone() };
    let mut sr = Backbone::sparse_regression().max_nonzeros(3).build().unwrap();
    assert!(fit_supervised(&mut sr, &sup) > 0);

    let cls = cls_data(6);
    let sup = SupervisedData { x: cls.x.clone(), y: cls.y.clone() };
    let mut lg = Backbone::sparse_logistic().max_nonzeros(2).build().unwrap();
    assert!(fit_supervised(&mut lg, &sup) > 0);
    let mut dt = Backbone::decision_tree().depth(2).build().unwrap();
    assert!(fit_supervised(&mut dt, &sup) > 0);

    let pts = blobs::generate(
        &blobs::BlobsConfig {
            n: 12,
            p: 2,
            true_clusters: 2,
            cluster_std: 0.4,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(7),
    );
    let mut cl = Backbone::clustering().n_clusters(2).build().unwrap();
    cl.try_fit(&pts.x, &Budget::seconds(60.0)).unwrap();
    let labels = cl.try_predict(&pts.x).unwrap();
    assert_eq!(labels.len(), 12);
    assert!(cl.diagnostics().unwrap().backbone_size > 0);
}

// ---------------------------------------------------------------------------
// Typed validation (no panics reachable from public inputs)
// ---------------------------------------------------------------------------

#[test]
fn invalid_hyperparameters_return_typed_errors_from_build() {
    assert!(matches!(
        Backbone::sparse_regression().beta(0.0).build(),
        Err(BackboneError::InvalidBeta { .. })
    ));
    assert!(matches!(
        Backbone::sparse_regression().alpha(1.5).build(),
        Err(BackboneError::InvalidAlpha { .. })
    ));
    assert!(matches!(
        Backbone::sparse_logistic().num_subproblems(0).build(),
        Err(BackboneError::ZeroSubproblems)
    ));
    assert!(matches!(
        Backbone::decision_tree().depth(0).build(),
        Err(BackboneError::InvalidHyperparameter { field: "depth", .. })
    ));
    assert!(matches!(
        Backbone::clustering().build(),
        Err(BackboneError::InvalidHyperparameter { field: "n_clusters", .. })
    ));
}

#[test]
fn hand_mutated_params_are_revalidated_at_fit() {
    // `params` is public: a user can corrupt a built estimator. The fit
    // pipeline re-validates, so this is a typed error, not a panic.
    let data = sr_data(8);
    let mut bad = Backbone::sparse_regression().max_nonzeros(3).build().unwrap();
    bad.params.alpha = 0.0;
    let err = bad.fit(&data.x, &data.y).unwrap_err();
    assert_eq!(err, BackboneError::InvalidAlpha { value: 0.0 });

    let mut bad = Backbone::clustering().n_clusters(2).build().unwrap();
    bad.params.beta = 2.0;
    let err = bad.fit(&Matrix::zeros(6, 2)).unwrap_err();
    assert_eq!(err, BackboneError::InvalidBeta { value: 2.0 });
}

#[test]
fn malformed_data_returns_typed_errors_from_fit() {
    let mut sr = Backbone::sparse_regression().build().unwrap();
    assert_eq!(
        sr.fit(&Matrix::zeros(4, 3), &[1.0, 2.0]).unwrap_err(),
        BackboneError::DimensionMismatch { x_rows: 4, y_len: 2 }
    );
    assert!(matches!(
        sr.fit(&Matrix::zeros(3, 0), &[1.0, 2.0, 3.0]).unwrap_err(),
        BackboneError::EmptyData { .. }
    ));
    // Zero rows (y empty too, so dims agree) must error, not panic.
    assert!(matches!(
        sr.fit(&Matrix::zeros(0, 3), &[]).unwrap_err(),
        BackboneError::EmptyData { .. }
    ));

    let mut lg = Backbone::sparse_logistic().build().unwrap();
    let x = Matrix::zeros(3, 2);
    assert_eq!(
        lg.fit(&x, &[0.0, 1.0, 0.5]).unwrap_err(),
        BackboneError::NonBinaryLabels { index: 2, value: 0.5 }
    );

    // The decision tree is also a binary classifier: same label contract.
    let mut dt = Backbone::decision_tree().build().unwrap();
    assert_eq!(
        dt.fit(&x, &[0.0, 1.0, 2.0]).unwrap_err(),
        BackboneError::NonBinaryLabels { index: 2, value: 2.0 }
    );
    assert!(matches!(
        dt.fit(&Matrix::zeros(0, 2), &[]).unwrap_err(),
        BackboneError::EmptyData { .. }
    ));

    let mut cl = Backbone::clustering().n_clusters(2).build().unwrap();
    assert!(matches!(
        cl.fit(&Matrix::zeros(1, 2)).unwrap_err(),
        BackboneError::EmptyData { .. }
    ));
}

#[test]
fn try_predict_reports_not_fitted_and_shape_mismatch() {
    let sr = Backbone::sparse_regression().build().unwrap();
    assert_eq!(sr.try_predict(&Matrix::zeros(2, 2)).unwrap_err(), BackboneError::NotFitted);

    let data = sr_data(9);
    let mut sr = Backbone::sparse_regression().max_nonzeros(3).build().unwrap();
    sr.fit(&data.x, &data.y).unwrap();
    // Wrong feature count.
    let err = sr.try_predict(&Matrix::zeros(5, 7)).unwrap_err();
    assert_eq!(err, BackboneError::ShapeMismatch { expected: 120, got: 7 });
}

// ---------------------------------------------------------------------------
// Budget exhaustion + execution policy
// ---------------------------------------------------------------------------

#[test]
fn zero_budget_short_circuits_and_reports_exhaustion() {
    let data = sr_data(10);
    let mut bb = Backbone::sparse_regression().max_nonzeros(3).build().unwrap();
    let model = bb.fit_with_budget(&data.x, &data.y, &Budget::seconds(0.0)).unwrap().clone();
    let d = bb.last_diagnostics.as_ref().unwrap();
    assert!(d.budget_exhausted, "exhaustion not surfaced: {d:?}");
    assert!(!d.converged);
    assert!(!d.iterations.is_empty());
    // A (degenerate) model is still returned.
    assert!(model.support.len() <= 3);
    assert!(model.objective.is_finite());
}

#[test]
fn parallel_policy_reproduces_sequential_fit() {
    let data = sr_data(11);
    let run = |policy: ExecutionPolicy| {
        let mut bb = Backbone::sparse_regression()
            .max_nonzeros(3)
            .execution(policy)
            .seed(3)
            .build()
            .unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let seq = run(ExecutionPolicy::Sequential);
    let par = run(ExecutionPolicy::Parallel);
    assert_eq!(seq.support, par.support);
    assert_eq!(seq.beta, par.beta);
}

// ---------------------------------------------------------------------------
// Diagnostics JSON (the `cli fit --out` payload)
// ---------------------------------------------------------------------------

#[test]
fn diagnostics_to_json_is_machine_readable() {
    let data = sr_data(12);
    let mut bb = Backbone::sparse_regression().max_nonzeros(3).build().unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    let d = bb.last_diagnostics.as_ref().unwrap();

    let parsed = Json::parse(&d.to_json().to_string_pretty()).unwrap();
    assert_eq!(
        parsed.get("screened_universe").and_then(Json::as_usize),
        Some(d.screened_universe)
    );
    assert_eq!(parsed.get("backbone_size").and_then(Json::as_usize), Some(d.backbone_size));
    assert_eq!(parsed.get("converged").and_then(Json::as_bool), Some(d.converged));
    assert_eq!(
        parsed.get("budget_exhausted").and_then(Json::as_bool),
        Some(d.budget_exhausted)
    );
    let iters = parsed.get("iterations").unwrap().as_array().unwrap();
    assert_eq!(iters.len(), d.iterations.len());
    for (js, it) in iters.iter().zip(&d.iterations) {
        assert_eq!(js.get("iteration").and_then(Json::as_usize), Some(it.iteration));
        assert_eq!(js.get("backbone_size").and_then(Json::as_usize), Some(it.backbone_size));
    }
}

// ---------------------------------------------------------------------------
// Tracing: opt-in span trees that account for the fit's wall time
// ---------------------------------------------------------------------------

#[test]
fn traced_fit_builds_a_trace_tree_that_accounts_for_wall_time() {
    let data = sr_data(21);
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(3)
        .seed(4)
        .trace(true)
        .build()
        .unwrap();
    let watch = std::time::Instant::now();
    bb.fit(&data.x, &data.y).unwrap();
    let wall = watch.elapsed().as_secs_f64();

    let d = bb.last_diagnostics.as_ref().unwrap();
    let trace = d.trace.as_ref().expect("trace requested but not recorded");
    assert_eq!(trace.name, "fit");
    assert!(trace.secs > 0.0 && trace.secs <= wall + 1e-6);

    let stages: Vec<&str> = trace.children.iter().map(|c| c.name.as_str()).collect();
    assert!(stages.contains(&"screen"), "stages: {stages:?}");
    assert!(stages.contains(&"iteration"), "stages: {stages:?}");
    assert!(stages.contains(&"reduced"), "stages: {stages:?}");
    let iteration =
        trace.children.iter().find(|c| c.name == "iteration").expect("iteration span");
    let inner: Vec<&str> = iteration.children.iter().map(|c| c.name.as_str()).collect();
    assert!(inner.contains(&"construct"), "iteration children: {inner:?}");
    assert!(inner.contains(&"subproblems"), "iteration children: {inner:?}");
    assert!(inner.contains(&"aggregate"), "iteration children: {inner:?}");

    // The stage spans cover the pipeline end to end: the root's direct
    // children sum to its wall time within 5% (plus a small absolute
    // slack so clock granularity on very fast fits can't flake this).
    let unattributed = trace.secs - trace.child_secs();
    assert!(unattributed >= -1e-9, "children exceed root: {unattributed}");
    assert!(
        unattributed <= (0.05 * trace.secs).max(0.005),
        "unattributed {unattributed:.6}s of root {:.6}s",
        trace.secs
    );

    // The tree rides along in the diagnostics JSON (cli fit --out).
    let doc = d.to_json();
    let parsed = Json::parse(&doc.to_string_compact()).unwrap();
    assert_eq!(
        parsed.get("trace").and_then(|t| t.get("name")).and_then(Json::as_str),
        Some("fit")
    );
}

#[test]
fn tracing_is_inert_when_disabled_and_never_perturbs_results() {
    let data = sr_data(22);
    let fit = |trace: bool| {
        let mut bb = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(3)
            .max_nonzeros(3)
            .seed(4)
            .trace(trace)
            .build()
            .unwrap();
        let model = bb.fit(&data.x, &data.y).unwrap().clone();
        let has_trace = bb.last_diagnostics.as_ref().unwrap().trace.is_some();
        let json = bb.last_diagnostics.as_ref().unwrap().to_json();
        (model, has_trace, json)
    };
    let (cold, traced_flag, _) = fit(true);
    let (plain, untraced_flag, untraced_json) = fit(false);
    assert!(traced_flag);
    assert!(!untraced_flag);
    // Untraced diagnostics carry no trace key at all.
    assert!(untraced_json.get("trace").is_none());
    // Tracing only reads clocks around stages — the fit itself is
    // bit-identical with and without it.
    assert_eq!(cold.support, plain.support);
    for (a, b) in cold.beta.iter().zip(&plain.beta) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(cold.objective.to_bits(), plain.objective.to_bits());
}
