//! Crash-safety and fault-injection acceptance suite (PR 9).
//!
//! Two families of tests share this binary on purpose:
//!
//! - **Corruption tolerance** — truncated, bit-flipped, and
//!   value-tampered `backbone-model/v1` / `backbone-warmstart-store/v1`
//!   artifacts must surface as *typed* errors at load (never a panic),
//!   checksum-less legacy artifacts must keep loading, and a failed
//!   overwrite must leave the previous artifact byte-identical on disk
//!   (the `atomic_write` contract).
//! - **Fault-plan behaviour + the chaos drill** (`--features
//!   fault-inject`) — the seeded schedule fires deterministically, and
//!   `serve --self-test --chaos` survives it with reconciled counters.
//!
//! They live in ONE binary because an installed fault plan is
//! process-global: a plan-installing test running concurrently with any
//! other test that touches a fire site (an `atomic_write`, a fit, a
//! serve accept) would leak injected faults into it. Inside this binary
//! every plan-installing or artifact-writing test holds
//! `fault::serial_guard()`; the chaos tests rely on `run_chaos` taking
//! the same guard internally (holding it around the call would
//! deadlock). The library test binary never installs a plan.

use backbone_learn::backbone::clustering::ClusteringModel;
use backbone_learn::backbone::decision_tree::BackboneTreeModel;
use backbone_learn::backbone::sparse_regression::SparseRegressionModel;
use backbone_learn::json::Json;
use backbone_learn::linalg::Matrix;
use backbone_learn::persist::{LoadedModel, ModelArtifact, PersistError, Provenance};
use backbone_learn::solvers::exact_tree::BinNode;
use backbone_learn::solvers::logistic::LogisticModel;
use backbone_learn::solvers::SolveStatus;
use backbone_learn::warmstart::{featurize, WarmStartError, WarmStartStore};

/// Unique scratch path for one save/load cycle.
fn scratch(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("backbone_corrupt_{}_{}.json", name, std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// With `fault-inject` compiled in, any test that writes artifacts must
/// serialize against tests that install fault plans (see module docs).
/// Without the feature this is a no-op — no plan can exist.
#[cfg(feature = "fault-inject")]
fn write_guard() -> std::sync::MutexGuard<'static, ()> {
    backbone_learn::fault::serial_guard()
}
#[cfg(not(feature = "fault-inject"))]
fn write_guard() {}

fn provenance(seed: u64) -> Provenance {
    Provenance {
        crate_version: "0.4.0".into(),
        seed,
        params: Json::parse("{}").unwrap(),
        config: Json::parse("{}").unwrap(),
        diagnostics: None,
    }
}

/// One small hand-built artifact per learner — corruption handling is a
/// wire-format property, so no fitting is needed.
fn artifacts() -> Vec<(&'static str, ModelArtifact)> {
    vec![
        (
            "sr",
            ModelArtifact {
                model: LoadedModel::SparseRegression(SparseRegressionModel {
                    beta: vec![0.0, 1.5, 0.0, -2.25, 0.0],
                    intercept: 0.5,
                    support: vec![1, 3],
                    objective: 3.5,
                    gap: 0.0,
                    status: SolveStatus::Optimal,
                }),
                provenance: provenance(7),
            },
        ),
        (
            "lg",
            ModelArtifact {
                model: LoadedModel::SparseLogistic(LogisticModel {
                    beta: vec![0.75, 0.0, -1.5],
                    intercept: -0.25,
                    support: vec![0, 2],
                    nll: 12.5,
                    status: SolveStatus::Optimal,
                }),
                provenance: provenance(3),
            },
        ),
        (
            "dt",
            ModelArtifact {
                model: LoadedModel::DecisionTree(BackboneTreeModel {
                    root: BinNode::Split {
                        feature: 0,
                        left: Box::new(BinNode::Leaf { prob: 0.25, n: 8 }),
                        right: Box::new(BinNode::Leaf { prob: 0.75, n: 4 }),
                    },
                    bin_map: vec![(2, 0.5), (5, -1.25)],
                    errors: 3,
                    status: SolveStatus::Optimal,
                    backbone_features: vec![2, 5],
                }),
                provenance: provenance(1),
            },
        ),
        (
            "cl",
            ModelArtifact {
                model: LoadedModel::Clustering(ClusteringModel {
                    labels: vec![0, 1, 1, 0, 2],
                    objective: 4.5,
                    gap: 0.0,
                    status: SolveStatus::Optimal,
                }),
                provenance: provenance(11),
            },
        ),
    ]
}

/// A small warm-start store with two real entries.
fn sample_store() -> WarmStartStore {
    let x = Matrix::from_rows(&[
        vec![1.0, 0.0, 2.0],
        vec![0.0, 1.0, -1.0],
        vec![2.0, -1.0, 0.5],
        vec![-1.0, 2.0, 1.5],
    ]);
    let y = vec![2.0, -1.0, 4.0, -2.0];
    let mut store = WarmStartStore::new(8);
    store.record(&featurize(&x, &y, 2), &[0, 2], &[1.9, 0.1], 0.05, 1.25, 0.5);
    let y2 = vec![1.0, 0.0, 3.0, -1.0];
    store.record(&featurize(&x, &y2, 2), &[0], &[1.5], 0.0, 2.5, 0.5);
    store
}

// ---------------------------------------------------------------------------
// Corruption tolerance: models
// ---------------------------------------------------------------------------

/// Truncating a saved artifact anywhere must yield a typed error at
/// load for every learner — never a panic, never a half-parsed model.
#[test]
fn truncated_artifacts_load_as_typed_errors_for_every_learner() {
    let _g = write_guard();
    for (name, artifact) in artifacts() {
        let path = scratch(&format!("trunc_{name}"));
        artifact.save(&path).unwrap();
        let full = std::fs::read_to_string(&path).unwrap();
        for cut in [full.len() / 4, full.len() / 2, 3 * full.len() / 4, full.len() - 2] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let outcome = std::panic::catch_unwind(|| ModelArtifact::load(&path));
            let loaded = outcome.unwrap_or_else(|_| {
                panic!("{name}: load PANICKED on artifact truncated at {cut} bytes")
            });
            assert!(
                loaded.is_err(),
                "{name}: truncation at {cut} bytes loaded successfully"
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Flipping a single bit mid-file must also come back as a typed error
/// (whether it lands as a parse failure or a checksum mismatch).
#[test]
fn bit_flipped_artifacts_load_as_typed_errors() {
    let _g = write_guard();
    for (name, artifact) in artifacts() {
        let path = scratch(&format!("flip_{name}"));
        artifact.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let outcome = std::panic::catch_unwind(|| ModelArtifact::load(&path));
        let loaded = outcome
            .unwrap_or_else(|_| panic!("{name}: load PANICKED on a bit-flipped artifact"));
        assert!(loaded.is_err(), "{name}: bit flip at byte {mid} loaded successfully");
        std::fs::remove_file(&path).ok();
    }
}

/// Valid JSON whose content no longer matches the embedded checksum is
/// the targeted corruption case: it must be the *checksum* error, with
/// both digests reported, before any semantic validation runs.
#[test]
fn value_tampering_is_a_typed_checksum_mismatch() {
    let _g = write_guard();
    let (_, artifact) = artifacts().swap_remove(0);
    let path = scratch("tamper");
    artifact.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let Json::Object(mut map) = Json::parse(&text).unwrap() else {
        panic!("artifact is not a JSON object")
    };
    assert!(map.contains_key("checksum"), "save() must embed a checksum");
    // Any content change invalidates the checksum computed over the
    // rest of the document.
    map.insert("tampered".to_string(), Json::Bool(true));
    std::fs::write(&path, Json::Object(map).to_string_pretty()).unwrap();
    let err = ModelArtifact::load(&path).unwrap_err();
    match err {
        PersistError::Checksum { stored, computed } => {
            assert!(stored.starts_with("fnv1a64:"), "stored digest format: {stored}");
            assert!(computed.starts_with("fnv1a64:"), "computed digest format: {computed}");
            assert_ne!(stored, computed);
        }
        other => panic!("expected PersistError::Checksum, got: {other}"),
    }
    std::fs::remove_file(&path).ok();
}

/// Pre-PR-9 artifacts carry no checksum; they must keep loading.
#[test]
fn checksum_less_legacy_artifact_still_loads() {
    let _g = write_guard();
    let (_, artifact) = artifacts().swap_remove(0);
    let path = scratch("legacy");
    // `to_json()` is the legacy wire format — no checksum key.
    let doc = artifact.to_json();
    assert!(doc.get("checksum").is_none(), "to_json() must stay checksum-free");
    std::fs::write(&path, doc.to_string_pretty()).unwrap();
    let loaded = ModelArtifact::load(&path).unwrap();
    assert_eq!(loaded.learner(), artifact.learner());
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Corruption tolerance: warm-start store
// ---------------------------------------------------------------------------

#[test]
fn corrupt_warm_store_is_a_typed_error_and_degrades_to_empty() {
    let _g = write_guard();
    let store = sample_store();
    let path = scratch("warm");
    store.save(&path).unwrap();
    let full = std::fs::read_to_string(&path).unwrap();

    // Truncation → typed error, and load_or_empty degrades to an empty
    // store while still reporting what went wrong.
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    let outcome = std::panic::catch_unwind(|| WarmStartStore::load(&path));
    assert!(
        outcome.expect("load PANICKED on a truncated store").is_err(),
        "truncated store loaded successfully"
    );
    let (degraded, err) = WarmStartStore::load_or_empty(&path, 8);
    assert!(degraded.is_empty(), "degraded store must start cold");
    assert!(err.is_some(), "degradation must report the typed error");

    // Value tampering → specifically the checksum error.
    let Json::Object(mut map) = Json::parse(&full).unwrap() else {
        panic!("store is not a JSON object")
    };
    assert!(map.contains_key("checksum"), "save() must embed a checksum");
    map.insert("tampered".to_string(), Json::Bool(true));
    std::fs::write(&path, Json::Object(map).to_string_pretty()).unwrap();
    match WarmStartStore::load(&path).unwrap_err() {
        WarmStartError::Checksum { stored, computed } => assert_ne!(stored, computed),
        other => panic!("expected WarmStartError::Checksum, got: {other}"),
    }

    // Legacy checksum-less document still loads with its entries.
    std::fs::write(&path, store.to_json().to_string_pretty()).unwrap();
    let legacy = WarmStartStore::load(&path).unwrap();
    assert_eq!(legacy.len(), store.len());
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Fault plan behaviour (feature-gated)
// ---------------------------------------------------------------------------

#[cfg(feature = "fault-inject")]
mod fault_plan {
    use backbone_learn::fault::{
        clear, fire, fired_count, install, serial_guard, FaultPlan, FaultPoint,
    };

    #[test]
    fn plan_fires_exactly_at_scheduled_indices() {
        let _serial = serial_guard();
        install(FaultPlan::new().with_fires(FaultPoint::WriteFail, &[0, 2]));
        let observed: Vec<bool> = (0..4).map(|_| fire(FaultPoint::WriteFail)).collect();
        assert_eq!(observed, vec![true, false, true, false]);
        assert_eq!(fired_count(FaultPoint::WriteFail), 2);
        // Other points are untouched.
        assert!(!fire(FaultPoint::WorkerPanic));
        assert_eq!(fired_count(FaultPoint::WorkerPanic), 0);
        clear();
        assert!(!fire(FaultPoint::WriteFail));
    }

    #[test]
    fn seeded_plans_are_deterministic_and_gap_spaced() {
        let _serial = serial_guard();
        let a = FaultPlan::seeded(7, 3, 16);
        let b = FaultPlan::seeded(7, 3, 16);
        for point in FaultPoint::ALL {
            assert_eq!(a.planned(point), 3);
            assert_eq!(b.planned(point), 3);
        }
        // Same seed → same schedule, observable through fire().
        install(a);
        let run_a: Vec<bool> = (0..80).map(|_| fire(FaultPoint::WorkerPanic)).collect();
        install(b);
        let run_b: Vec<bool> = (0..80).map(|_| fire(FaultPoint::WorkerPanic)).collect();
        assert_eq!(run_a, run_b);
        // Gap spacing: no two consecutive fires closer than the gap.
        let hits: Vec<usize> =
            run_a.iter().enumerate().filter(|(_, &h)| h).map(|(i, _)| i).collect();
        for w in hits.windows(2) {
            assert!(w[1] - w[0] >= 16, "fires too close: {hits:?}");
        }
        clear();
    }

    #[test]
    fn no_plan_means_no_fires() {
        let _serial = serial_guard();
        clear();
        for point in FaultPoint::ALL {
            assert!(!fire(point));
        }
    }
}

/// A failed overwrite must leave the previous artifact byte-identical:
/// the injected I/O failure hits the temp file, never the target.
#[cfg(feature = "fault-inject")]
#[test]
fn crash_during_save_leaves_prior_artifact_intact() {
    use backbone_learn::fault::{clear, install, FaultPlan, FaultPoint};
    let _g = write_guard();
    let mut all = artifacts();
    let (_, replacement) = all.swap_remove(1);
    let (_, original) = all.swap_remove(0);
    let path = scratch("crash_save");
    original.save(&path).unwrap();
    let before = std::fs::read(&path).unwrap();

    install(FaultPlan::new().with_fires(FaultPoint::WriteFail, &[0]));
    let err = replacement.save(&path).unwrap_err();
    clear();
    assert!(
        matches!(err, PersistError::Io { .. }),
        "injected write failure must surface as a typed I/O error, got: {err}"
    );

    let after = std::fs::read(&path).unwrap();
    assert_eq!(before, after, "failed overwrite mutated the previous artifact");
    let survivor = ModelArtifact::load(&path).unwrap();
    assert_eq!(survivor.learner(), original.learner());
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// The chaos drill end to end (feature-gated)
// ---------------------------------------------------------------------------

#[cfg(feature = "fault-inject")]
mod chaos {
    use backbone_learn::backbone::sparse_regression::SparseRegressionModel;
    use backbone_learn::json::Json;
    use backbone_learn::persist::LoadedModel;
    use backbone_learn::serve::selftest::{run_self_test, SelfTestConfig};
    use backbone_learn::solvers::SolveStatus;

    fn toy_model() -> LoadedModel {
        LoadedModel::SparseRegression(SparseRegressionModel {
            beta: vec![1.0, -2.0, 0.5],
            intercept: 0.25,
            support: vec![0, 1, 2],
            objective: 1.0,
            gap: 0.0,
            status: SolveStatus::Optimal,
        })
    }

    /// The whole drill on a loopback server. Deliberately does NOT hold
    /// `fault::serial_guard()` — `run_chaos` takes it internally, which
    /// is what serializes it against every other test in this binary.
    #[test]
    fn chaos_drill_survives_and_reconciles() {
        let report = run_self_test(
            toy_model(),
            &SelfTestConfig {
                requests: 48,
                connections: 3,
                batch_rows: 4,
                threads: 2,
                chaos: true,
                chaos_seed: 7,
                ..SelfTestConfig::quick()
            },
        )
        .unwrap();
        let chaos = report.chaos.as_ref().expect("chaos section present");
        assert!(chaos.server_alive, "server died during the drill");
        assert!(chaos.store_intact, "warm store corrupt after injected write failures");
        assert_eq!(
            chaos.unstructured_errors, 0,
            "an error response was not structured JSON"
        );
        assert_eq!(chaos.fit_io_failures, 0, "a fit was lost even after retries");
        assert!(
            chaos.counters_reconciled,
            "counters did not reconcile: {:?}",
            chaos.mismatches
        );
        assert_eq!(chaos.fit_timeouts, 2, "both deadline probes must 503");
        assert_eq!(
            chaos.fit_panics, chaos.injected_worker_panics,
            "every fired worker panic must surface as exactly one 500"
        );
        assert_eq!(
            report.keep_alive.failed, 0,
            "predict slots must all succeed after retries"
        );
        assert!(report.passed(), "chaos report must pass its own gate");

        let doc = report.to_json();
        let cj = doc.get("chaos").expect("chaos JSON section");
        assert_eq!(cj.get("ok").and_then(Json::as_bool), Some(true));
        assert!(cj.get("injected").and_then(|i| i.get("worker_panics")).is_some());
        assert_eq!(doc.get("passed").and_then(Json::as_bool), Some(true));
    }

    /// Same seed → same injected solver/write fault sequence → same
    /// chaos outcome counts. (Connection-level faults depend on socket
    /// interleaving and are deliberately not compared.)
    #[test]
    fn chaos_drill_is_deterministic_for_a_seed() {
        let cfg = SelfTestConfig {
            requests: 24,
            connections: 2,
            batch_rows: 4,
            threads: 1,
            chaos: true,
            chaos_seed: 11,
            ..SelfTestConfig::quick()
        };
        let a = run_self_test(toy_model(), &cfg).unwrap();
        let b = run_self_test(toy_model(), &cfg).unwrap();
        let (ca, cb) = (a.chaos.unwrap(), b.chaos.unwrap());
        assert_eq!(ca.injected_worker_panics, cb.injected_worker_panics);
        assert_eq!(ca.fit_panics, cb.fit_panics);
        assert_eq!(ca.fit_ok, cb.fit_ok);
        assert_eq!(ca.fit_timeouts, cb.fit_timeouts);
    }
}
