//! Cross-module integration: full backbone runs on generated data, with
//! the paper's qualitative claims asserted end to end (phase-1 speedup
//! structure, exact-phase optimality, heuristic-vs-backbone ordering).

use backbone_learn::backbone::Backbone;
use backbone_learn::data::blobs;
use backbone_learn::data::classification;
use backbone_learn::data::sparse_regression;
use backbone_learn::metrics::{auc, r2_score, silhouette_score, support_recovery};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cd::{elastic_net_path, ElasticNetConfig};
use backbone_learn::solvers::kmeans::{kmeans_fit, KMeansConfig};
use backbone_learn::solvers::SolveStatus;
use backbone_learn::util::Budget;

#[test]
fn sparse_regression_backbone_beats_lasso_on_support_recovery() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig {
            n: 150,
            p: 600,
            k: 5,
            rho: 0.3,
            snr: 5.0,
        },
        &mut Rng::seed_from_u64(42),
    );

    // Lasso baseline (full path, best in-sample).
    let path = elastic_net_path(&data.x, &data.y, &ElasticNetConfig::default());
    let lasso = path.select_best(&data.x, &data.y);
    let lasso_rec = support_recovery(&lasso.support(), &data.support_true);

    // Backbone.
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .max_nonzeros(5)
        .build()
        .unwrap();
    let model = bb.fit(&data.x, &data.y).unwrap().clone();
    let bb_rec = support_recovery(&model.support, &data.support_true);

    assert!(
        bb_rec.f1 >= lasso_rec.f1,
        "backbone F1 {} < lasso F1 {}",
        bb_rec.f1,
        lasso_rec.f1
    );
    assert!(bb_rec.f1 >= 0.8, "backbone F1 too low: {}", bb_rec.f1);
    // Exact phase solved a ≤ 50-feature problem, not 600.
    let d = bb.last_diagnostics.as_ref().unwrap();
    assert!(d.backbone_size <= 50);
    assert_eq!(model.status, SolveStatus::Optimal);
}

#[test]
fn decision_tree_backbone_competitive_with_cart_on_test_set() {
    let mut rng = Rng::seed_from_u64(7);
    let data = classification::generate(
        &classification::ClassificationConfig {
            n: 450,
            p: 30,
            k: 4,
            n_redundant: 3,
            n_clusters: 4,
            class_sep: 1.8,
            flip_y: 0.03,
        },
        &mut rng,
    );
    let split = backbone_learn::data::train_test_split(&data.x, &data.y, 1.0 / 3.0, &mut rng);

    let cart = backbone_learn::solvers::cart::cart_fit(
        &split.x_train,
        &split.y_train,
        &backbone_learn::solvers::cart::CartConfig { max_depth: 2, ..Default::default() },
    );
    let cart_auc = auc(&split.y_test, &cart.predict_proba(&split.x_test));

    let mut bb = Backbone::decision_tree()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(5)
        .depth(2)
        .bins(3) // finer thresholds: CART picks optimal cut points, the
        //          exact tree only sees the quantile grid
        .build()
        .unwrap();
    bb.fit(&split.x_train, &split.y_train).unwrap();
    let bb_auc = auc(&split.y_test, &bb.predict_proba(&split.x_test));

    assert!(
        bb_auc >= cart_auc - 0.05,
        "backbone AUC {bb_auc:.3} much worse than CART {cart_auc:.3}"
    );
    assert!(bb_auc > 0.6, "bb_auc={bb_auc}");
}

#[test]
fn clustering_backbone_at_least_as_good_as_kmeans_silhouette() {
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 14,
            p: 2,
            true_clusters: 2,
            cluster_std: 0.9,
            center_box: 8.0,
            min_center_dist: 6.0,
        },
        &mut Rng::seed_from_u64(3),
    );
    let target_k = 4; // ambiguity: more than the true 2

    let km = kmeans_fit(
        &data.x,
        &KMeansConfig { k: target_k, ..Default::default() },
        &mut Rng::seed_from_u64(5),
    );
    let km_sil = silhouette_score(&data.x, &km.labels);

    let mut bb = Backbone::clustering()
        .beta(1.0)
        .num_subproblems(3)
        .n_clusters(target_k)
        .build()
        .unwrap();
    let model = bb.fit_with_budget(&data.x, &Budget::seconds(60.0)).unwrap().clone();
    let bb_sil = silhouette_score(&data.x, &model.labels);

    assert!(
        bb_sil >= km_sil - 1e-9,
        "backbone silhouette {bb_sil:.4} < kmeans {km_sil:.4}"
    );
}

#[test]
fn backbone_phase_timings_are_recorded_and_positive() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 80, p: 200, k: 3, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(9),
    );
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(3)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    let d = bb.last_diagnostics.as_ref().unwrap();
    assert!(d.phase1_secs >= 0.0);
    assert!(d.phase2_secs >= 0.0);
    assert!(!d.iterations.is_empty());
    assert_eq!(
        d.iterations.first().unwrap().universe_size,
        d.screened_universe
    );
}

#[test]
fn budget_propagates_to_exact_phase() {
    // Zero budget: the exact phase must still return (TimedOut incumbent).
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 100, p: 300, k: 5, rho: 0.4, snr: 2.0 },
        &mut Rng::seed_from_u64(10),
    );
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(5)
        .build()
        .unwrap();
    let model = bb.fit_with_budget(&data.x, &data.y, &Budget::seconds(0.0)).unwrap();
    assert!(matches!(model.status, SolveStatus::TimedOut | SolveStatus::Optimal));
    assert!(model.support.len() <= 5);
    let r2 = r2_score(&data.y, &model.predict(&data.x));
    assert!(r2.is_finite());
}

#[test]
fn grid_cells_match_table1_row_shape() {
    // Tiny end-to-end run of the harness itself (1 rep): row structure,
    // method names, and the qualitative ordering BbLearn ≥ GLMNet.
    use backbone_learn::bench_support::run_sparse_regression_block;
    use backbone_learn::config::{ExperimentConfig, Problem};
    let mut cfg = ExperimentConfig::quick_defaults(Problem::SparseRegression);
    cfg.n = 100;
    cfg.p = 200;
    cfg.k = 3;
    cfg.repetitions = 1;
    cfg.budget_secs = 20.0;
    cfg.grid.truncate(2);
    let rows = run_sparse_regression_block(&cfg).unwrap();
    assert_eq!(rows.len(), 4);
    let glmnet = rows.iter().find(|r| r.method == "GLMNet").unwrap();
    let best_bb = rows
        .iter()
        .filter(|r| r.method == "BbLearn")
        .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
        .unwrap();
    assert!(
        best_bb.accuracy >= glmnet.accuracy - 0.05,
        "BbLearn {:.3} ≪ GLMNet {:.3}",
        best_bb.accuracy,
        glmnet.accuracy
    );
    for r in &rows {
        assert!(r.time_secs >= 0.0);
        if r.method == "BbLearn" {
            assert!(r.backbone_size.is_some());
            assert!(r.m.is_some() && r.alpha.is_some() && r.beta.is_some());
        }
    }
}
