//! Integration tests of the learning-to-solve warm-start subsystem:
//! warm fits must stay bit-reproducible across thread counts (the warm
//! start is an input, not hidden state), the store's LRU eviction must
//! be deterministic under sequence replay, a corrupt or missing store
//! must degrade gracefully to a cold fit with a typed error, and the
//! `backbone-warmstart-store/v1` wire format is byte-pinned against a
//! golden fixture.

use backbone_learn::backbone::Backbone;
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::Matrix;
use backbone_learn::prop::{property, Gen};
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;
use backbone_learn::warmstart::{
    featurize, suggested_alpha, InstanceFeatures, WarmStartError, WarmStartStore, FEATURE_LEN,
};

fn temp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("backbone_warmstart_{}_{}.json", name, std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn assert_bits_eq(a: &[f64], b: &[f64], context: &str) {
    assert_eq!(a.len(), b.len(), "{context}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{context}[{i}]: {x} vs {y}");
    }
}

/// Same cache state + same instance ⇒ the suggested warm start is
/// bit-identical, and the warm fit is bit-identical across the inline
/// sequential schedule (`threads(1)`) and the all-cores scheduler
/// (`threads(0)`).
#[test]
fn warm_fit_is_bit_identical_across_thread_counts() {
    let cfg = SparseRegressionConfig { n: 60, p: 120, k: 3, rho: 0.1, snr: 5.0 };
    let mut rng = Rng::seed_from_u64(7);
    let data = generate(&cfg, &mut rng);
    let budget = Budget::seconds(30.0);
    let mut cold = Backbone::sparse_regression()
        .alpha(0.2)
        .beta(0.5)
        .num_subproblems(4)
        .max_nonzeros(3)
        .threads(1)
        .seed(7)
        .build()
        .unwrap();
    let cold_model = cold.fit_with_budget(&data.x, &data.y, &budget).unwrap().clone();
    let features = featurize(&data.x, &data.y, 3);
    let mut store = WarmStartStore::new(8);
    let coeffs: Vec<f64> = cold_model.support.iter().map(|&j| cold_model.beta[j]).collect();
    store.record(
        &features,
        &cold_model.support,
        &coeffs,
        cold_model.intercept,
        cold_model.objective,
        0.2,
    );

    // A fresh instance from the same family gets a neighbor hit.
    let data2 = generate(&cfg, &mut rng);
    let f2 = featurize(&data2.x, &data2.y, 3);
    let fit = |threads: usize, store: &mut WarmStartStore| {
        let w = store.suggest(&f2).expect("neighbor hit");
        assert!(!w.exact, "different data must not be an exact hit");
        let mut bb = Backbone::sparse_regression()
            .alpha(suggested_alpha(120, 3))
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(3)
            .threads(threads)
            .seed(7)
            .warm_start(w.beta)
            .build()
            .unwrap();
        bb.fit_with_budget(&data2.x, &data2.y, &budget).unwrap().clone()
    };
    // Clone the store per run so both thread counts see the same state.
    let m1 = fit(1, &mut store.clone());
    let m0 = fit(0, &mut store.clone());
    assert_bits_eq(&m1.beta, &m0.beta, "warm beta across thread counts");
    assert_eq!(m1.support, m0.support);
    assert_eq!(m1.intercept.to_bits(), m0.intercept.to_bits());
    assert_eq!(m1.objective.to_bits(), m0.objective.to_bits());
}

/// Replaying the same record/suggest sequence reproduces the same store
/// byte-for-byte — eviction and LRU updates are driven by the logical
/// tick, never wall clock.
#[test]
fn eviction_sequence_replay_is_deterministic() {
    let run = || {
        let mut store = WarmStartStore::new(3);
        for i in 0..10u64 {
            let f = InstanceFeatures {
                p: 5,
                values: (0..FEATURE_LEN).map(|j| (i as f64) * 3.0 + j as f64).collect(),
            };
            store.record(&f, &[i as usize % 5], &[1.0 + i as f64], 0.0, i as f64, 0.5);
            if i % 3 == 0 {
                let probe = InstanceFeatures {
                    p: 5,
                    values: (0..FEATURE_LEN).map(|j| j as f64).collect(),
                };
                let _ = store.suggest(&probe);
            }
        }
        assert_eq!(store.len(), 3, "capacity bound respected");
        store.to_json().to_string_pretty()
    };
    assert_eq!(run(), run());
}

/// A corrupt store surfaces a typed error but still yields an empty
/// store, so the caller degrades to a cold fit; a missing file is a
/// fresh cache, not an error. Cold fits stay bit-reproducible.
#[test]
fn corrupt_store_degrades_to_cold_fit_with_typed_error() {
    let path = temp_path("corrupt");
    std::fs::write(&path, "{ this is not json !").unwrap();
    let (store, err) = WarmStartStore::load_or_empty(&path, 8);
    assert!(store.is_empty());
    assert!(matches!(err, Some(WarmStartError::Parse { .. })), "got {err:?}");

    std::fs::write(&path, r#"{"schema": "backbone-model/v1"}"#).unwrap();
    let (store2, err2) = WarmStartStore::load_or_empty(&path, 8);
    assert!(store2.is_empty());
    assert!(matches!(err2, Some(WarmStartError::Schema { .. })), "got {err2:?}");

    std::fs::remove_file(&path).unwrap();
    let (store3, err3) = WarmStartStore::load_or_empty(&path, 8);
    assert!(store3.is_empty() && err3.is_none());

    // The degraded (empty) store yields no suggestion, and the fit that
    // proceeds without one is the ordinary, reproducible cold fit.
    let mut rng = Rng::seed_from_u64(3);
    let data =
        generate(&SparseRegressionConfig { n: 40, p: 60, k: 2, rho: 0.1, snr: 5.0 }, &mut rng);
    let mut empty = store;
    assert!(empty.suggest(&featurize(&data.x, &data.y, 2)).is_none());
    let budget = Budget::seconds(30.0);
    let fit = || {
        Backbone::sparse_regression()
            .alpha(0.3)
            .beta(0.5)
            .num_subproblems(3)
            .max_nonzeros(2)
            .threads(1)
            .seed(3)
            .build()
            .unwrap()
            .fit_with_budget(&data.x, &data.y, &budget)
            .unwrap()
            .clone()
    };
    let a = fit();
    let b = fit();
    assert_bits_eq(&a.beta, &b.beta, "cold fit determinism");
    assert_eq!(a.support, b.support);
}

/// The `backbone-warmstart-store/v1` wire format is byte-pinned: this
/// exact operation sequence must serialize to the committed fixture,
/// and the fixture must parse back and reserialize byte-identically.
#[test]
fn store_wire_format_matches_golden_fixture() {
    let mut store = WarmStartStore::new(4);
    let f1 = InstanceFeatures {
        p: 6,
        values: vec![4.0, 6.0, 2.0, 1.5, 1.0, 2.0, 0.5, 0.25, 0.0, 1.0, 0.75, 1.25],
    };
    store.record(&f1, &[1, 4], &[0.5, -2.0], 0.25, 3.5, 0.5);
    let f2 = InstanceFeatures {
        p: 6,
        values: vec![4.0, 6.0, 2.0, 1.75, 1.25, 2.25, 0.5, 0.25, 0.5, 1.5, 0.625, 1.125],
    };
    store.record(&f2, &[0, 3], &[1.5, 0.75], -0.5, 2.25, 0.25);

    let golden = include_str!("fixtures/warmstart_store_v1.json");
    assert_eq!(store.to_json().to_string_pretty(), golden);
    let back = WarmStartStore::parse(golden).unwrap();
    assert_eq!(back, store);
    assert_eq!(back.to_json().to_string_pretty(), golden);
}

/// Featurization is total and fixed-length on random instances, survives
/// the JSON round trip bit-exactly, and a repeat submission of the same
/// instance is always an exact (distance-zero) hit.
#[test]
fn prop_featurize_round_trips_through_the_store() {
    property("warmstart_featurize_roundtrip", 40, |g: &mut Gen| {
        let n = g.usize_in(2..10);
        let p = g.usize_in(1..12);
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, g.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let k = g.usize_in(1..(p + 1));
        let f = featurize(&x, &y, k);
        assert_eq!(f.values.len(), FEATURE_LEN);
        assert_eq!(f.p, p);
        assert!(f.values.iter().all(|v| v.is_finite()), "features finite: {:?}", f.values);

        let mut store = WarmStartStore::new(4);
        let support: Vec<usize> = (0..k.min(p)).collect();
        let coeffs: Vec<f64> = support.iter().map(|_| g.normal()).collect();
        store.record(&f, &support, &coeffs, g.normal(), g.normal().abs(), 0.5);
        let text = store.to_json().to_string_pretty();
        let mut back = WarmStartStore::parse(&text).unwrap();
        assert_bits_eq(&back.entries()[0].features, &f.values, "features round trip");
        assert_bits_eq(&back.entries()[0].coefficients, &coeffs, "coefficients round trip");
        let w = back.suggest(&f).expect("hit");
        assert!(w.exact);
        assert_eq!(w.distance, 0.0);
        assert_eq!(w.support, support);
    });
}
