//! Determinism acceptance suite for `ExecutionPolicy::Parallel`: every
//! shipped learner, fitted through the real threaded scheduler with 1, 2,
//! and 4 workers, must produce a **bit-identical** model to the
//! sequential schedule for the same seed — coefficients, supports, tree
//! structure, labels, objectives, everything. This is the contract that
//! makes `--threads N` a pure wall-clock knob.

use backbone_learn::backbone::{Backbone, ExecutionPolicy};
use backbone_learn::data::{blobs, classification, sparse_regression};
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

#[test]
fn sparse_regression_parallel_fits_are_bit_identical() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig {
            n: 100,
            p: 200,
            k: 4,
            rho: 0.2,
            snr: 5.0,
        },
        &mut Rng::seed_from_u64(21),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(5)
            .max_nonzeros(4)
            .seed(7)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        let model = bb.fit(&data.x, &data.y).unwrap().clone();
        let backbone_size = bb.last_diagnostics.as_ref().unwrap().backbone_size;
        (model, backbone_size)
    };
    let (seq, seq_backbone) = fit(None);
    for threads in THREAD_COUNTS {
        let (par, par_backbone) = fit(Some(threads));
        assert_eq!(seq.beta, par.beta, "threads={threads}");
        assert_eq!(seq.intercept, par.intercept, "threads={threads}");
        assert_eq!(seq.support, par.support, "threads={threads}");
        assert_eq!(seq.objective, par.objective, "threads={threads}");
        assert_eq!(seq_backbone, par_backbone, "threads={threads}");
    }
}

#[test]
fn sparse_logistic_parallel_fits_are_bit_identical() {
    let data = classification::generate(
        &classification::ClassificationConfig {
            n: 200,
            p: 40,
            k: 3,
            n_redundant: 0,
            n_clusters: 2,
            class_sep: 2.0,
            flip_y: 0.02,
        },
        &mut Rng::seed_from_u64(22),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::sparse_logistic()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(3)
            .seed(5)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.beta, par.beta, "threads={threads}");
        assert_eq!(seq.intercept, par.intercept, "threads={threads}");
        assert_eq!(seq.support, par.support, "threads={threads}");
        assert_eq!(seq.nll, par.nll, "threads={threads}");
    }
}

#[test]
fn decision_tree_parallel_fits_are_bit_identical() {
    let data = classification::generate(
        &classification::ClassificationConfig {
            n: 250,
            p: 30,
            k: 4,
            n_redundant: 2,
            n_clusters: 4,
            class_sep: 1.8,
            flip_y: 0.03,
        },
        &mut Rng::seed_from_u64(23),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::decision_tree()
            .alpha(0.6)
            .beta(0.5)
            .num_subproblems(4)
            .depth(2)
            .seed(3)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.root, par.root, "threads={threads}");
        assert_eq!(seq.bin_map, par.bin_map, "threads={threads}");
        assert_eq!(seq.errors, par.errors, "threads={threads}");
        assert_eq!(seq.backbone_features, par.backbone_features, "threads={threads}");
    }
}

#[test]
fn clustering_parallel_fits_are_bit_identical() {
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 14,
            p: 2,
            true_clusters: 3,
            cluster_std: 0.4,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(24),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::clustering()
            .beta(0.9)
            .num_subproblems(4)
            .n_clusters(3)
            .seed(9)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit_with_budget(&data.x, &Budget::seconds(120.0)).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.labels, par.labels, "threads={threads}");
        assert_eq!(seq.objective, par.objective, "threads={threads}");
    }
}

#[test]
fn diagnostics_report_the_worker_count() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 60, p: 100, k: 3, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(25),
    );
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(4)
        .max_nonzeros(3)
        .threads(2)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().threads_used, 2);
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().subproblems_skipped, 0);
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(4)
        .max_nonzeros(3)
        .execution(ExecutionPolicy::Sequential)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().threads_used, 1);
}
