//! Determinism acceptance suite for `ExecutionPolicy::Parallel`: every
//! shipped learner, fitted through the real threaded scheduler with 1, 2,
//! and 4 workers, must produce a **bit-identical** model to the
//! sequential schedule for the same seed — coefficients, supports, tree
//! structure, labels, objectives, everything. This is the contract that
//! makes `--threads N` a pure wall-clock knob.

use backbone_learn::backbone::{Backbone, ExecutionPolicy};
use backbone_learn::data::{blobs, classification, sparse_regression};
use backbone_learn::linalg::{set_backend, BackendChoice};
use backbone_learn::rng::Rng;
use backbone_learn::util::Budget;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// The compute-backend axis of the determinism contract (PR-8): every
/// variant must produce fits bit-identical to `scalar` at every thread
/// count of this suite. On hardware without AVX2 `Simd`/`Auto` resolve to
/// scalar and the comparisons are trivially exact, so the suite still
/// passes on non-AVX2 targets.
const BACKENDS: [BackendChoice; 3] =
    [BackendChoice::Scalar, BackendChoice::Simd, BackendChoice::Auto];

#[test]
fn sparse_regression_parallel_fits_are_bit_identical() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig {
            n: 100,
            p: 200,
            k: 4,
            rho: 0.2,
            snr: 5.0,
        },
        &mut Rng::seed_from_u64(21),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(5)
            .max_nonzeros(4)
            .seed(7)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        let model = bb.fit(&data.x, &data.y).unwrap().clone();
        let backbone_size = bb.last_diagnostics.as_ref().unwrap().backbone_size;
        (model, backbone_size)
    };
    let (seq, seq_backbone) = fit(None);
    for threads in THREAD_COUNTS {
        let (par, par_backbone) = fit(Some(threads));
        assert_eq!(seq.beta, par.beta, "threads={threads}");
        assert_eq!(seq.intercept, par.intercept, "threads={threads}");
        assert_eq!(seq.support, par.support, "threads={threads}");
        assert_eq!(seq.objective, par.objective, "threads={threads}");
        assert_eq!(seq_backbone, par_backbone, "threads={threads}");
    }
}

#[test]
fn sparse_logistic_parallel_fits_are_bit_identical() {
    let data = classification::generate(
        &classification::ClassificationConfig {
            n: 200,
            p: 40,
            k: 3,
            n_redundant: 0,
            n_clusters: 2,
            class_sep: 2.0,
            flip_y: 0.02,
        },
        &mut Rng::seed_from_u64(22),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::sparse_logistic()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(3)
            .seed(5)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.beta, par.beta, "threads={threads}");
        assert_eq!(seq.intercept, par.intercept, "threads={threads}");
        assert_eq!(seq.support, par.support, "threads={threads}");
        assert_eq!(seq.nll, par.nll, "threads={threads}");
    }
}

#[test]
fn decision_tree_parallel_fits_are_bit_identical() {
    let data = classification::generate(
        &classification::ClassificationConfig {
            n: 250,
            p: 30,
            k: 4,
            n_redundant: 2,
            n_clusters: 4,
            class_sep: 1.8,
            flip_y: 0.03,
        },
        &mut Rng::seed_from_u64(23),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::decision_tree()
            .alpha(0.6)
            .beta(0.5)
            .num_subproblems(4)
            .depth(2)
            .seed(3)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.root, par.root, "threads={threads}");
        assert_eq!(seq.bin_map, par.bin_map, "threads={threads}");
        assert_eq!(seq.errors, par.errors, "threads={threads}");
        assert_eq!(seq.backbone_features, par.backbone_features, "threads={threads}");
    }
}

#[test]
fn clustering_parallel_fits_are_bit_identical() {
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 14,
            p: 2,
            true_clusters: 3,
            cluster_std: 0.4,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(24),
    );
    let fit = |threads: Option<usize>| {
        let builder = Backbone::clustering()
            .beta(0.9)
            .num_subproblems(4)
            .n_clusters(3)
            .seed(9)
            .execution(ExecutionPolicy::Sequential);
        let builder = match threads {
            None => builder,
            Some(n) => builder.threads(n),
        };
        let mut bb = builder.build().unwrap();
        bb.fit_with_budget(&data.x, &Budget::seconds(120.0)).unwrap().clone()
    };
    let seq = fit(None);
    for threads in THREAD_COUNTS {
        let par = fit(Some(threads));
        assert_eq!(seq.labels, par.labels, "threads={threads}");
        assert_eq!(seq.objective, par.objective, "threads={threads}");
    }
}

/// Backend × thread-count bit-identity for all four learners: the
/// reference fit runs on the scalar backend with the sequential schedule;
/// every (backend, threads) combination must reproduce it bit for bit.
/// Uses the process-global `set_backend` (what `--backend` and
/// `BACKBONE_BACKEND` drive); safe even if another test computes
/// concurrently, because backends are bit-identical by construction.
#[test]
fn all_learners_bit_identical_across_backends_and_thread_counts() {
    let sr = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 80, p: 150, k: 4, rho: 0.2, snr: 5.0 },
        &mut Rng::seed_from_u64(21),
    );
    let lr = classification::generate(
        &classification::ClassificationConfig {
            n: 150,
            p: 30,
            k: 3,
            n_redundant: 0,
            n_clusters: 2,
            class_sep: 2.0,
            flip_y: 0.02,
        },
        &mut Rng::seed_from_u64(22),
    );
    let dt = classification::generate(
        &classification::ClassificationConfig {
            n: 180,
            p: 20,
            k: 3,
            n_redundant: 1,
            n_clusters: 4,
            class_sep: 1.8,
            flip_y: 0.03,
        },
        &mut Rng::seed_from_u64(23),
    );
    let cl = blobs::generate(
        &blobs::BlobsConfig {
            n: 14,
            p: 2,
            true_clusters: 3,
            cluster_std: 0.4,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(24),
    );

    // One fit of all four learners under (backend, threads); returns every
    // bit-comparable artifact.
    let fit_all = |choice: BackendChoice, threads: usize| {
        set_backend(choice);
        let mut sr_bb = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(4)
            .threads(threads)
            .seed(7)
            .build()
            .unwrap();
        let sr_model = sr_bb.fit(&sr.x, &sr.y).unwrap().clone();
        let mut lr_bb = Backbone::sparse_logistic()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(3)
            .threads(threads)
            .seed(5)
            .build()
            .unwrap();
        let lr_model = lr_bb.fit(&lr.x, &lr.y).unwrap().clone();
        let mut dt_bb = Backbone::decision_tree()
            .alpha(0.6)
            .beta(0.5)
            .num_subproblems(4)
            .depth(2)
            .threads(threads)
            .seed(3)
            .build()
            .unwrap();
        let dt_model = dt_bb.fit(&dt.x, &dt.y).unwrap().clone();
        let mut cl_bb = Backbone::clustering()
            .beta(0.9)
            .num_subproblems(4)
            .n_clusters(3)
            .threads(threads)
            .seed(9)
            .build()
            .unwrap();
        let cl_model = cl_bb.fit_with_budget(&cl.x, &Budget::seconds(120.0)).unwrap().clone();
        (sr_model, lr_model, dt_model, cl_model)
    };

    let reference = fit_all(BackendChoice::Scalar, 1);
    for choice in BACKENDS {
        for threads in THREAD_COUNTS {
            let got = fit_all(choice, threads);
            let tag = format!("backend={} threads={threads}", choice.name());
            assert_eq!(reference.0.beta, got.0.beta, "sr beta {tag}");
            assert_eq!(reference.0.support, got.0.support, "sr support {tag}");
            assert_eq!(reference.0.intercept, got.0.intercept, "sr intercept {tag}");
            assert_eq!(reference.0.objective, got.0.objective, "sr objective {tag}");
            assert_eq!(reference.1.beta, got.1.beta, "lr beta {tag}");
            assert_eq!(reference.1.support, got.1.support, "lr support {tag}");
            assert_eq!(reference.1.nll, got.1.nll, "lr nll {tag}");
            assert_eq!(reference.2.root, got.2.root, "dt root {tag}");
            assert_eq!(reference.2.errors, got.2.errors, "dt errors {tag}");
            assert_eq!(
                reference.2.backbone_features, got.2.backbone_features,
                "dt backbone {tag}"
            );
            assert_eq!(reference.3.labels, got.3.labels, "cl labels {tag}");
            assert_eq!(reference.3.objective, got.3.objective, "cl objective {tag}");
        }
    }
    set_backend(BackendChoice::Auto);
}

#[test]
fn diagnostics_report_the_worker_count() {
    let data = sparse_regression::generate(
        &sparse_regression::SparseRegressionConfig { n: 60, p: 100, k: 3, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(25),
    );
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(4)
        .max_nonzeros(3)
        .threads(2)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().threads_used, 2);
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().subproblems_skipped, 0);
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(4)
        .max_nonzeros(3)
        .execution(ExecutionPolicy::Sequential)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();
    assert_eq!(bb.last_diagnostics.as_ref().unwrap().threads_used, 1);
}
