//! Persistence acceptance suite: for every learner,
//! `fit → save → load → predict` must be **bit-identical** to predicting
//! from the in-memory fitted estimator — on fresh data, not just the
//! training matrix — and the `backbone-model/v1` wire format itself is
//! pinned by golden fixture files (`tests/fixtures/model_v1_*.json`)
//! that fail this suite on any accidental format drift.

use backbone_learn::backbone::clustering::ClusteringModel;
use backbone_learn::backbone::decision_tree::BackboneTreeModel;
use backbone_learn::backbone::sparse_regression::SparseRegressionModel;
use backbone_learn::backbone::{Backbone, Predict};
use backbone_learn::data::{blobs, classification, sparse_regression};
use backbone_learn::json::Json;
use backbone_learn::linalg::Matrix;
use backbone_learn::persist::{LearnerKind, LoadedModel, ModelArtifact, Provenance};
use backbone_learn::prop::{property, Gen};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::exact_tree::BinNode;
use backbone_learn::solvers::logistic::LogisticModel;
use backbone_learn::solvers::SolveStatus;
use backbone_learn::util::Budget;

/// Unique scratch path for one save/load cycle.
fn scratch(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("backbone_persist_{}_{}.json", name, std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn assert_bits_eq(a: &[f64], b: &[f64], context: &str) {
    assert_eq!(a.len(), b.len(), "{context}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{context}: prediction {i} differs ({x} vs {y})"
        );
    }
}

// ---------------------------------------------------------------------------
// Per-learner fit → save → load → predict round trips
// ---------------------------------------------------------------------------

#[test]
fn sparse_regression_round_trip_is_bit_identical() {
    let gen_cfg = sparse_regression::SparseRegressionConfig {
        n: 80,
        p: 120,
        k: 3,
        rho: 0.1,
        snr: 5.0,
    };
    let data = sparse_regression::generate(&gen_cfg, &mut Rng::seed_from_u64(1));
    let fresh = sparse_regression::generate(&gen_cfg, &mut Rng::seed_from_u64(2));
    let mut bb = Backbone::sparse_regression()
        .alpha(0.5)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(3)
        .seed(9)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();

    let path = scratch("sr");
    ModelArtifact::from_sparse_regression(&bb).unwrap().save(&path).unwrap();
    let loaded = ModelArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.learner(), LearnerKind::SparseRegression);
    assert_bits_eq(
        &bb.try_predict(&fresh.x).unwrap(),
        &loaded.model.try_predict(&fresh.x).unwrap(),
        "sparse regression",
    );
    // Provenance carried the fit's story along.
    let digest = loaded.provenance.diagnostics.as_ref().unwrap();
    assert_eq!(
        digest.backbone_size,
        bb.last_diagnostics.as_ref().unwrap().backbone_size
    );
    assert_eq!(loaded.provenance.seed, 9);
}

#[test]
fn sparse_logistic_round_trip_is_bit_identical() {
    let gen_cfg = classification::ClassificationConfig {
        n: 150,
        p: 25,
        k: 3,
        n_redundant: 0,
        n_clusters: 2,
        class_sep: 2.0,
        flip_y: 0.02,
    };
    let data = classification::generate(&gen_cfg, &mut Rng::seed_from_u64(3));
    let fresh = classification::generate(&gen_cfg, &mut Rng::seed_from_u64(4));
    let mut bb = Backbone::sparse_logistic()
        .alpha(0.6)
        .beta(0.5)
        .num_subproblems(3)
        .max_nonzeros(2)
        .seed(5)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();

    let path = scratch("lg");
    ModelArtifact::from_sparse_logistic(&bb).unwrap().save(&path).unwrap();
    let loaded = ModelArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.learner(), LearnerKind::SparseLogistic);
    assert_bits_eq(
        &bb.try_predict(&fresh.x).unwrap(),
        &loaded.model.try_predict(&fresh.x).unwrap(),
        "sparse logistic labels",
    );
    // Probabilities too, not just the thresholded labels.
    assert_bits_eq(
        &bb.predict_proba(&fresh.x),
        &loaded.model.predict_scores(&fresh.x).unwrap(),
        "sparse logistic probabilities",
    );
}

#[test]
fn decision_tree_round_trip_is_bit_identical() {
    let gen_cfg = classification::ClassificationConfig {
        n: 150,
        p: 20,
        k: 3,
        n_redundant: 0,
        n_clusters: 4,
        class_sep: 2.0,
        flip_y: 0.02,
    };
    let data = classification::generate(&gen_cfg, &mut Rng::seed_from_u64(5));
    let fresh = classification::generate(&gen_cfg, &mut Rng::seed_from_u64(6));
    let mut bb = Backbone::decision_tree()
        .alpha(0.6)
        .beta(0.5)
        .num_subproblems(3)
        .depth(2)
        .seed(7)
        .build()
        .unwrap();
    bb.fit(&data.x, &data.y).unwrap();

    let path = scratch("dt");
    ModelArtifact::from_decision_tree(&bb).unwrap().save(&path).unwrap();
    let loaded = ModelArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.learner(), LearnerKind::DecisionTree);
    assert_bits_eq(
        &bb.try_predict(&fresh.x).unwrap(),
        &loaded.model.try_predict(&fresh.x).unwrap(),
        "decision tree labels",
    );
    assert_bits_eq(
        &bb.predict_proba(&fresh.x),
        &loaded.model.predict_scores(&fresh.x).unwrap(),
        "decision tree probabilities",
    );
}

#[test]
fn clustering_round_trip_is_bit_identical() {
    let data = blobs::generate(
        &blobs::BlobsConfig {
            n: 14,
            p: 2,
            true_clusters: 3,
            cluster_std: 0.4,
            center_box: 8.0,
            min_center_dist: 5.0,
        },
        &mut Rng::seed_from_u64(4),
    );
    let mut bb = Backbone::clustering()
        .beta(1.0)
        .num_subproblems(3)
        .n_clusters(3)
        .seed(11)
        .build()
        .unwrap();
    bb.fit_with_budget(&data.x, &Budget::seconds(120.0)).unwrap();

    let path = scratch("cl");
    ModelArtifact::from_clustering(&bb).unwrap().save(&path).unwrap();
    let loaded = ModelArtifact::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.learner(), LearnerKind::Clustering);
    // Clustering is transductive: labels-as-f64, checked on the training
    // matrix (the only valid input by the row-count contract).
    let direct: Vec<f64> = bb.try_predict(&data.x).unwrap().iter().map(|&l| l as f64).collect();
    assert_bits_eq(
        &direct,
        &loaded.model.try_predict(&data.x).unwrap(),
        "clustering labels",
    );
}

#[test]
fn unfitted_estimator_cannot_be_persisted() {
    let bb = Backbone::sparse_regression().build().unwrap();
    assert!(ModelArtifact::from_sparse_regression(&bb).is_err());
}

// ---------------------------------------------------------------------------
// Property: random models survive the wire format bit-for-bit
// ---------------------------------------------------------------------------

#[test]
fn random_models_round_trip_bitwise() {
    property("sparse-regression artifacts round-trip", 60, |g: &mut Gen| {
        let p = g.usize_in(1..30);
        let k = g.usize_in(0..p.min(6));
        let mut beta = vec![0.0; p];
        let support = g.subset(p, k);
        for &j in &support {
            beta[j] = g.normal() * 10.0;
        }
        let model = SparseRegressionModel {
            beta,
            intercept: g.normal(),
            support,
            objective: g.normal().abs(),
            gap: if g.bool_with(0.3) { f64::NAN } else { g.normal().abs() },
            status: SolveStatus::Optimal,
        };
        let artifact = ModelArtifact {
            model: LoadedModel::SparseRegression(model.clone()),
            provenance: Provenance {
                crate_version: "0.2.0".into(),
                seed: 0,
                params: Json::parse("{}").unwrap(),
                config: Json::parse("{}").unwrap(),
                diagnostics: None,
            },
        };
        let text = artifact.to_json().to_string_pretty();
        let back = ModelArtifact::parse(&text).unwrap();
        let LoadedModel::SparseRegression(m) = &back.model else {
            panic!("wrong learner kind after round trip")
        };
        assert_eq!(m.support, model.support);
        for (a, b) in m.beta.iter().zip(&model.beta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(m.intercept.to_bits(), model.intercept.to_bits());
        assert_eq!(m.gap.to_bits(), model.gap.to_bits());
    });
}

// ---------------------------------------------------------------------------
// Golden fixtures: the wire format is frozen
// ---------------------------------------------------------------------------

fn fixed_provenance(seed: u64, params: &str, config: &str) -> Provenance {
    Provenance {
        crate_version: "0.2.0".into(),
        seed,
        params: Json::parse(params).unwrap(),
        config: Json::parse(config).unwrap(),
        diagnostics: None,
    }
}

fn golden_sr() -> ModelArtifact {
    ModelArtifact {
        model: LoadedModel::SparseRegression(SparseRegressionModel {
            beta: vec![0.0, 1.5, 0.0, -2.25, 0.0],
            intercept: 0.5,
            support: vec![1, 3],
            objective: 3.5,
            gap: f64::NAN,
            status: SolveStatus::Optimal,
        }),
        provenance: fixed_provenance(
            7,
            r#"{"alpha": 0.5, "b_max": 100, "beta": 0.5, "max_iterations": 4,
                "num_subproblems": 5}"#,
            r#"{"gap_tol": 0.01, "lambda2": 0.001, "max_nonzeros": 10,
                "subproblem_nonzeros": 10}"#,
        ),
    }
}

fn golden_lg() -> ModelArtifact {
    ModelArtifact {
        model: LoadedModel::SparseLogistic(LogisticModel {
            beta: vec![0.75, 0.0, -1.5],
            intercept: -0.25,
            support: vec![0, 2],
            nll: 12.5,
            status: SolveStatus::Optimal,
        }),
        provenance: fixed_provenance(
            3,
            r#"{"alpha": 0.5, "b_max": 12, "beta": 0.5, "max_iterations": 4,
                "num_subproblems": 5}"#,
            r#"{"iht_iters": 150, "max_nonzeros": 2, "ridge": 0.001}"#,
        ),
    }
}

fn golden_dt() -> ModelArtifact {
    ModelArtifact {
        model: LoadedModel::DecisionTree(BackboneTreeModel {
            root: BinNode::Split {
                feature: 0,
                left: Box::new(BinNode::Leaf { prob: 0.25, n: 8 }),
                right: Box::new(BinNode::Split {
                    feature: 1,
                    left: Box::new(BinNode::Leaf { prob: 0.75, n: 4 }),
                    right: Box::new(BinNode::Leaf { prob: 1.0, n: 3 }),
                }),
            },
            bin_map: vec![(2, 0.5), (5, -1.25)],
            errors: 3,
            status: SolveStatus::TimedOut,
            backbone_features: vec![2, 5],
        }),
        provenance: fixed_provenance(
            1,
            r#"{"alpha": 0.5, "b_max": 0, "beta": 0.5, "max_iterations": 4,
                "num_subproblems": 5}"#,
            r#"{"bins": 2, "depth": 2, "importance_threshold": 0, "min_leaf": 1}"#,
        ),
    }
}

fn golden_cl() -> ModelArtifact {
    ModelArtifact {
        model: LoadedModel::Clustering(ClusteringModel {
            labels: vec![0, 1, 1, 0, 2],
            objective: 4.5,
            gap: f64::NAN,
            status: SolveStatus::Infeasible,
        }),
        provenance: fixed_provenance(
            11,
            r#"{"alpha": 1, "b_max": 0, "beta": 0.8, "max_iterations": 1,
                "num_subproblems": 5}"#,
            r#"{"min_cluster_size": 1, "n_clusters": 3, "n_init": 10}"#,
        ),
    }
}

/// Serialized golden artifacts must match the committed fixtures byte for
/// byte, and the fixtures must load back into working models. Any change
/// to the wire format — key names, number formatting, nesting — turns
/// this red and forces a deliberate schema bump.
#[test]
fn golden_fixtures_pin_the_wire_format() {
    let cases: [(&str, ModelArtifact, &str); 4] = [
        (
            "sparse_regression",
            golden_sr(),
            include_str!("fixtures/model_v1_sparse_regression.json"),
        ),
        (
            "sparse_logistic",
            golden_lg(),
            include_str!("fixtures/model_v1_sparse_logistic.json"),
        ),
        ("decision_tree", golden_dt(), include_str!("fixtures/model_v1_decision_tree.json")),
        ("clustering", golden_cl(), include_str!("fixtures/model_v1_clustering.json")),
    ];
    for (name, artifact, fixture) in cases {
        let rendered = artifact.to_json().to_string_pretty();
        assert_eq!(
            rendered, fixture,
            "{name}: serialized artifact drifted from the committed fixture"
        );
        let loaded = ModelArtifact::parse(fixture)
            .unwrap_or_else(|e| panic!("{name}: fixture no longer loads: {e}"));
        assert_eq!(loaded.learner().name(), name);
    }
}

/// Fixture models predict pinned values — format stability alone is not
/// enough, the *semantics* of a loaded model are frozen too.
#[test]
fn golden_fixture_predictions_are_pinned() {
    let sr = ModelArtifact::parse(include_str!("fixtures/model_v1_sparse_regression.json"))
        .unwrap();
    let x = Matrix::from_rows(&[
        vec![1.0, 2.0, 3.0, 4.0, 5.0],
        vec![0.0, -1.0, 0.0, 2.0, 0.0],
    ]);
    // 1.5*x1 - 2.25*x3 + 0.5
    assert_eq!(sr.model.try_predict(&x).unwrap(), vec![-5.5, -5.5]);

    let dt =
        ModelArtifact::parse(include_str!("fixtures/model_v1_decision_tree.json")).unwrap();
    // bin_map: column 0 = (feature 2, thr 0.5) — x[2] ≤ 0.5 goes right;
    // column 1 = (feature 5, thr -1.25) — x[5] ≤ -1.25 goes right.
    let x = Matrix::from_rows(&[
        vec![0.0, 0.0, 1.0, 0.0, 0.0, 0.0],  // x2 > 0.5 → left leaf
        vec![0.0, 0.0, 0.0, 0.0, 0.0, 0.0],  // right, x5 > -1.25 → left leaf
        vec![0.0, 0.0, 0.0, 0.0, 0.0, -2.0], // right, x5 ≤ -1.25 → right leaf
    ]);
    assert_eq!(dt.model.predict_scores(&x).unwrap(), vec![0.25, 0.75, 1.0]);
    assert_eq!(dt.model.try_predict(&x).unwrap(), vec![0.0, 1.0, 1.0]);

    let cl = ModelArtifact::parse(include_str!("fixtures/model_v1_clustering.json")).unwrap();
    assert_eq!(
        cl.model.try_predict(&Matrix::zeros(5, 2)).unwrap(),
        vec![0.0, 1.0, 1.0, 0.0, 2.0]
    );

    let lg = ModelArtifact::parse(include_str!("fixtures/model_v1_sparse_logistic.json"))
        .unwrap();
    let x = Matrix::from_rows(&[vec![10.0, 0.0, 0.0], vec![-10.0, 0.0, 0.0]]);
    assert_eq!(lg.model.try_predict(&x).unwrap(), vec![1.0, 0.0]);
}
