//! Property tests of the blocked linalg kernels and the Gram-cached
//! polish against their retained scalar oracles (the perf-pass safety
//! net): blocked `matvec`/`matvec_t`/`matmul`/`gram` must agree with the
//! `*_naive` reference implementations to ≤ 1e-9 across random shapes,
//! the Gram-cached polish must agree with the full-refit
//! `polish_support` oracle, `cholesky_bordered` must agree with a full
//! refactorization, and fixed-seed fits must be bit-reproducible.

use backbone_learn::backbone::Backbone;
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::{
    cholesky, cholesky_bordered, dot_naive, gather_sum_naive, set_backend, simd_available,
    sqdist_naive, BackendChoice, ComputeBackend, Matrix,
};
use backbone_learn::prop::{property, Gen};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cd::{
    l0_fit, l0_fit_with, polish_support, polish_support_cached, L0Config, L0Workspace,
};

const TOL: f64 = 1e-9;

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            // Mix of normals and exact zeros exercises the zero-skip
            // fast paths of the blocked kernels.
            let v = if g.bool_with(0.15) { 0.0 } else { g.normal() };
            m.set(i, j, v);
        }
    }
    m
}

fn assert_close_slice(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= TOL * (1.0 + x.abs()), "{what}[{i}]: {x} vs {y}");
    }
}

fn assert_close_matrix(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape mismatch");
    assert_close_slice(a.data(), b.data(), what);
}

#[test]
fn prop_blocked_kernels_match_scalar_oracles() {
    property("blocked linalg = scalar oracles", 60, |g| {
        let rows = g.usize_in(1..40);
        let cols = g.usize_in(1..40);
        let a = random_matrix(g, rows, cols);
        let v = g.vec_normal(cols);
        let w = g.vec_normal(rows);

        assert_close_slice(&a.matvec(&v), &a.matvec_naive(&v), "matvec");
        assert_close_slice(&a.matvec_t(&w), &a.matvec_t_naive(&w), "matvec_t");
        assert_close_matrix(&a.gram(), &a.gram_naive(), "gram");

        let inner = g.usize_in(1..20);
        let b = random_matrix(g, cols, inner);
        assert_close_matrix(&a.matmul(&b), &a.matmul_naive(&b), "matmul");

        // Fused residual vs the unfused composition.
        let beta = g.vec_normal(cols);
        let y = g.vec_normal(rows);
        let offset = g.normal();
        let mut fused = Vec::new();
        a.residual_into(&beta, &y, offset, &mut fused);
        let pred = a.matvec_naive(&beta);
        let unfused: Vec<f64> =
            y.iter().zip(&pred).map(|(yi, pi)| yi - offset - pi).collect();
        assert_close_slice(&fused, &unfused, "residual_into");

        // Cached squared norms vs direct computation.
        let rn: Vec<f64> = (0..rows)
            .map(|i| a.row(i).iter().map(|x| x * x).sum::<f64>())
            .collect();
        assert_close_slice(a.row_sq_norms(), &rn, "row_sq_norms");
        let mut cn = vec![0.0; cols];
        for i in 0..rows {
            for (c, &x) in cn.iter_mut().zip(a.row(i)) {
                *c += x * x;
            }
        }
        assert_close_slice(a.col_sq_norms(), &cn, "col_sq_norms");
    });
}

/// Scalar-vs-SIMD-vs-naive agreement for every backend-dispatched kernel
/// across odd sizes (not multiples of the 4-wide lanes, including the
/// n = 0 and n = 1 edges). Both backends must agree with the sequential
/// naive oracle to ≤ 1e-9 — and with *each other* bit-exactly (the
/// backend bit-identity contract; kernels are called directly on
/// `ComputeBackend` values, so the process-global backend is untouched).
#[test]
fn prop_backend_kernels_match_naive_and_each_other() {
    property("scalar = simd = naive across odd sizes", 40, |g| {
        const LENS: [usize; 12] = [0, 1, 2, 3, 5, 7, 9, 13, 17, 31, 63, 101];
        let len = LENS[g.usize_in(0..LENS.len())];
        let a = g.vec_normal(len);
        let b = g.vec_normal(len);
        let (s, v) = (ComputeBackend::Scalar, ComputeBackend::Simd);

        let (ds, dv, dn) = (s.dot(&a, &b), v.dot(&a, &b), dot_naive(&a, &b));
        assert_eq!(ds.to_bits(), dv.to_bits(), "dot bit-identity len={len}");
        assert!((ds - dn).abs() <= TOL * (1.0 + dn.abs()), "dot vs naive len={len}");

        let (qs, qv, qn) = (s.sqdist(&a, &b), v.sqdist(&a, &b), sqdist_naive(&a, &b));
        assert_eq!(qs.to_bits(), qv.to_bits(), "sqdist bit-identity len={len}");
        assert!((qs - qn).abs() <= TOL * (1.0 + qn.abs()), "sqdist vs naive len={len}");

        let alpha = g.normal();
        let (mut ys, mut yv) = (b.clone(), b.clone());
        s.axpy(alpha, &a, &mut ys);
        v.axpy(alpha, &a, &mut yv);
        assert_eq!(ys, yv, "axpy bit-identity len={len}");
        let yn: Vec<f64> = b.iter().zip(&a).map(|(yi, xi)| yi + alpha * xi).collect();
        assert_close_slice(&ys, &yn, "axpy vs naive");

        if len > 0 {
            let idx: Vec<usize> = (0..g.usize_in(0..2 * len + 1))
                .map(|_| g.usize_in(0..len))
                .collect();
            let (gs, gv, gn) =
                (s.gather_sum(&a, &idx), v.gather_sum(&a, &idx), gather_sum_naive(&a, &idx));
            assert_eq!(gs.to_bits(), gv.to_bits(), "gather_sum bit-identity len={len}");
            assert!((gs - gn).abs() <= TOL * (1.0 + gn.abs()), "gather_sum vs naive");
        }

        let c = [g.normal(), g.normal(), g.normal(), g.normal()];
        let (r0, r1, r2, r3) =
            (g.vec_normal(len), g.vec_normal(len), g.vec_normal(len), g.vec_normal(len));
        let base = g.vec_normal(len);
        let (mut os, mut ov) = (base.clone(), base.clone());
        s.fused4(c, &r0, &r1, &r2, &r3, &mut os);
        v.fused4(c, &r0, &r1, &r2, &r3, &mut ov);
        assert_eq!(os, ov, "fused4 bit-identity len={len}");
        let on: Vec<f64> = (0..len)
            .map(|j| base[j] + c[0] * r0[j] + c[1] * r1[j] + c[2] * r2[j] + c[3] * r3[j])
            .collect();
        assert_close_slice(&os, &on, "fused4 vs naive");

        let w = g.normal();
        let means = g.vec_normal(len);
        let (mut num_s, mut den_s) = (base.clone(), r0.clone());
        let (mut num_v, mut den_v) = (base.clone(), r0.clone());
        s.centered_accumulate(&a, &means, w, &mut num_s, &mut den_s);
        v.centered_accumulate(&a, &means, w, &mut num_v, &mut den_v);
        assert_eq!(num_s, num_v, "centered_accumulate num bit-identity len={len}");
        assert_eq!(den_s, den_v, "centered_accumulate den bit-identity len={len}");
    });
}

/// The fitted support (and every coefficient) of a fixed-seed fit is
/// pinned across `scalar`/`simd`/`auto`: backend choice may only change
/// timings, never results. Uses the process-global [`set_backend`] the
/// CLI flag drives; safe under concurrent tests precisely because the
/// backends are bit-identical.
#[test]
fn backbone_supports_are_pinned_across_backends() {
    let data = generate(
        &SparseRegressionConfig { n: 120, p: 200, k: 4, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(99),
    );
    let fit = |choice: BackendChoice| {
        set_backend(choice);
        let mut bb = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(4)
            .seed(31)
            .build()
            .unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let scalar = fit(BackendChoice::Scalar);
    let simd = fit(BackendChoice::Simd);
    let auto = fit(BackendChoice::Auto);
    set_backend(BackendChoice::Auto);
    assert!(!scalar.support.is_empty());
    for (name, other) in [("simd", &simd), ("auto", &auto)] {
        assert_eq!(scalar.support, other.support, "support drift under {name}");
        assert_eq!(scalar.beta, other.beta, "beta drift under {name}");
        assert_eq!(scalar.intercept, other.intercept, "intercept drift under {name}");
        assert_eq!(scalar.objective, other.objective, "objective drift under {name}");
    }
    // The test is vacuous as a SIMD check on hardware without AVX2, but
    // still pins scalar determinism there.
    let _ = simd_available();
}

#[test]
fn prop_bordered_cholesky_matches_full_factorization() {
    property("bordered cholesky = full refactorization", 60, |g| {
        let m = g.usize_in(1..12);
        let rows = m + g.usize_in(1..6);
        // SPD via AᵀA + I.
        let a = random_matrix(g, rows, m);
        let mut spd = a.gram();
        for i in 0..m {
            let v = spd.get(i, i) + 1.0;
            spd.set(i, i, v);
        }
        let full = cholesky(&spd).expect("SPD by construction");
        // Factor the leading (m−1) block, then border with the last
        // row/column.
        let lead: Vec<usize> = (0..m - 1).collect();
        let sub = spd.select_rows(&lead).select_columns(&lead);
        let l_minus = cholesky(&sub).expect("leading block SPD");
        let cross: Vec<f64> = (0..m - 1).map(|i| spd.get(i, m - 1)).collect();
        let bordered = cholesky_bordered(&l_minus, &cross, spd.get(m - 1, m - 1))
            .expect("bordered SPD");
        assert_close_matrix(&bordered, &full, "cholesky_bordered");
    });
}

#[test]
fn prop_gram_cached_polish_matches_full_refit_oracle() {
    property("gram-cached polish = full-refit oracle", 40, |g| {
        let n = g.usize_in(20..60);
        let p = g.usize_in(5..30);
        let k = g.usize_in(1..8).min(p);
        let mut x = random_matrix(g, n, p);
        // Random column offsets make the centering path do real work.
        for j in 0..p {
            let shift = g.normal() * 2.0;
            for i in 0..n {
                let v = x.get(i, j) + shift;
                x.set(i, j, v);
            }
        }
        let y = g.vec_normal(n);
        let support = g.subset(p, k);
        let lambda2 = g.f64_in(1e-4..0.1);

        let (b1, i1, o1) = polish_support(&x, &y, &support, lambda2);
        let mut ws = L0Workspace::default();
        let (b2, i2, o2) = polish_support_cached(&x, &y, &support, lambda2, &mut ws);
        assert!((i1 - i2).abs() <= TOL * (1.0 + i1.abs()), "intercept {i1} vs {i2}");
        assert!((o1 - o2).abs() <= TOL * (1.0 + o1.abs()), "objective {o1} vs {o2}");
        assert_close_slice(&b1, &b2, "polish beta");
    });
}

#[test]
fn prop_l0_fit_deterministic_and_workspace_invariant() {
    property("l0_fit reproducible + workspace-invariant", 15, |g| {
        let n = g.usize_in(25..60);
        let p = g.usize_in(10..40);
        let k = g.usize_in(1..6).min(p);
        let x = random_matrix(g, n, p);
        let y = g.vec_normal(n);
        let cfg = L0Config { k, lambda2: 1e-3, ..Default::default() };
        let a = l0_fit(&x, &y, &cfg);
        let b = l0_fit(&x, &y, &cfg);
        assert_eq!(a.support, b.support);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.intercept, b.intercept);
        assert_eq!(a.objective, b.objective);
        // A dirty reused workspace must not change anything.
        let mut ws = L0Workspace::default();
        let _ = l0_fit_with(&x, &y, &L0Config { k: 2.min(p), ..Default::default() }, &mut ws);
        let c = l0_fit_with(&x, &y, &cfg, &mut ws);
        assert_eq!(a.support, c.support);
        assert_eq!(a.beta, c.beta);
    });
}

/// Fixed-seed, fixed-data end-to-end fit is bit-reproducible — the
/// determinism anchor of the perf pass (blocked kernels and the
/// Gram-cached polish must not introduce any run-to-run variance).
#[test]
fn backbone_fit_is_bit_reproducible_at_fixed_seed() {
    let data = generate(
        &SparseRegressionConfig { n: 120, p: 200, k: 4, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(99),
    );
    let fit = || {
        let mut bb = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(4)
            .seed(31)
            .build()
            .unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let a = fit();
    let b = fit();
    assert_eq!(a.support, b.support);
    assert_eq!(a.beta, b.beta);
    assert_eq!(a.intercept, b.intercept);
    assert_eq!(a.objective, b.objective);
}
