//! Property tests of the blocked linalg kernels and the Gram-cached
//! polish against their retained scalar oracles (the perf-pass safety
//! net): blocked `matvec`/`matvec_t`/`matmul`/`gram` must agree with the
//! `*_naive` reference implementations to ≤ 1e-9 across random shapes,
//! the Gram-cached polish must agree with the full-refit
//! `polish_support` oracle, `cholesky_bordered` must agree with a full
//! refactorization, and fixed-seed fits must be bit-reproducible.

use backbone_learn::backbone::Backbone;
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::{cholesky, cholesky_bordered, Matrix};
use backbone_learn::prop::{property, Gen};
use backbone_learn::rng::Rng;
use backbone_learn::solvers::cd::{
    l0_fit, l0_fit_with, polish_support, polish_support_cached, L0Config, L0Workspace,
};

const TOL: f64 = 1e-9;

fn random_matrix(g: &mut Gen, rows: usize, cols: usize) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            // Mix of normals and exact zeros exercises the zero-skip
            // fast paths of the blocked kernels.
            let v = if g.bool_with(0.15) { 0.0 } else { g.normal() };
            m.set(i, j, v);
        }
    }
    m
}

fn assert_close_slice(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= TOL * (1.0 + x.abs()), "{what}[{i}]: {x} vs {y}");
    }
}

fn assert_close_matrix(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()), "{what}: shape mismatch");
    assert_close_slice(a.data(), b.data(), what);
}

#[test]
fn prop_blocked_kernels_match_scalar_oracles() {
    property("blocked linalg = scalar oracles", 60, |g| {
        let rows = g.usize_in(1..40);
        let cols = g.usize_in(1..40);
        let a = random_matrix(g, rows, cols);
        let v = g.vec_normal(cols);
        let w = g.vec_normal(rows);

        assert_close_slice(&a.matvec(&v), &a.matvec_naive(&v), "matvec");
        assert_close_slice(&a.matvec_t(&w), &a.matvec_t_naive(&w), "matvec_t");
        assert_close_matrix(&a.gram(), &a.gram_naive(), "gram");

        let inner = g.usize_in(1..20);
        let b = random_matrix(g, cols, inner);
        assert_close_matrix(&a.matmul(&b), &a.matmul_naive(&b), "matmul");

        // Fused residual vs the unfused composition.
        let beta = g.vec_normal(cols);
        let y = g.vec_normal(rows);
        let offset = g.normal();
        let mut fused = Vec::new();
        a.residual_into(&beta, &y, offset, &mut fused);
        let pred = a.matvec_naive(&beta);
        let unfused: Vec<f64> =
            y.iter().zip(&pred).map(|(yi, pi)| yi - offset - pi).collect();
        assert_close_slice(&fused, &unfused, "residual_into");

        // Cached squared norms vs direct computation.
        let rn: Vec<f64> = (0..rows)
            .map(|i| a.row(i).iter().map(|x| x * x).sum::<f64>())
            .collect();
        assert_close_slice(a.row_sq_norms(), &rn, "row_sq_norms");
        let mut cn = vec![0.0; cols];
        for i in 0..rows {
            for (c, &x) in cn.iter_mut().zip(a.row(i)) {
                *c += x * x;
            }
        }
        assert_close_slice(a.col_sq_norms(), &cn, "col_sq_norms");
    });
}

#[test]
fn prop_bordered_cholesky_matches_full_factorization() {
    property("bordered cholesky = full refactorization", 60, |g| {
        let m = g.usize_in(1..12);
        let rows = m + g.usize_in(1..6);
        // SPD via AᵀA + I.
        let a = random_matrix(g, rows, m);
        let mut spd = a.gram();
        for i in 0..m {
            let v = spd.get(i, i) + 1.0;
            spd.set(i, i, v);
        }
        let full = cholesky(&spd).expect("SPD by construction");
        // Factor the leading (m−1) block, then border with the last
        // row/column.
        let lead: Vec<usize> = (0..m - 1).collect();
        let sub = spd.select_rows(&lead).select_columns(&lead);
        let l_minus = cholesky(&sub).expect("leading block SPD");
        let cross: Vec<f64> = (0..m - 1).map(|i| spd.get(i, m - 1)).collect();
        let bordered = cholesky_bordered(&l_minus, &cross, spd.get(m - 1, m - 1))
            .expect("bordered SPD");
        assert_close_matrix(&bordered, &full, "cholesky_bordered");
    });
}

#[test]
fn prop_gram_cached_polish_matches_full_refit_oracle() {
    property("gram-cached polish = full-refit oracle", 40, |g| {
        let n = g.usize_in(20..60);
        let p = g.usize_in(5..30);
        let k = g.usize_in(1..8).min(p);
        let mut x = random_matrix(g, n, p);
        // Random column offsets make the centering path do real work.
        for j in 0..p {
            let shift = g.normal() * 2.0;
            for i in 0..n {
                let v = x.get(i, j) + shift;
                x.set(i, j, v);
            }
        }
        let y = g.vec_normal(n);
        let support = g.subset(p, k);
        let lambda2 = g.f64_in(1e-4..0.1);

        let (b1, i1, o1) = polish_support(&x, &y, &support, lambda2);
        let mut ws = L0Workspace::default();
        let (b2, i2, o2) = polish_support_cached(&x, &y, &support, lambda2, &mut ws);
        assert!((i1 - i2).abs() <= TOL * (1.0 + i1.abs()), "intercept {i1} vs {i2}");
        assert!((o1 - o2).abs() <= TOL * (1.0 + o1.abs()), "objective {o1} vs {o2}");
        assert_close_slice(&b1, &b2, "polish beta");
    });
}

#[test]
fn prop_l0_fit_deterministic_and_workspace_invariant() {
    property("l0_fit reproducible + workspace-invariant", 15, |g| {
        let n = g.usize_in(25..60);
        let p = g.usize_in(10..40);
        let k = g.usize_in(1..6).min(p);
        let x = random_matrix(g, n, p);
        let y = g.vec_normal(n);
        let cfg = L0Config { k, lambda2: 1e-3, ..Default::default() };
        let a = l0_fit(&x, &y, &cfg);
        let b = l0_fit(&x, &y, &cfg);
        assert_eq!(a.support, b.support);
        assert_eq!(a.beta, b.beta);
        assert_eq!(a.intercept, b.intercept);
        assert_eq!(a.objective, b.objective);
        // A dirty reused workspace must not change anything.
        let mut ws = L0Workspace::default();
        let _ = l0_fit_with(&x, &y, &L0Config { k: 2.min(p), ..Default::default() }, &mut ws);
        let c = l0_fit_with(&x, &y, &cfg, &mut ws);
        assert_eq!(a.support, c.support);
        assert_eq!(a.beta, c.beta);
    });
}

/// Fixed-seed, fixed-data end-to-end fit is bit-reproducible — the
/// determinism anchor of the perf pass (blocked kernels and the
/// Gram-cached polish must not introduce any run-to-run variance).
#[test]
fn backbone_fit_is_bit_reproducible_at_fixed_seed() {
    let data = generate(
        &SparseRegressionConfig { n: 120, p: 200, k: 4, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(99),
    );
    let fit = || {
        let mut bb = Backbone::sparse_regression()
            .alpha(0.5)
            .beta(0.5)
            .num_subproblems(4)
            .max_nonzeros(4)
            .seed(31)
            .build()
            .unwrap();
        bb.fit(&data.x, &data.y).unwrap().clone()
    };
    let a = fit();
    let b = fit();
    assert_eq!(a.support, b.support);
    assert_eq!(a.beta, b.beta);
    assert_eq!(a.intercept, b.intercept);
    assert_eq!(a.objective, b.objective);
}
