//! Regenerates Table 1, clustering block (experiment T1-CL in DESIGN.md).
//! Quick scale by default; BENCH_FULL=1 for (200, 2, 5) — where, exactly
//! as in the paper, the Exact row burns the whole budget.

mod common;

use backbone_learn::bench_support::{render_table, run_clustering_block};
use backbone_learn::config::Problem;

fn main() {
    let cfg = common::configure(Problem::Clustering);
    let rows = run_clustering_block(&cfg).expect("block failed");
    println!(
        "{}",
        render_table(
            &format!("Table 1 — Clustering (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &rows
        )
    );
}
