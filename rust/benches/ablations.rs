//! Ablation benches (experiments A-αβ, A-M, A-SCREEN in DESIGN.md) — the
//! hyperparameter-sensitivity claims the paper makes in prose:
//!
//! - sparse regression runs best with *larger* (α, β) — "when possible,
//!   it is preferred to solve larger subproblems that include more signal";
//! - decision trees benefit from *smaller* subproblems ("feature sampling
//!   as in random forests");
//! - clustering is insensitive to its hyperparameters.
//!
//! Select with BENCH_ABLATION=alpha-beta|num-subproblems|screen (default:
//! all three, quick scale).

mod common;

use backbone_learn::bench_support::{render_table, run_block};
use backbone_learn::config::{BackboneCell, Problem};

fn grid_alpha_beta() -> Vec<BackboneCell> {
    let mut g = Vec::new();
    for &alpha in &[0.1, 0.5, 0.9] {
        for &beta in &[0.3, 0.5, 0.9] {
            g.push(BackboneCell { m: 5, alpha, beta });
        }
    }
    g
}

fn grid_m() -> Vec<BackboneCell> {
    [1usize, 2, 5, 10, 20]
        .iter()
        .map(|&m| BackboneCell { m, alpha: 0.5, beta: 0.5 })
        .collect()
}

fn grid_screen() -> Vec<BackboneCell> {
    [1.0, 0.5, 0.25, 0.1]
        .iter()
        .map(|&alpha| BackboneCell { m: 5, alpha, beta: 0.5 })
        .collect()
}

fn run(problem: Problem, name: &str, grid: Vec<BackboneCell>) {
    let mut cfg = common::configure(problem);
    cfg.grid = grid;
    let rows = run_block(&cfg).expect("ablation failed");
    println!(
        "{}",
        render_table(&format!("Ablation `{name}` — {}", problem.name()), &rows)
    );
}

fn main() {
    let which = std::env::var("BENCH_ABLATION").unwrap_or_else(|_| "all".into());
    if which == "alpha-beta" || which == "all" {
        run(Problem::SparseRegression, "alpha-beta", grid_alpha_beta());
        run(Problem::DecisionTrees, "alpha-beta", grid_alpha_beta());
    }
    if which == "num-subproblems" || which == "all" {
        run(Problem::SparseRegression, "num-subproblems", grid_m());
        run(
            Problem::Clustering,
            "num-subproblems",
            grid_m()
                .into_iter()
                .map(|mut c| {
                    c.alpha = 1.0;
                    c.beta = 1.0;
                    c
                })
                .collect(),
        );
    }
    if which == "screen" || which == "all" {
        run(Problem::SparseRegression, "screen", grid_screen());
    }
}
