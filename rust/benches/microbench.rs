//! Micro-benchmarks of the hot paths — the instrument for the perf pass
//! (EXPERIMENTS.md §Perf). Each primitive is timed native vs PJRT (when
//! artifacts exist) at the shapes the Table-1 workloads actually hit.
//!
//! Run: `cargo bench --bench microbench` (or the compiled binary directly).

mod common;

use backbone_learn::backbone::screen::correlation_utilities;
use backbone_learn::backbone::{Backbone, ExecutionPolicy};
use backbone_learn::data::sparse_regression::{generate, SparseRegressionConfig};
use backbone_learn::linalg::{set_backend, simd_available, BackendChoice, Matrix};
use backbone_learn::rng::Rng;
use backbone_learn::runtime::Engine;
use backbone_learn::solvers::cd::{elastic_net_path, l0_fit, ElasticNetConfig, L0Config};
use backbone_learn::solvers::kmeans::{kmeans_fit, KMeansConfig};
use backbone_learn::solvers::l0bnb::{l0bnb_solve, L0BnbConfig};
use backbone_learn::util::Budget;
use common::timed;

fn bench_n(label: &str, iters: usize, mut f: impl FnMut()) -> f64 {
    // Warm-up once (PJRT compilation, caches), then time the mean.
    f();
    let (_, secs) = timed(|| {
        for _ in 0..iters {
            f();
        }
    });
    let per = secs / iters as f64;
    println!("{label:<44} {:>10.3} ms/iter ({iters} iters)", per * 1e3);
    per
}

fn main() {
    println!("== microbench: hot-path primitives ==\n");
    let engine = Engine::load("artifacts").ok();
    if engine.is_none() {
        println!("(no artifacts — PJRT rows skipped; run `make artifacts`)\n");
    }

    // --- Screening: n=200, p=1000 (quick SR shape). ----------------------
    let data = generate(
        &SparseRegressionConfig { n: 200, p: 1000, k: 5, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(1),
    );
    let t_native = bench_n("screen corr (native, 200×1000)", 20, || {
        let u = correlation_utilities(&data.x, &data.y);
        std::hint::black_box(u);
    });
    if let Some(engine) = &engine {
        let t_pjrt = bench_n("screen corr (PJRT,   200×1000)", 20, || {
            let u = engine.screen_utilities(&data.x, &data.y).unwrap().unwrap();
            std::hint::black_box(u);
        });
        println!("  → PJRT/native ratio: {:.2}×\n", t_pjrt / t_native);
    }

    // --- Screening at paper scale: n=500, p=5000. -------------------------
    let big = generate(
        &SparseRegressionConfig { n: 500, p: 5000, k: 10, rho: 0.1, snr: 5.0 },
        &mut Rng::seed_from_u64(2),
    );
    let t_native = bench_n("screen corr (native, 500×5000)", 5, || {
        std::hint::black_box(correlation_utilities(&big.x, &big.y));
    });
    if let Some(engine) = &engine {
        let t_pjrt = bench_n("screen corr (PJRT,   500×5000)", 5, || {
            std::hint::black_box(engine.screen_utilities(&big.x, &big.y).unwrap().unwrap());
        });
        println!("  → PJRT/native ratio: {:.2}×\n", t_pjrt / t_native);
    }

    // --- IHT subproblem fit: n=200, p_sub=400, k=5. -----------------------
    let sub = data.x.select_columns(&(0..400).collect::<Vec<_>>());
    let t_native = bench_n("L0 subproblem (native IHT+swaps, 200×400)", 10, || {
        std::hint::black_box(l0_fit(&sub, &data.y, &L0Config { k: 5, ..Default::default() }));
    });
    if let Some(engine) = &engine {
        let t_pjrt = bench_n("L0 subproblem (PJRT IHT,        200×400)", 10, || {
            std::hint::black_box(engine.iht_support(&sub, &data.y, 5).unwrap().unwrap());
        });
        println!("  → PJRT/native ratio: {:.2}×\n", t_pjrt / t_native);
    }

    // --- GLMNet path (the heuristic baseline's cost). ----------------------
    bench_n("elastic-net path (50 λ, 200×1000)", 3, || {
        std::hint::black_box(elastic_net_path(
            &data.x,
            &data.y,
            &ElasticNetConfig { n_lambda: 50, ..Default::default() },
        ));
    });

    // --- L0BnB on a reduced (backbone-sized) problem. ----------------------
    let reduced = data.x.select_columns(&(0..60).collect::<Vec<_>>());
    bench_n("L0BnB exact (200×60, k=5)", 3, || {
        std::hint::black_box(l0bnb_solve(
            &reduced,
            &data.y,
            &L0BnbConfig { k: 5, ..Default::default() },
            &Budget::seconds(60.0),
        ));
    });

    // --- k-means: n=200, d=2, k=5 (clustering shape). ----------------------
    let blob = backbone_learn::data::blobs::generate(
        &backbone_learn::data::blobs::BlobsConfig::default(),
        &mut Rng::seed_from_u64(3),
    );
    let mut rng = Rng::seed_from_u64(4);
    let t_native = bench_n("kmeans (native, 200×2, k=5, 10 init)", 10, || {
        std::hint::black_box(kmeans_fit(
            &blob.x,
            &KMeansConfig { k: 5, ..Default::default() },
            &mut rng,
        ));
    });
    if let Some(engine) = &engine {
        let mut rng = Rng::seed_from_u64(4);
        let t_pjrt = bench_n("kmeans (PJRT Lloyd, 200×2, k=5, 10 init)", 10, || {
            std::hint::black_box(
                engine
                    .kmeans_via_lloyd(&blob.x, &KMeansConfig { k: 5, ..Default::default() }, &mut rng)
                    .unwrap()
                    .unwrap(),
            );
        });
        println!("  → PJRT/native ratio: {:.2}×\n", t_pjrt / t_native);
    }

    // --- Subproblem batch: Sequential vs Parallel scheduler. ----------------
    // One full backbone fit (phase 1 dominated by the M=8 subproblem
    // batch) per policy; the batch contract makes the fits bit-identical,
    // so the ratio is pure scheduling speedup.
    {
        let data = generate(
            &SparseRegressionConfig { n: 200, p: 1500, k: 5, rho: 0.1, snr: 5.0 },
            &mut Rng::seed_from_u64(5),
        );
        let fit = |policy: ExecutionPolicy| {
            let builder = Backbone::sparse_regression()
                .alpha(0.8)
                .beta(0.5)
                .num_subproblems(8)
                .max_nonzeros(5)
                .seed(1)
                .execution(policy);
            let builder = if policy == ExecutionPolicy::Parallel {
                builder.threads(0) // all available cores
            } else {
                builder
            };
            let mut bb = builder.build().unwrap();
            let model = bb.fit(&data.x, &data.y).unwrap().clone();
            (model, bb.last_diagnostics.clone().unwrap())
        };
        let t_seq = bench_n("backbone batch (sequential, M=8, 200×1500)", 3, || {
            std::hint::black_box(fit(ExecutionPolicy::Sequential));
        });
        let t_par = bench_n("backbone batch (parallel,   M=8, 200×1500)", 3, || {
            std::hint::black_box(fit(ExecutionPolicy::Parallel));
        });
        let (m_seq, _) = fit(ExecutionPolicy::Sequential);
        let (m_par, d_par) = fit(ExecutionPolicy::Parallel);
        assert_eq!(m_seq.beta, m_par.beta, "policies diverged — batch contract broken");
        println!(
            "  → parallel/sequential speedup: {:.2}× on {} threads (bit-identical fits)\n",
            t_seq / t_par,
            d_par.threads_used.max(1),
        );
    }

    // --- Blocked kernels vs scalar oracles (same shapes the fits hit). ------
    {
        let x = &big.x; // 500×5000
        let v: Vec<f64> = (0..x.cols()).map(|i| ((i % 13) as f64 - 6.0) * 0.1).collect();
        let w: Vec<f64> = (0..x.rows()).map(|i| ((i % 7) as f64 - 3.0) * 0.1).collect();
        let t_blk = bench_n("matvec   blocked (500×5000)", 50, || {
            std::hint::black_box(x.matvec(&v));
        });
        let t_nav = bench_n("matvec   naive   (500×5000)", 50, || {
            std::hint::black_box(x.matvec_naive(&v));
        });
        println!("  → naive/blocked: {:.2}×\n", t_nav / t_blk);
        let t_blk = bench_n("matvec_t blocked (500×5000)", 50, || {
            std::hint::black_box(x.matvec_t(&w));
        });
        let t_nav = bench_n("matvec_t naive   (500×5000)", 50, || {
            std::hint::black_box(x.matvec_t_naive(&w));
        });
        println!("  → naive/blocked: {:.2}×\n", t_nav / t_blk);
        let sub = x.select_columns(&(0..400).collect::<Vec<_>>());
        let t_blk = bench_n("gram     blocked (500×400)", 10, || {
            std::hint::black_box(sub.gram());
        });
        let t_nav = bench_n("gram     naive   (500×400)", 10, || {
            std::hint::black_box(sub.gram_naive());
        });
        println!("  → naive/blocked: {:.2}×\n", t_nav / t_blk);
    }

    // --- Scalar vs SIMD per backend kernel (n=500, p=2000 perf-gate shape). --
    // Every backend-dispatched kernel, timed once per compute backend by
    // flipping the process-global dispatch. Backends are bit-identical, so
    // the ratio is pure instruction-selection speedup. Skipped (scalar row
    // only) when the CPU lacks AVX2.
    {
        let gate = generate(
            &SparseRegressionConfig { n: 500, p: 2000, k: 10, rho: 0.1, snr: 5.0 },
            &mut Rng::seed_from_u64(8),
        );
        let x = &gate.x; // 500×2000
        let (n, p) = (x.rows(), x.cols());
        let v: Vec<f64> = (0..p).map(|i| ((i % 13) as f64 - 6.0) * 0.1).collect();
        let w: Vec<f64> = (0..n).map(|i| ((i % 7) as f64 - 3.0) * 0.1).collect();
        let len = n * p;
        let a: Vec<f64> = (0..len).map(|i| ((i % 17) as f64 - 8.0) * 0.05).collect();
        let b: Vec<f64> = (0..len).map(|i| ((i % 11) as f64 - 5.0) * 0.07).collect();
        let idx: Vec<usize> = (0..len).map(|i| (i * 7919) % len).collect();
        let means = x.col_means();
        let backends: &[BackendChoice] = if simd_available() {
            &[BackendChoice::Scalar, BackendChoice::Simd]
        } else {
            println!("(no AVX2 — SIMD kernel rows skipped, scalar only)\n");
            &[BackendChoice::Scalar]
        };
        let mut pairs: Vec<(&str, Vec<f64>)> = Vec::new();
        for &choice in backends {
            set_backend(choice);
            let name = choice.name();
            let mut record = |kernel: &'static str, secs: f64| {
                match pairs.iter_mut().find(|(k, _)| *k == kernel) {
                    Some((_, v)) => v.push(secs),
                    None => pairs.push((kernel, vec![secs])),
                }
            };
            record("dot", bench_n(&format!("dot      {name:<7} (1M)"), 50, || {
                std::hint::black_box(backbone_learn::linalg::dot(&a, &b));
            }));
            let mut yacc = b.clone();
            record("axpy", bench_n(&format!("axpy     {name:<7} (1M)"), 50, || {
                backbone_learn::linalg::axpy(0.5, &a, &mut yacc);
                std::hint::black_box(&yacc);
            }));
            record("sqdist", bench_n(&format!("sqdist   {name:<7} (1M)"), 50, || {
                std::hint::black_box(backbone_learn::linalg::sqdist(&a, &b));
            }));
            record("gather_sum", bench_n(&format!("gather   {name:<7} (1M)"), 20, || {
                std::hint::black_box(backbone_learn::linalg::gather_sum(&a, &idx));
            }));
            let (mut num, mut den) = (vec![0.0; p], vec![0.0; p]);
            record(
                "centered_accumulate",
                bench_n(&format!("centered {name:<7} (500×2000)"), 10, || {
                    for i in 0..n {
                        backbone_learn::linalg::centered_accumulate(
                            x.row(i),
                            &means,
                            w[i],
                            &mut num,
                            &mut den,
                        );
                    }
                    std::hint::black_box(&num);
                }),
            );
            let mut buf = Vec::new();
            record("matvec", bench_n(&format!("matvec   {name:<7} (500×2000)"), 50, || {
                x.matvec_into(&v, &mut buf);
                std::hint::black_box(&buf);
            }));
            let mut buft = Vec::new();
            record("matvec_t", bench_n(&format!("matvec_t {name:<7} (500×2000)"), 50, || {
                x.matvec_t_into(&w, &mut buft);
                std::hint::black_box(&buft);
            }));
            record("gram", bench_n(&format!("gram     {name:<7} (500×2000)"), 2, || {
                std::hint::black_box(x.gram());
            }));
            let beta: Vec<f64> = (0..p).map(|i| ((i % 5) as f64 - 2.0) * 0.02).collect();
            let mut resid = Vec::new();
            record(
                "residual_into",
                bench_n(&format!("residual {name:<7} (500×2000)"), 50, || {
                    x.residual_into(&beta, &gate.y, 0.1, &mut resid);
                    std::hint::black_box(&resid);
                }),
            );
        }
        set_backend(BackendChoice::Auto);
        if backends.len() == 2 {
            for (kernel, secs) in &pairs {
                if let [scalar, simd] = secs[..] {
                    println!("  → {kernel}: scalar/simd = {:.2}×", scalar / simd);
                }
            }
            println!();
        }
    }

    // --- End-to-end backbone fit at the perf-gate shape (single thread). ----
    // n=500, p=2000, k=10 sparse regression: the acceptance class the
    // PR-over-PR perf trajectory (`cli bench`, BENCH_*.json) tracks.
    {
        let data = generate(
            &SparseRegressionConfig { n: 500, p: 2000, k: 10, rho: 0.1, snr: 5.0 },
            &mut Rng::seed_from_u64(71),
        );
        let t = bench_n("backbone SR fit (sequential, 500×2000, k=10)", 3, || {
            let mut bb = Backbone::sparse_regression()
                .alpha(0.5)
                .beta(0.5)
                .num_subproblems(8)
                .max_nonzeros(10)
                .seed(7)
                .build()
                .unwrap();
            std::hint::black_box(bb.fit(&data.x, &data.y).unwrap().clone());
        });
        println!("  → {:.1} ms end-to-end\n", t * 1e3);
    }

    // --- Matmul roofline reference. -----------------------------------------
    let a = Matrix::from_vec(256, 256, (0..256 * 256).map(|i| (i % 7) as f64).collect());
    let t = bench_n("matmul 256×256×256 (blocked)", 10, || {
        std::hint::black_box(a.matmul(&a));
    });
    let flops = 2.0 * 256f64.powi(3);
    println!("  → {:.2} GFLOP/s blocked matmul", flops / t / 1e9);
    let t_nav = bench_n("matmul 256×256×256 (naive)", 10, || {
        std::hint::black_box(a.matmul_naive(&a));
    });
    println!("  → naive/blocked: {:.2}×\n", t_nav / t);

    println!("done.");
}
