//! Regenerates Table 1, sparse-regression block (experiment T1-SR in
//! DESIGN.md). Quick scale by default; BENCH_FULL=1 for (500, 5000, 10).

mod common;

use backbone_learn::bench_support::{render_table, run_sparse_regression_block};
use backbone_learn::config::Problem;

fn main() {
    let cfg = common::configure(Problem::SparseRegression);
    let rows = run_sparse_regression_block(&cfg).expect("block failed");
    println!(
        "{}",
        render_table(
            &format!("Table 1 — Sparse Regression (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &rows
        )
    );
}
