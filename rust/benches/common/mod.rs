//! Shared bench plumbing (criterion is not in the offline vendor set, so
//! benches are `harness = false` binaries using this module).
//!
//! Environment knobs:
//! - `BENCH_FULL=1`   — run at the paper's Table-1 scale (hours!) instead
//!   of the quick scale that finishes in minutes on one core.
//! - `BENCH_REPS=N`   — override the repetition count.
//! - `BENCH_BUDGET=S` — override the per-method budget (seconds).

use backbone_learn::config::{ExperimentConfig, Problem};

pub fn configure(problem: Problem) -> ExperimentConfig {
    let full = std::env::var("BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let mut cfg = if full {
        ExperimentConfig::paper_defaults(problem)
    } else {
        ExperimentConfig::quick_defaults(problem)
    };
    if let Ok(r) = std::env::var("BENCH_REPS") {
        if let Ok(r) = r.parse() {
            cfg.repetitions = r;
        }
    }
    if let Ok(b) = std::env::var("BENCH_BUDGET") {
        if let Ok(b) = b.parse() {
            cfg.budget_secs = b;
        }
    }
    eprintln!(
        "[bench] {} scale: n={} p={} k={} reps={} budget={}s",
        if full { "PAPER" } else { "quick" },
        cfg.n,
        cfg.p,
        cfg.k,
        cfg.repetitions,
        cfg.budget_secs
    );
    cfg
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = std::time::Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}
