//! Regenerates Table 1, decision-tree block (experiment T1-DT in
//! DESIGN.md). Quick scale by default; BENCH_FULL=1 for (500, 100, 10).

mod common;

use backbone_learn::bench_support::{render_table, run_decision_tree_block};
use backbone_learn::config::Problem;

fn main() {
    let cfg = common::configure(Problem::DecisionTrees);
    let rows = run_decision_tree_block(&cfg).expect("block failed");
    println!(
        "{}",
        render_table(
            &format!("Table 1 — Decision Trees (n,p,k)=({},{},{})", cfg.n, cfg.p, cfg.k),
            &rows
        )
    );
}
