//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and serves
//! them to the backbone hot paths.
//!
//! `make artifacts` (Python, build-time only) lowers the L2 entry points
//! to HLO **text** under `artifacts/` plus a `manifest.json` describing
//! each shape-specialized entry. At run time, [`Engine`] parses the
//! manifest, compiles executables lazily through the PJRT CPU client
//! (`xla` crate), and memoizes them. Python never runs on this path.
//!
//! Shape policy:
//! - **rows (n) must match exactly** — padding rows would corrupt the
//!   column means inside `screen_utilities` and the residuals inside
//!   `iht_solve`;
//! - **feature counts are bucketed**: inputs are zero-padded on the right
//!   up to the artifact's `p`. Zero columns produce zero utilities and are
//!   never selected by IHT's top-k (proven in `python/tests/test_model.py`
//!   and re-checked in `rust/tests/integration_runtime.rs`).
//!
//! Every consumer has a pure-Rust fallback ([`Backend`] decides), so the
//! system works without artifacts — just without the AOT fast path.

// The real engine needs the `xla` crate (PJRT bindings); builds without
// the `pjrt` feature get an API-identical stub whose `Engine::load`
// always errors, so every consumer transparently falls back to the
// pure-Rust implementations.
#[cfg(feature = "pjrt")]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;

pub use engine::{Engine, ManifestEntry};

use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solvers::cd::{l0_fit_with, polish_to_model, L0Config, L0Model, L0Workspace};
use crate::solvers::kmeans::{kmeans_fit_with, KMeansConfig, KMeansModel, KMeansWorkspace};
use std::sync::Arc;

/// Which engine executes dense numeric hot paths.
#[derive(Debug, Clone, Default)]
pub enum Backend {
    /// Pure-Rust implementations.
    #[default]
    Native,
    /// AOT JAX/Pallas artifacts via PJRT, with native fallback when no
    /// shape bucket matches.
    Pjrt(Arc<Engine>),
}

impl Backend {
    /// Load the PJRT backend from an artifacts directory.
    pub fn pjrt_from_dir(dir: &str) -> anyhow::Result<Backend> {
        Ok(Backend::Pjrt(Arc::new(Engine::load(dir)?)))
    }

    /// True if this backend has a live PJRT engine.
    pub fn is_pjrt(&self) -> bool {
        matches!(self, Backend::Pjrt(_))
    }

    /// Screening utilities |corr(x_j, y)|.
    pub fn correlation_utilities(&self, x: &Matrix, y: &[f64]) -> Vec<f64> {
        if let Backend::Pjrt(engine) = self {
            if let Ok(Some(u)) = engine.screen_utilities(x, y) {
                return u;
            }
        }
        crate::backbone::screen::correlation_utilities(x, y)
    }

    /// L0 heuristic subproblem fit (IHT support + ridge polish on the PJRT
    /// path; full native CD/IHT/swap heuristic otherwise). `ws` is the
    /// caller-owned scratch of the native path — the backbone passes one
    /// per worker thread so repeated subproblem fits reuse buffers.
    pub fn l0_subproblem_fit(
        &self,
        x: &Matrix,
        y: &[f64],
        cfg: &L0Config,
        ws: &mut L0Workspace,
    ) -> L0Model {
        if let Backend::Pjrt(engine) = self {
            if let Ok(Some(support)) = engine.iht_support(x, y, cfg.k) {
                return polish_to_model(x, y, &support, cfg.lambda2);
            }
        }
        l0_fit_with(x, y, cfg, ws)
    }

    /// k-means fit: kmeans++ seeding is always native (cheap, branchy);
    /// the Lloyd iterations run through the AOT `lloyd_step` artifact when
    /// a shape bucket matches. `ws` is the native path's caller-owned
    /// scratch (one per backbone worker thread).
    pub fn kmeans(
        &self,
        x: &Matrix,
        cfg: &KMeansConfig,
        rng: &mut Rng,
        ws: &mut KMeansWorkspace,
    ) -> KMeansModel {
        if let Backend::Pjrt(engine) = self {
            if engine.has_lloyd(x.rows(), x.cols(), cfg.k) {
                if let Ok(Some(model)) = engine.kmeans_via_lloyd(x, cfg, rng) {
                    return model;
                }
            }
        }
        kmeans_fit_with(x, cfg, rng, ws)
    }
}

/// Human-readable summary of the artifacts directory.
pub fn describe_artifacts(dir: &str) -> anyhow::Result<String> {
    let engine = Engine::load(dir)?;
    Ok(engine.describe())
}
