//! The PJRT engine: manifest parsing, lazy compilation, shape-bucket
//! selection, padding, and execution of the AOT artifacts.

use crate::json::Json;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solvers::kmeans::{KMeansConfig, KMeansModel};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// One shape-specialized artifact from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub n: usize,
    /// Feature count (screen/iht) — 0 for lloyd entries.
    pub p: usize,
    /// Sparsity k (iht) / cluster count (lloyd) — 0 elsewhere.
    pub k: usize,
    /// Dimension d (lloyd only).
    pub d: usize,
    /// IHT iterations (iht only).
    pub iters: usize,
}

/// Loads artifacts and executes them on the PJRT CPU client.
pub struct Engine {
    dir: PathBuf,
    entries: Vec<ManifestEntry>,
    client: xla::PjRtClient,
    // File name → compiled executable (lazy, memoized). A mutex (not
    // RefCell) so the engine is Sync: backbone learners holding a
    // `Backend` are shared by reference across the parallel subproblem
    // scheduler's worker threads. Compilation is rare (once per shape
    // bucket); the lock is uncontended on the hot path.
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    // Serializes ALL PJRT FFI access (client + executables): the `xla`
    // crate's wrappers are not thread-safe, so every public entry point
    // that touches them funnels through `run()`/`describe()`, which take
    // this gate first. Workers therefore time-slice the engine rather
    // than race it — the native fallbacks carry the parallel speedup.
    gate: Mutex<()>,
}

// SAFETY: the `xla` FFI wrapper types are !Send/!Sync, but every code
// path that dereferences them (`compile` → only called from `run`;
// `run`; `describe`) executes under the `gate` mutex, so no two threads
// ever access the PJRT client or an executable concurrently, and the
// PJRT CPU client has no thread-affinity requirements. The cache map
// itself is independently synchronized.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Engine({} entries from {:?})", self.entries.len(), self.dir)
    }
}

impl Engine {
    /// Parse `dir/manifest.json` and start the PJRT CPU client.
    pub fn load(dir: &str) -> Result<Engine> {
        let dir = PathBuf::from(dir);
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        let entries_json = doc
            .require("entries")?
            .as_array()
            .ok_or_else(|| anyhow!("manifest `entries` must be an array"))?;
        let geti = |e: &Json, key: &str| -> usize {
            e.get(key).and_then(Json::as_usize).unwrap_or(0)
        };
        let mut entries = Vec::new();
        for e in entries_json {
            entries.push(ManifestEntry {
                kind: e
                    .require("kind")?
                    .as_str()
                    .ok_or_else(|| anyhow!("entry `kind` must be a string"))?
                    .to_string(),
                file: e
                    .require("file")?
                    .as_str()
                    .ok_or_else(|| anyhow!("entry `file` must be a string"))?
                    .to_string(),
                n: geti(e, "n"),
                p: geti(e, "p"),
                k: geti(e, "k"),
                d: geti(e, "d"),
                iters: geti(e, "iters"),
            });
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(Engine {
            dir,
            entries,
            client,
            cache: Mutex::new(HashMap::new()),
            gate: Mutex::new(()),
        })
    }

    /// All manifest entries.
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// Table of entries for `backbone-learn artifacts`.
    pub fn describe(&self) -> String {
        let _gate = self.gate.lock().unwrap(); // platform_name is FFI
        let mut out = format!(
            "{} artifacts on platform `{}`:\n",
            self.entries.len(),
            self.client.platform_name()
        );
        for e in &self.entries {
            out.push_str(&format!(
                "  {:<8} n={:<5} p={:<5} k={:<3} d={:<2} iters={:<4} {}\n",
                e.kind, e.n, e.p, e.k, e.d, e.iters, e.file
            ));
        }
        out
    }

    fn compile(&self, file: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // Hold the lock across compilation so concurrent batch workers
        // hitting the same shape bucket compile each artifact exactly
        // once (compilation is the expensive step this cache amortizes;
        // it only runs once per file, so the coarse critical section is
        // never on the steady-state hot path).
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {file}: {e}"))?,
        );
        cache.insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    fn literal_matrix_f32(m: &Matrix) -> Result<xla::Literal> {
        let flat = m.to_f32();
        xla::Literal::vec1(&flat)
            .reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| anyhow!("reshape literal: {e}"))
    }

    fn literal_vec_f32(v: &[f64]) -> xla::Literal {
        let flat: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        xla::Literal::vec1(&flat)
    }

    fn run(&self, file: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        // One worker in the PJRT runtime at a time (see the Sync SAFETY
        // note on `Engine`): compilation and execution both happen under
        // the gate.
        let _gate = self.gate.lock().unwrap();
        let exe = self.compile(file)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {file}: {e}"))?;
        result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {file}: {e}"))
    }

    // --- Entry selection ---------------------------------------------------

    fn find_screen(&self, n: usize, p: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "screen" && e.n == n && e.p >= p)
            .min_by_key(|e| e.p)
    }

    fn find_iht(&self, n: usize, p: usize, k: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == "iht" && e.n == n && e.k == k && e.p >= p)
            .min_by_key(|e| e.p)
    }

    fn find_lloyd(&self, n: usize, d: usize, k: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "lloyd" && e.n == n && e.d == d && e.k == k)
    }

    /// Whether a Lloyd artifact exists for this exact shape.
    pub fn has_lloyd(&self, n: usize, d: usize, k: usize) -> bool {
        self.find_lloyd(n, d, k).is_some()
    }

    // --- Hot-path entry points ----------------------------------------------
    //
    // All return Ok(None) when no shape bucket matches (caller falls back
    // to the native implementation) and Err only on real failures.

    /// |corr(x_j, y)| screening utilities via the AOT artifact.
    pub fn screen_utilities(&self, x: &Matrix, y: &[f64]) -> Result<Option<Vec<f64>>> {
        let Some(entry) = self.find_screen(x.rows(), x.cols()) else {
            return Ok(None);
        };
        let xp = x.pad_columns(entry.p);
        let x_lit = Self::literal_matrix_f32(&xp)?;
        let y_lit = Self::literal_vec_f32(y);
        let out = self.run(&entry.file, &[x_lit, y_lit])?;
        let u = out
            .to_tuple1()
            .map_err(|e| anyhow!("untupling screen output: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("reading screen output: {e}"))?;
        if u.len() != entry.p {
            bail!("screen output length {} != bucket p {}", u.len(), entry.p);
        }
        Ok(Some(u[..x.cols()].iter().map(|&v| v as f64).collect()))
    }

    /// IHT support via the AOT artifact: indices of nonzero coefficients
    /// of the k-sparse solve (padded columns can never enter — they have
    /// zero gradient).
    pub fn iht_support(&self, x: &Matrix, y: &[f64], k: usize) -> Result<Option<Vec<usize>>> {
        let Some(entry) = self.find_iht(x.rows(), x.cols(), k) else {
            return Ok(None);
        };
        let xp = x.pad_columns(entry.p);
        let x_lit = Self::literal_matrix_f32(&xp)?;
        let y_lit = Self::literal_vec_f32(y);
        let out = self.run(&entry.file, &[x_lit, y_lit])?;
        let beta = out
            .to_tuple1()
            .map_err(|e| anyhow!("untupling iht output: {e}"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("reading iht output: {e}"))?;
        let mut support: Vec<usize> = beta
            .iter()
            .take(x.cols())
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, _)| j)
            .collect();
        // The artifact thresholds with `|z| >= kth-largest`, so magnitude
        // ties can momentarily admit > k entries: keep the k largest.
        if support.len() > k {
            support.sort_by(|&a, &b| {
                beta[b].abs().partial_cmp(&beta[a].abs()).unwrap()
            });
            support.truncate(k);
            support.sort_unstable();
        }
        Ok(Some(support))
    }

    /// One Lloyd step via the AOT artifact → (centroids, labels, inertia).
    pub fn lloyd_step(
        &self,
        points: &Matrix,
        centroids: &Matrix,
    ) -> Result<Option<(Matrix, Vec<usize>, f64)>> {
        let (n, d) = (points.rows(), points.cols());
        let k = centroids.rows();
        let Some(entry) = self.find_lloyd(n, d, k) else {
            return Ok(None);
        };
        let p_lit = Self::literal_matrix_f32(points)?;
        let c_lit = Self::literal_matrix_f32(centroids)?;
        let out = self.run(&entry.file, &[p_lit, c_lit])?;
        let (c_out, l_out, i_out) = out
            .to_tuple3()
            .map_err(|e| anyhow!("untupling lloyd output: {e}"))?;
        let c_flat = c_out.to_vec::<f32>().map_err(|e| anyhow!("centroids: {e}"))?;
        let labels_raw = l_out.to_vec::<i32>().map_err(|e| anyhow!("labels: {e}"))?;
        let inertia = i_out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("inertia: {e}"))?
            .first()
            .copied()
            .unwrap_or(f32::NAN) as f64;
        let centroids_new =
            Matrix::from_vec(k, d, c_flat.iter().map(|&v| v as f64).collect());
        let labels: Vec<usize> = labels_raw.iter().map(|&l| l.max(0) as usize).collect();
        Ok(Some((centroids_new, labels, inertia)))
    }

    /// Full k-means via AOT Lloyd steps (native kmeans++ seeding, native
    /// convergence control). Returns None if no artifact matches.
    pub fn kmeans_via_lloyd(
        &self,
        x: &Matrix,
        cfg: &KMeansConfig,
        rng: &mut Rng,
    ) -> Result<Option<KMeansModel>> {
        if self.find_lloyd(x.rows(), x.cols(), cfg.k).is_none() {
            return Ok(None);
        }
        let mut best: Option<KMeansModel> = None;
        for _ in 0..cfg.n_init.max(1) {
            // Native kmeans++ seeding (branchy / RNG-driven).
            let seeds = crate::solvers::kmeans::kmeans_fit(
                x,
                &KMeansConfig { k: cfg.k, n_init: 1, max_iter: 0, tol: cfg.tol },
                rng,
            );
            let mut centroids = seeds.centroids;
            let mut labels = vec![0usize; x.rows()];
            let mut inertia = f64::INFINITY;
            let mut iterations = 0;
            for it in 0..cfg.max_iter {
                iterations = it + 1;
                let Some((c_new, l_new, i_new)) = self.lloyd_step(x, &centroids)? else {
                    return Ok(None);
                };
                let movement: f64 = (0..cfg.k)
                    .map(|c| crate::linalg::sqdist(centroids.row(c), c_new.row(c)))
                    .sum();
                centroids = c_new;
                labels = l_new;
                inertia = i_new;
                if movement < cfg.tol {
                    break;
                }
            }
            let model = KMeansModel { labels, centroids, inertia, iterations };
            if best.as_ref().map_or(true, |b| model.inertia < b.inertia) {
                best = Some(model);
            }
        }
        Ok(best)
    }
}
