//! API-identical stand-in for the PJRT engine, compiled when the `pjrt`
//! feature (and with it the `xla` crate) is disabled.
//!
//! [`Engine::load`] always errors, so [`super::Backend::pjrt_from_dir`]
//! fails cleanly and callers fall back to [`super::Backend::Native`] — the
//! bit-compatible pure-Rust implementations. The hot-path entry points
//! exist only so `Backend` compiles unchanged; they are unreachable
//! because no `Engine` value can be constructed.
//!
//! This try-artifact-else-fall-back seam is the accelerator-level twin of
//! the CPU kernel seam in [`crate::linalg`] (`ComputeBackend`): both pick
//! the fastest available implementation at runtime behind one stable call
//! site, and both keep the portable implementation as the always-correct
//! fallback. A future device backend plugs in here; a future ISA backend
//! (AVX-512, NEON) plugs into `linalg::backend`.

use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::solvers::kmeans::{KMeansConfig, KMeansModel};
use anyhow::{bail, Result};

/// One shape-specialized artifact from `manifest.json` (mirror of the
/// real engine's type so `Engine::entries()` keeps its signature).
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub n: usize,
    /// Feature count (screen/iht) — 0 for lloyd entries.
    pub p: usize,
    /// Sparsity k (iht) / cluster count (lloyd) — 0 elsewhere.
    pub k: usize,
    /// Dimension d (lloyd only).
    pub d: usize,
    /// IHT iterations (iht only).
    pub iters: usize,
}

/// Stub engine: carries no state and cannot be constructed.
#[derive(Debug)]
pub struct Engine {
    entries: Vec<ManifestEntry>,
}

impl Engine {
    /// Always errors: this build has no PJRT support.
    pub fn load(_dir: &str) -> Result<Engine> {
        bail!(
            "built without the `pjrt` feature — AOT artifacts unavailable, \
             using the native backend"
        )
    }

    /// All manifest entries (empty; unreachable without `load`).
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// Table of entries for `backbone-learn artifacts`.
    pub fn describe(&self) -> String {
        "0 artifacts (built without the `pjrt` feature)\n".to_string()
    }

    /// Whether a Lloyd artifact exists for this exact shape (never).
    pub fn has_lloyd(&self, _n: usize, _d: usize, _k: usize) -> bool {
        false
    }

    /// No artifact ever matches: callers fall back to native.
    pub fn screen_utilities(&self, _x: &Matrix, _y: &[f64]) -> Result<Option<Vec<f64>>> {
        Ok(None)
    }

    /// No artifact ever matches: callers fall back to native.
    pub fn iht_support(
        &self,
        _x: &Matrix,
        _y: &[f64],
        _k: usize,
    ) -> Result<Option<Vec<usize>>> {
        Ok(None)
    }

    /// No artifact ever matches: callers fall back to native.
    pub fn lloyd_step(
        &self,
        _points: &Matrix,
        _centroids: &Matrix,
    ) -> Result<Option<(Matrix, Vec<usize>, f64)>> {
        Ok(None)
    }

    /// No artifact ever matches: callers fall back to native.
    pub fn kmeans_via_lloyd(
        &self,
        _x: &Matrix,
        _cfg: &KMeansConfig,
        _rng: &mut Rng,
    ) -> Result<Option<KMeansModel>> {
        Ok(None)
    }
}
