//! Continuous/discrete distributions on top of [`Rng`](super::Rng).

use super::Rng;

impl Rng {
    /// Standard normal draw via the Marsaglia polar method.
    ///
    /// We deliberately do not cache the second variate: caching makes the
    /// consumed-stream length depend on call parity, which breaks the
    /// reproducibility contract when generators are forked mid-sequence.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal draw with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill `buf` with iid standard normals.
    pub fn fill_normal(&mut self, buf: &mut [f64]) {
        for x in buf.iter_mut() {
            *x = self.normal();
        }
    }

    /// Exponential draw with rate `lambda` (inverse-CDF method).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // 1 - U avoids ln(0).
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Draw from a categorical distribution given (unnormalized,
    /// non-negative) weights. Returns the selected index.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "categorical: weights must have positive finite sum"
        );
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            debug_assert!(w >= 0.0);
            target -= w;
            if target < 0.0 {
                return i;
            }
        }
        weights.len() - 1 // floating-point slack: land on the last bucket
    }

    /// Rademacher draw (±1 with equal probability).
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::rng::Rng;

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from_u64(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn normal_with_shift_scale() {
        let mut rng = Rng::seed_from_u64(19);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_with(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.03, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::seed_from_u64(23);
        let n = 100_000;
        let mean = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Rng::seed_from_u64(29);
        let weights = [1.0, 2.0, 7.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&weights)] += 1;
        }
        let freqs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        assert!((freqs[0] - 0.1).abs() < 0.01);
        assert!((freqs[1] - 0.2).abs() < 0.01);
        assert!((freqs[2] - 0.7).abs() < 0.01);
    }

    #[test]
    fn categorical_handles_zero_weights() {
        let mut rng = Rng::seed_from_u64(31);
        for _ in 0..1000 {
            let idx = rng.categorical(&[0.0, 1.0, 0.0]);
            assert_eq!(idx, 1);
        }
    }

    #[test]
    fn rademacher_balanced() {
        let mut rng = Rng::seed_from_u64(37);
        let sum: f64 = (0..100_000).map(|_| rng.rademacher()).sum();
        assert!(sum.abs() < 2_000.0);
    }
}
