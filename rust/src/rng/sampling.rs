//! Shuffling and sampling utilities used by subproblem construction.

use super::Rng;

impl Rng {
    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` uniformly at random.
    ///
    /// Uses Floyd's algorithm for small `k` relative to `n` (no O(n)
    /// allocation), falling back to a partial shuffle otherwise.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k == 0 {
            return Vec::new();
        }
        if k * 4 <= n {
            // Floyd's: for j in n-k..n, pick t in [0, j]; insert t or j.
            let mut chosen: Vec<usize> = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.usize_below(j + 1);
                if chosen.contains(&t) {
                    chosen.push(j);
                } else {
                    chosen.push(t);
                }
            }
            chosen.sort_unstable();
            chosen
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            // Partial Fisher–Yates: fix positions 0..k.
            for i in 0..k {
                let j = i + self.usize_below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx.sort_unstable();
            idx
        }
    }

    /// Sample `k` distinct elements from `pool` uniformly (returned in
    /// pool order).
    pub fn sample_from<T: Copy>(&mut self, pool: &[T], k: usize) -> Vec<T> {
        self.sample_indices(pool.len(), k)
            .into_iter()
            .map(|i| pool[i])
            .collect()
    }

    /// Weighted sampling *without* replacement via the Efraimidis–Spirakis
    /// exponential-keys method: each item gets key `u^(1/w)`; take the `k`
    /// largest. Items with zero weight are never selected unless fewer than
    /// `k` positive-weight items exist.
    pub fn weighted_sample_without_replacement(
        &mut self,
        weights: &[f64],
        k: usize,
    ) -> Vec<usize> {
        let n = weights.len();
        assert!(k <= n);
        let mut keyed: Vec<(f64, usize)> = weights
            .iter()
            .enumerate()
            .map(|(i, &w)| {
                debug_assert!(w >= 0.0, "negative weight");
                let key = if w > 0.0 {
                    // log-key for numerical stability: ln(u)/w
                    (self.next_f64().max(1e-300)).ln() / w
                } else {
                    f64::NEG_INFINITY
                };
                (key, i)
            })
            .collect();
        keyed.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut out: Vec<usize> = keyed.into_iter().take(k).map(|(_, i)| i).collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::rng::Rng;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from_u64(41);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::seed_from_u64(43);
        for (n, k) in [(10, 3), (100, 90), (5, 5), (1000, 10), (7, 0)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            for w in s.windows(2) {
                assert!(w[0] < w[1], "not strictly sorted/distinct: {s:?}");
            }
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_uniformity() {
        // Each of 10 items should appear in a k=3 sample with prob 0.3.
        let mut rng = Rng::seed_from_u64(47);
        let mut counts = [0usize; 10];
        let reps = 30_000;
        for _ in 0..reps {
            for i in rng.sample_indices(10, 3) {
                counts[i] += 1;
            }
        }
        for &c in &counts {
            let f = c as f64 / reps as f64;
            assert!((f - 0.3).abs() < 0.02, "freq={f}");
        }
    }

    #[test]
    fn weighted_sample_prefers_heavy_items() {
        let mut rng = Rng::seed_from_u64(53);
        let weights = [0.01, 0.01, 10.0, 10.0, 0.01];
        let mut hit2 = 0;
        let reps = 2000;
        for _ in 0..reps {
            let s = rng.weighted_sample_without_replacement(&weights, 2);
            assert_eq!(s.len(), 2);
            if s.contains(&2) && s.contains(&3) {
                hit2 += 1;
            }
        }
        assert!(hit2 as f64 / reps as f64 > 0.95, "hit2={hit2}");
    }

    #[test]
    fn weighted_sample_zero_weight_excluded() {
        let mut rng = Rng::seed_from_u64(59);
        let weights = [0.0, 1.0, 1.0, 0.0];
        for _ in 0..500 {
            let s = rng.weighted_sample_without_replacement(&weights, 2);
            assert_eq!(s, vec![1, 2]);
        }
    }

    #[test]
    fn sample_from_preserves_pool_values() {
        let mut rng = Rng::seed_from_u64(61);
        let pool = [10usize, 20, 30, 40, 50];
        let s = rng.sample_from(&pool, 3);
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|v| pool.contains(v)));
    }
}
