//! Deterministic pseudo-random number generation.
//!
//! The paper's experiments are Monte-Carlo averages over 10 repetitions of
//! synthetic data; bit-for-bit reproducibility across runs (and across the
//! PJRT / pure-Rust execution paths) requires a self-contained RNG rather
//! than platform `rand`. We implement **xoshiro256++** (Blackman & Vigna)
//! seeded via **SplitMix64**, plus the distributions the generators and
//! solvers need: uniform floats/ints, standard normal (polar method),
//! Fisher–Yates shuffling, and weighted / uniform sampling without
//! replacement.

mod distributions;
mod sampling;

/// xoshiro256++ PRNG. Deterministic, seedable, `Clone` for stream forking.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator from a single `u64` via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not be seeded with all zeros; SplitMix64 of any seed
        // cannot produce four zero outputs, but guard anyway.
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits; divide by 2^53.
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fork an independent child stream (jump-free splitting: reseed from
    /// the parent's output mixed through SplitMix64). Streams are
    /// statistically independent for our Monte-Carlo purposes.
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut rng = Rng::seed_from_u64(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_respects_bound_and_covers() {
        let mut rng = Rng::seed_from_u64(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn bernoulli_rate() {
        let mut rng = Rng::seed_from_u64(5);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn fork_streams_differ_from_parent() {
        let mut parent = Rng::seed_from_u64(9);
        let mut child = parent.fork();
        let equal = (0..64).filter(|_| parent.next_u64() == child.next_u64()).count();
        assert!(equal < 2);
    }

    #[test]
    fn uniform_range() {
        let mut rng = Rng::seed_from_u64(13);
        for _ in 0..1000 {
            let x = rng.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }
}
