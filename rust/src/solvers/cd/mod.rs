//! Coordinate-descent solvers for sparse regression heuristics.
//!
//! - [`elastic_net`] — GLMNet-style cyclic coordinate descent with an
//!   active-set strategy and a warm-started regularization path.
//! - [`l0`] — L0Learn-style heuristic for L0L2-regularized regression:
//!   iterative hard thresholding (IHT) with ridge polishing plus local
//!   swap search.
//!
//! Both serve two roles in the paper's experiments: standalone heuristic
//! *baselines* (the GLMNet row of Table 1) and the backbone's
//! `fit_subproblem` workhorse.

pub mod elastic_net;
pub mod l0;

pub use elastic_net::{
    elastic_net_fit, elastic_net_path, ElasticNetConfig, ElasticNetModel, ElasticNetPath,
};
pub use l0::{
    l0_fit, l0_fit_with, polish_support, polish_support_cached, polish_to_model, L0Config,
    L0Model, L0Workspace,
};

/// Soft-thresholding operator `S(z, γ) = sign(z) · max(|z| − γ, 0)`.
#[inline]
pub fn soft_threshold(z: f64, gamma: f64) -> f64 {
    if z > gamma {
        z - gamma
    } else if z < -gamma {
        z + gamma
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::soft_threshold;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }
}
