//! L0Learn-style heuristic for L0L2-regularized sparse regression.
//!
//! Solves `min ‖y − Xβ‖² + λ₂‖β‖₂²  s.t. ‖β‖₀ ≤ k` approximately via
//! **iterative hard thresholding** (projected gradient on the sparsity
//! ball with a Lipschitz step) followed by a ridge polish on the selected
//! support and a **local swap search** (try exchanging support features
//! for the most correlated excluded ones), the combination L0Learn's
//! `CDPSI` algorithm popularized.
//!
//! This routine is the default `fit_subproblem` for the sparse-regression
//! backbone. When a PJRT artifact of matching shape is available, the IHT
//! iterations run through the AOT-compiled JAX/Pallas kernel instead (see
//! `runtime::iht`); this pure-Rust implementation is the fallback and the
//! cross-check oracle.

use crate::linalg::{dot, least_squares, Matrix};

/// L0 heuristic hyperparameters.
#[derive(Debug, Clone)]
pub struct L0Config {
    /// Target support size (number of nonzeros).
    pub k: usize,
    /// Ridge penalty λ₂.
    pub lambda2: f64,
    /// IHT iterations.
    pub max_iter: usize,
    /// Convergence tolerance on the support (stop early when unchanged).
    pub patience: usize,
    /// Local-swap improvement rounds after IHT.
    pub swap_rounds: usize,
}

impl Default for L0Config {
    fn default() -> Self {
        Self { k: 10, lambda2: 1e-3, max_iter: 100, patience: 3, swap_rounds: 2 }
    }
}

/// A fitted L0 model.
#[derive(Debug, Clone)]
pub struct L0Model {
    /// Dense coefficient vector (nonzeros exactly on `support`).
    pub beta: Vec<f64>,
    pub intercept: f64,
    /// Sorted support indices.
    pub support: Vec<usize>,
    /// Training objective ‖y − ŷ‖² + λ₂‖β‖².
    pub objective: f64,
}

/// Reusable scratch buffers for [`l0_fit_with`]: the IHT iterate, its
/// gradient/residual vectors and the top-k index buffer, plus a reusable
/// design-matrix buffer for callers that restrict columns per fit.
///
/// One workspace serves any problem shape — buffers are resized on entry —
/// so a single `Default`-constructed workspace can be reused across every
/// subproblem a worker thread solves. Contents never affect results: every
/// buffer is overwritten before it is read.
#[derive(Debug, Clone, Default)]
pub struct L0Workspace {
    /// Caller-owned column-restricted design matrix (`select_columns_into`).
    pub xs: crate::linalg::Matrix,
    beta: Vec<f64>,
    pred: Vec<f64>,
    resid: Vec<f64>,
    grad: Vec<f64>,
    z: Vec<f64>,
    idx: Vec<usize>,
}

impl L0Model {
    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        x.matvec(&self.beta).iter().map(|v| v + self.intercept).collect()
    }
}

/// Largest-magnitude `k` indices of `v` (ties broken by lower index).
fn top_k_indices(v: &[f64], k: usize) -> Vec<usize> {
    top_k_indices_with(v, k, &mut Vec::new())
}

/// [`top_k_indices`] reusing a caller-owned index buffer for the sort.
fn top_k_indices_with(v: &[f64], k: usize, idx: &mut Vec<usize>) -> Vec<usize> {
    idx.clear();
    idx.extend(0..v.len());
    idx.sort_by(|&a, &b| {
        v[b].abs().partial_cmp(&v[a].abs()).unwrap().then(a.cmp(&b))
    });
    let mut top: Vec<usize> = idx.iter().copied().take(k).collect();
    top.sort_unstable();
    top
}

/// Ridge refit restricted to `support`; returns (dense beta, intercept,
/// objective).
fn polish(
    x: &Matrix,
    y: &[f64],
    support: &[usize],
    lambda2: f64,
) -> (Vec<f64>, f64, f64) {
    let p = x.cols();
    if support.is_empty() {
        let intercept = crate::linalg::mean(y);
        let obj: f64 = y.iter().map(|v| (v - intercept) * (v - intercept)).sum();
        return (vec![0.0; p], intercept, obj);
    }
    let xs = x.select_columns(support);
    // Center y for the intercept, then refit.
    let y_mean = crate::linalg::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    let means = xs.col_means();
    let mut xc = xs.clone();
    for i in 0..xc.rows() {
        let row = xc.row_mut(i);
        for (j, m) in means.iter().enumerate() {
            row[j] -= m;
        }
    }
    let beta_s = least_squares(&xc, &yc, lambda2).unwrap_or_else(|_| vec![0.0; support.len()]);
    let mut beta = vec![0.0; p];
    let mut intercept = y_mean;
    for (jj, &j) in support.iter().enumerate() {
        beta[j] = beta_s[jj];
        intercept -= beta_s[jj] * means[jj];
    }
    let pred = x.matvec(&beta);
    let obj: f64 = y
        .iter()
        .zip(&pred)
        .map(|(yv, pv)| {
            let r = yv - pv - intercept;
            r * r
        })
        .sum::<f64>()
        + lambda2 * dot(&beta, &beta);
    (beta, intercept, obj)
}

/// Power-iteration estimate of the largest eigenvalue of `XᵀX / n` —
/// the IHT step size is `1 / L` with `L` this spectral bound (times n).
/// Borrows the workspace's `z`/`pred`/`grad` buffers for the iteration.
fn lipschitz_estimate(x: &Matrix, ws: &mut L0Workspace) -> f64 {
    let p = x.cols();
    ws.z.clear();
    ws.z.resize(p, 1.0 / (p as f64).sqrt());
    let mut lam = 1.0;
    for _ in 0..20 {
        x.matvec_into(&ws.z, &mut ws.pred); // X v
        x.matvec_t_into(&ws.pred, &mut ws.grad); // Xᵀ X v
        let norm = crate::linalg::norm2(&ws.grad);
        if norm < 1e-12 {
            return 1.0;
        }
        lam = norm;
        for (vi, g) in ws.z.iter_mut().zip(&ws.grad) {
            *vi = g / norm;
        }
    }
    lam.max(1e-12)
}

/// Build an [`L0Model`] from a fixed support via ridge polish — the entry
/// point the PJRT runtime uses: the AOT IHT artifact supplies the support,
/// and this refit supplies exact coefficients/objective (identical to what
/// [`l0_fit`] does after its own IHT phase).
pub fn polish_to_model(x: &Matrix, y: &[f64], support: &[usize], lambda2: f64) -> L0Model {
    let mut support = support.to_vec();
    support.sort_unstable();
    support.dedup();
    let (beta, intercept, objective) = polish(x, y, &support, lambda2);
    L0Model { beta, intercept, support, objective }
}

/// Fit via IHT + polish + local swaps (one-shot scratch; see
/// [`l0_fit_with`] for the allocation-reusing entry point).
pub fn l0_fit(x: &Matrix, y: &[f64], cfg: &L0Config) -> L0Model {
    l0_fit_with(x, y, cfg, &mut L0Workspace::default())
}

/// Fit via IHT + polish + local swaps, borrowing caller-owned scratch —
/// the entry point of the backbone's `fit_subproblem` hot loop, where one
/// workspace is reused across every subproblem a worker thread solves.
/// Bit-identical to [`l0_fit`] for any workspace state.
pub fn l0_fit_with(x: &Matrix, y: &[f64], cfg: &L0Config, ws: &mut L0Workspace) -> L0Model {
    assert_eq!(x.rows(), y.len());
    let p = x.cols();
    let k = cfg.k.min(p);
    if k == 0 || p == 0 {
        let (beta, intercept, objective) = polish(x, y, &[], cfg.lambda2);
        return L0Model { beta, intercept, support: vec![], objective };
    }

    // --- IHT phase -------------------------------------------------------
    let lip = lipschitz_estimate(x, ws) + cfg.lambda2;
    let step = 1.0 / lip;
    ws.beta.clear();
    ws.beta.resize(p, 0.0);
    let mut support: Vec<usize> = Vec::new();
    let mut stable = 0;
    for _ in 0..cfg.max_iter {
        // gradient of ½‖y−Xβ‖² + ½λ₂‖β‖² : −Xᵀ(y−Xβ) + λ₂β
        x.matvec_into(&ws.beta, &mut ws.pred);
        ws.resid.clear();
        ws.resid.extend(y.iter().zip(&ws.pred).map(|(yv, pv)| yv - pv));
        x.matvec_t_into(&ws.resid, &mut ws.grad); // = Xᵀ r
        ws.z.clear();
        ws.z.extend(
            ws.beta
                .iter()
                .zip(&ws.grad)
                .map(|(&b, &g)| b + step * (g - cfg.lambda2 * b)),
        );
        let new_support = top_k_indices_with(&ws.z, k, &mut ws.idx);
        ws.beta.iter_mut().for_each(|b| *b = 0.0);
        for &j in &new_support {
            ws.beta[j] = ws.z[j];
        }
        if new_support == support {
            stable += 1;
            if stable >= cfg.patience {
                break;
            }
        } else {
            stable = 0;
        }
        support = new_support;
    }
    // The last IHT iterate feeds the polish below via `support`.

    // --- Polish ----------------------------------------------------------
    let (mut beta, mut intercept, mut objective) = polish(x, y, &support, cfg.lambda2);

    // --- Local swap search -------------------------------------------------
    // For each swap round: compute the residual correlation of excluded
    // features; try swapping the weakest support member for the strongest
    // excluded candidate; keep if the polished objective improves.
    for _ in 0..cfg.swap_rounds {
        if support.is_empty() || support.len() >= p {
            break;
        }
        x.matvec_into(&beta, &mut ws.pred);
        ws.resid.clear();
        ws.resid.extend(
            y.iter().zip(&ws.pred).map(|(yv, pv)| yv - pv - intercept),
        );
        x.matvec_t_into(&ws.resid, &mut ws.grad);
        let corr = &ws.grad;
        // Strongest excluded candidate.
        let cand = (0..p)
            .filter(|j| !support.contains(j))
            .max_by(|&a, &b| corr[a].abs().partial_cmp(&corr[b].abs()).unwrap());
        let Some(cand) = cand else { break };
        // Weakest support member (smallest |beta|).
        let weakest_pos = support
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| beta[a].abs().partial_cmp(&beta[b].abs()).unwrap())
            .map(|(pos, _)| pos)
            .unwrap();
        let mut trial = support.clone();
        trial[weakest_pos] = cand;
        trial.sort_unstable();
        let (tb, ti, tobj) = polish(x, y, &trial, cfg.lambda2);
        if tobj + 1e-12 < objective {
            support = trial;
            beta = tb;
            intercept = ti;
            objective = tobj;
        } else {
            break; // local optimum
        }
    }

    L0Model { beta, intercept, support, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse_regression::{generate, SparseRegressionConfig};
    use crate::rng::Rng;

    #[test]
    fn top_k_selects_largest_magnitudes() {
        let v = [0.1, -5.0, 3.0, -0.2, 4.0];
        assert_eq!(top_k_indices(&v, 2), vec![1, 4]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&v, 5).len(), 5);
    }

    #[test]
    fn recovers_true_support_no_noise() {
        let cfg_data = SparseRegressionConfig { n: 80, p: 40, k: 4, rho: 0.0, snr: 0.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(1));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 4, ..Default::default() });
        assert_eq!(m.support, data.support_true);
        for &j in &data.support_true {
            assert!((m.beta[j].abs() - 1.0).abs() < 0.05, "beta[{j}]={}", m.beta[j]);
        }
    }

    #[test]
    fn recovers_support_with_noise_and_correlation() {
        let cfg_data = SparseRegressionConfig { n: 200, p: 100, k: 5, rho: 0.3, snr: 10.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(2));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 5, ..Default::default() });
        let rec = crate::metrics::support_recovery(&m.support, &data.support_true);
        assert!(rec.f1 >= 0.8, "f1={}", rec.f1);
        let r2 = crate::metrics::r2_score(&data.y, &m.predict(&data.x));
        assert!(r2 > 0.8, "r2={r2}");
    }

    #[test]
    fn respects_sparsity_budget() {
        let cfg_data = SparseRegressionConfig { n: 50, p: 30, k: 6, rho: 0.1, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(3));
        for k in [1, 3, 6, 10] {
            let m = l0_fit(&data.x, &data.y, &L0Config { k, ..Default::default() });
            assert!(m.support.len() <= k);
            let nnz = m.beta.iter().filter(|&&b| b != 0.0).count();
            assert_eq!(nnz, m.support.len());
        }
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh_scratch() {
        // One workspace reused across differently-shaped fits must give
        // exactly what fresh scratch gives — the contract that lets the
        // batch scheduler hand one workspace per worker thread.
        let mut ws = L0Workspace::default();
        for (n, p, k, seed) in [(40, 30, 3, 10), (60, 80, 5, 11), (25, 12, 2, 12)] {
            let cfg_data = SparseRegressionConfig { n, p, k, rho: 0.2, snr: 5.0 };
            let data = generate(&cfg_data, &mut Rng::seed_from_u64(seed));
            let cfg = L0Config { k, ..Default::default() };
            let fresh = l0_fit(&data.x, &data.y, &cfg);
            let reused = l0_fit_with(&data.x, &data.y, &cfg, &mut ws);
            assert_eq!(fresh.support, reused.support);
            assert_eq!(fresh.beta, reused.beta);
            assert_eq!(fresh.intercept, reused.intercept);
            assert_eq!(fresh.objective, reused.objective);
        }
    }

    #[test]
    fn k_zero_gives_intercept_only() {
        let cfg_data = SparseRegressionConfig { n: 30, p: 10, k: 2, rho: 0.0, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(4));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 0, ..Default::default() });
        assert!(m.support.is_empty());
        assert!((m.intercept - crate::linalg::mean(&data.y)).abs() < 1e-10);
    }

    #[test]
    fn swap_search_improves_greedy_mistake() {
        // Construct a trap: two features nearly collinear with the target
        // of a third. IHT may pick the decoy; swaps should fix or at least
        // not hurt the objective.
        let cfg_data = SparseRegressionConfig { n: 120, p: 60, k: 3, rho: 0.7, snr: 20.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(5));
        let no_swaps = l0_fit(
            &data.x,
            &data.y,
            &L0Config { k: 3, swap_rounds: 0, ..Default::default() },
        );
        let with_swaps = l0_fit(
            &data.x,
            &data.y,
            &L0Config { k: 3, swap_rounds: 5, ..Default::default() },
        );
        assert!(with_swaps.objective <= no_swaps.objective + 1e-9);
    }

    #[test]
    fn objective_matches_definition() {
        let cfg_data = SparseRegressionConfig { n: 40, p: 20, k: 3, rho: 0.0, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(6));
        let cfg = L0Config { k: 3, lambda2: 0.01, ..Default::default() };
        let m = l0_fit(&data.x, &data.y, &cfg);
        let pred = m.predict(&data.x);
        let rss: f64 = data.y.iter().zip(&pred).map(|(y, p)| (y - p) * (y - p)).sum();
        let expected = rss + cfg.lambda2 * crate::linalg::dot(&m.beta, &m.beta);
        assert!((m.objective - expected).abs() < 1e-8);
    }
}
