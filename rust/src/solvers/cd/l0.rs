//! L0Learn-style heuristic for L0L2-regularized sparse regression.
//!
//! Solves `min ‖y − Xβ‖² + λ₂‖β‖₂²  s.t. ‖β‖₀ ≤ k` approximately via
//! **iterative hard thresholding** (projected gradient on the sparsity
//! ball with a Lipschitz step) followed by a ridge polish on the selected
//! support and a **local swap search** (try exchanging support features
//! for the most correlated excluded ones), the combination L0Learn's
//! `CDPSI` algorithm popularized.
//!
//! Hot-path structure (the per-core cost the backbone multiplies by M
//! subproblems per iteration):
//!
//! - the polish builds the support's **centered Gram system**
//!   (`XsᵀXs`, `Xsᵀy`) in one O(nk²) row-major pass — no column gather,
//!   no matrix clone, no centering copy — and solves it by Cholesky;
//! - each swap-search trial is evaluated **incrementally**: the trial
//!   shares the retained (k−1)² Gram block with the current support, so
//!   the data-dependent work is only the candidate column's cross
//!   products (O(nk)) plus a bordered Cholesky update
//!   ([`crate::linalg::cholesky_bordered`], O(k²)) on top of an O(k³)
//!   refactorization of the retained block (k = support size, tiny next
//!   to n) — versus the previous per-trial column gather + centering +
//!   full normal-equations rebuild (O(nk² + k³), dominated by the
//!   O(nk²) Gram rebuild);
//! - trial objectives use the Gram quadratic form
//!   `yᵀy − 2βᵀb + βᵀGβ + λ₂‖β‖²` (O(k²)); the returned model's
//!   objective is recomputed once from the definition via the fused
//!   [`Matrix::residual_into`] pass.
//!
//! The straightforward full-refit polish is retained as
//! [`polish_support`] — the property-test oracle the Gram-cached path
//! ([`polish_support_cached`]) is checked against.
//!
//! This routine is the default `fit_subproblem` for the sparse-regression
//! backbone. When a PJRT artifact of matching shape is available, the IHT
//! iterations run through the AOT-compiled JAX/Pallas kernel instead (see
//! `runtime::iht`); this pure-Rust implementation is the fallback and the
//! cross-check oracle.

use crate::linalg::{
    cholesky, cholesky_bordered, dot, least_squares, solve_lower, solve_lower_transpose,
    Matrix,
};

/// L0 heuristic hyperparameters.
#[derive(Debug, Clone)]
pub struct L0Config {
    /// Target support size (number of nonzeros).
    pub k: usize,
    /// Ridge penalty λ₂.
    pub lambda2: f64,
    /// IHT iterations.
    pub max_iter: usize,
    /// Convergence tolerance on the support (stop early when unchanged).
    pub patience: usize,
    /// Local-swap improvement rounds after IHT.
    pub swap_rounds: usize,
    /// Optional warm start for the IHT phase: a dense length-p iterate
    /// (e.g. the relaxation solution of an enclosing branch-and-bound
    /// node, or a neighbouring cardinality's fit) projected onto the
    /// top-k magnitude set before the first iteration. Ignored when the
    /// length does not match the problem. Passing the warm start
    /// explicitly — instead of smuggling it through workspace state —
    /// keeps every fit a pure function of its inputs, which is the batch
    /// scheduler's determinism contract.
    pub warm_start: Option<Vec<f64>>,
}

impl Default for L0Config {
    fn default() -> Self {
        Self {
            k: 10,
            lambda2: 1e-3,
            max_iter: 100,
            patience: 3,
            swap_rounds: 2,
            warm_start: None,
        }
    }
}

/// A fitted L0 model.
#[derive(Debug, Clone)]
pub struct L0Model {
    /// Dense coefficient vector (nonzeros exactly on `support`).
    pub beta: Vec<f64>,
    pub intercept: f64,
    /// Sorted support indices.
    pub support: Vec<usize>,
    /// Training objective ‖y − ŷ‖² + λ₂‖β‖².
    pub objective: f64,
}

/// Reusable scratch buffers for [`l0_fit_with`]: the IHT iterate, its
/// gradient/residual vectors and the top-k index buffer, the support
/// membership mask of the swap search, the Gram-cached polish state
/// ([`PolishCache`]), plus a reusable design-matrix buffer for callers
/// that restrict columns per fit.
///
/// One workspace serves any problem shape — buffers are resized on entry —
/// so a single `Default`-constructed workspace can be reused across every
/// subproblem a worker thread solves. Contents never affect results: every
/// buffer is overwritten before it is read.
#[derive(Debug, Clone, Default)]
pub struct L0Workspace {
    /// Caller-owned column-restricted design matrix (`select_columns_into`).
    pub xs: crate::linalg::Matrix,
    beta: Vec<f64>,
    pred: Vec<f64>,
    resid: Vec<f64>,
    grad: Vec<f64>,
    z: Vec<f64>,
    idx: Vec<usize>,
    /// Support membership mask of the swap search (length p, reset per
    /// use) — replaces the O(p·k) `support.contains` scan of the
    /// candidate loop with O(p) lookups.
    mask: Vec<bool>,
    cache: PolishCache,
}

/// Centered Gram system of one support: `G = Σᵢ(xᵢ−m)(xᵢ−m)ᵀ`,
/// `b = Σᵢ(xᵢ−m)(yᵢ−ȳ)`, plus the column means and y statistics needed
/// to recover the intercept and the objective without touching `X`
/// again. Built in one O(nk²) row-major pass; kept in sync across
/// accepted swaps by splicing in the already-computed candidate cross
/// products (O(k)) instead of rebuilding.
#[derive(Debug, Clone, Default)]
struct PolishCache {
    /// Cache column order: feature ids, insertion order (not sorted).
    features: Vec<usize>,
    g: Matrix,
    xty: Vec<f64>,
    means: Vec<f64>,
    y_mean: f64,
    /// Centered yᵀy.
    yty: f64,
    /// Row-gather scratch of the build pass.
    srow: Vec<f64>,
    /// Scratch for `G + λI` submatrices handed to Cholesky.
    gl: Matrix,
    /// Candidate cross-product scratch of the swap trials.
    cross: Vec<f64>,
    /// Retained-feature ids scratch of the swap trials.
    rfeats: Vec<usize>,
    /// Retained cache-position scratch of the swap trials.
    rpos: Vec<usize>,
    /// Right-hand-side scratch of the swap trials.
    bbuf: Vec<f64>,
}

/// Everything a swap trial computes: the bordered solve's coefficients
/// (retained order then candidate), the trial objective, and the
/// candidate column's statistics (spliced into the cache on acceptance).
struct SwapEval {
    beta: Vec<f64>,
    intercept: f64,
    objective: f64,
    cross: Vec<f64>,
    diag: f64,
    xty: f64,
    mean: f64,
}

impl PolishCache {
    /// One-pass build of the centered Gram system for `support`: O(nk²).
    fn build(&mut self, x: &Matrix, y: &[f64], support: &[usize]) {
        let k = support.len();
        let n = x.rows();
        self.features.clear();
        self.features.extend_from_slice(support);
        if self.g.rows() != k || self.g.cols() != k {
            self.g = Matrix::zeros(k, k);
        } else {
            self.g.data_mut().iter_mut().for_each(|v| *v = 0.0);
        }
        self.xty.clear();
        self.xty.resize(k, 0.0);
        self.means.clear();
        self.means.resize(k, 0.0);
        self.srow.clear();
        self.srow.resize(k, 0.0);
        let mut y_sum = 0.0;
        let mut y_sq = 0.0;
        let gd = self.g.data_mut();
        for i in 0..n {
            let row = x.row(i);
            for (jj, &j) in support.iter().enumerate() {
                self.srow[jj] = row[j];
            }
            let yi = y[i];
            y_sum += yi;
            y_sq += yi * yi;
            for a in 0..k {
                let sa = self.srow[a];
                self.means[a] += sa;
                self.xty[a] += sa * yi;
                // Rank-1 upper-triangle row update, backend-dispatched
                // (elementwise axpy — bit-identical across backends).
                crate::linalg::axpy(sa, &self.srow[a..], &mut gd[a * k + a..(a + 1) * k]);
            }
        }
        let nf = (n.max(1)) as f64;
        self.y_mean = y_sum / nf;
        self.yty = y_sq - nf * self.y_mean * self.y_mean;
        for m in self.means.iter_mut() {
            *m /= nf;
        }
        for a in 0..k {
            self.xty[a] -= nf * self.means[a] * self.y_mean;
            for b in a..k {
                let v = gd[a * k + b] - nf * self.means[a] * self.means[b];
                gd[a * k + b] = v;
                gd[b * k + a] = v;
            }
        }
    }

    /// Solve `(G + λ₂I)β = b` by Cholesky with the same jitter fallback
    /// as [`crate::linalg::least_squares`]; `None` if even the jittered
    /// system is not positive definite (degenerate support).
    fn solve(&mut self, lambda2: f64) -> Option<Vec<f64>> {
        let k = self.features.len();
        if k == 0 {
            return Some(Vec::new());
        }
        self.gl.clone_from(&self.g); // field-wise: reuses gl's buffer
        {
            let gld = self.gl.data_mut();
            for i in 0..k {
                gld[i * k + i] += lambda2;
            }
        }
        let l = match cholesky(&self.gl) {
            Ok(l) => l,
            Err(_) => {
                let jitter = 1e-8 * (self.gl.frobenius_norm() / k as f64).max(1e-8);
                let gld = self.gl.data_mut();
                for i in 0..k {
                    gld[i * k + i] += jitter;
                }
                cholesky(&self.gl).ok()?
            }
        };
        let w = solve_lower(&l, &self.xty);
        Some(solve_lower_transpose(&l, &w))
    }

    /// Ridge objective `yᵀy − 2βᵀb + βᵀGβ + λ₂‖β‖²` of coefficients in
    /// cache order — O(k²), no pass over the data. Exact for any β (not
    /// just stationary points), so jittered solves stay comparable.
    fn objective_for(&self, beta_s: &[f64], lambda2: f64) -> f64 {
        let k = self.features.len();
        debug_assert_eq!(beta_s.len(), k);
        let mut quad = 0.0;
        for a in 0..k {
            quad += beta_s[a] * dot(self.g.row(a), beta_s);
        }
        self.yty - 2.0 * dot(beta_s, &self.xty) + quad + lambda2 * dot(beta_s, beta_s)
    }

    /// Intercept recovering the uncentered model: `ȳ − Σ βⱼ mⱼ`.
    fn intercept_for(&self, beta_s: &[f64]) -> f64 {
        self.y_mean - dot(beta_s, &self.means)
    }

    /// Evaluate swapping the support member at cache position `w` for the
    /// excluded feature `cand`: O(nk) candidate cross products + O(k²)
    /// bordered Cholesky/solve (plus one O(k³) factorization of the
    /// retained block, k = support size). `None` when the trial system is
    /// numerically degenerate — the caller treats that as non-improving.
    fn eval_swap(
        &mut self,
        x: &Matrix,
        y: &[f64],
        w: usize,
        cand: usize,
        lambda2: f64,
    ) -> Option<SwapEval> {
        let k = self.features.len();
        let n = x.rows();
        let nf = (n.max(1)) as f64;
        let km = k - 1;
        self.rpos.clear();
        self.rpos.extend((0..k).filter(|&a| a != w));
        self.rfeats.clear();
        for &a in &self.rpos {
            let f = self.features[a];
            self.rfeats.push(f);
        }
        // Retained (k−1)² block of G, ridge added — shared with the
        // current support, no recomputation.
        if self.gl.rows() != km || self.gl.cols() != km {
            self.gl = Matrix::zeros(km, km);
        }
        {
            let gld = self.gl.data_mut();
            for i in 0..km {
                for j in 0..km {
                    gld[i * km + j] = self.g.get(self.rpos[i], self.rpos[j])
                        + if i == j { lambda2 } else { 0.0 };
                }
            }
        }
        let l_minus = cholesky(&self.gl).ok()?;

        // Candidate column statistics + cross products: one O(nk) pass.
        self.cross.clear();
        self.cross.resize(km, 0.0);
        let mut diag_raw = 0.0;
        let mut xty_raw = 0.0;
        let mut sum_c = 0.0;
        for i in 0..n {
            let row = x.row(i);
            let xc = row[cand];
            sum_c += xc;
            diag_raw += xc * xc;
            xty_raw += xc * y[i];
            if xc != 0.0 {
                for (j, &f) in self.rfeats.iter().enumerate() {
                    self.cross[j] += xc * row[f];
                }
            }
        }
        let mean_c = sum_c / nf;
        for j in 0..km {
            self.cross[j] -= nf * mean_c * self.means[self.rpos[j]];
        }
        let diag_c = diag_raw - nf * mean_c * mean_c;
        let xty_c = xty_raw - nf * mean_c * self.y_mean;

        // Bordered factor + solve: O(k²).
        let l = cholesky_bordered(&l_minus, &self.cross, diag_c + lambda2).ok()?;
        self.bbuf.clear();
        for &a in &self.rpos {
            self.bbuf.push(self.xty[a]);
        }
        self.bbuf.push(xty_c);
        let t = solve_lower(&l, &self.bbuf);
        let beta = solve_lower_transpose(&l, &t);

        // Quadratic-form objective over the bordered Gram.
        let mut quad = 0.0;
        for i in 0..km {
            let gi = self.g.row(self.rpos[i]);
            let mut s = 0.0;
            for j in 0..km {
                s += gi[self.rpos[j]] * beta[j];
            }
            quad += beta[i] * s;
        }
        let b_last = beta[km];
        quad += 2.0 * b_last * dot(&beta[..km], &self.cross) + b_last * b_last * diag_c;
        let objective =
            self.yty - 2.0 * dot(&beta, &self.bbuf) + quad + lambda2 * dot(&beta, &beta);

        let mut intercept = self.y_mean - b_last * mean_c;
        for j in 0..km {
            intercept -= beta[j] * self.means[self.rpos[j]];
        }

        Some(SwapEval {
            beta,
            intercept,
            objective,
            cross: self.cross.clone(),
            diag: diag_c,
            xty: xty_c,
            mean: mean_c,
        })
    }

    /// Splice an accepted swap into the cache: position `w` becomes
    /// feature `cand` with the trial's already-computed column statistics
    /// — O(k), no data pass.
    fn accept_swap(&mut self, w: usize, cand: usize, eval: &SwapEval) {
        let k = self.features.len();
        self.features[w] = cand;
        self.means[w] = eval.mean;
        self.xty[w] = eval.xty;
        let mut j = 0;
        for a in 0..k {
            if a == w {
                continue;
            }
            self.g.set(w, a, eval.cross[j]);
            self.g.set(a, w, eval.cross[j]);
            j += 1;
        }
        self.g.set(w, w, eval.diag);
    }
}

impl L0Model {
    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        x.matvec(&self.beta).iter().map(|v| v + self.intercept).collect()
    }
}

/// Largest-magnitude `k` indices of `v` (ties broken by lower index).
fn top_k_indices(v: &[f64], k: usize) -> Vec<usize> {
    top_k_indices_with(v, k, &mut Vec::new())
}

/// [`top_k_indices`] reusing a caller-owned index buffer. Uses an O(p)
/// expected-time selection instead of a full sort; the comparator is a
/// total order (magnitude desc, then index asc), so the selected set —
/// and therefore the result — is identical to the sort-based oracle.
fn top_k_indices_with(v: &[f64], k: usize, idx: &mut Vec<usize>) -> Vec<usize> {
    idx.clear();
    idx.extend(0..v.len());
    let k = k.min(idx.len());
    if k == 0 {
        return Vec::new();
    }
    let cmp = |a: &usize, b: &usize| {
        v[*b].abs().partial_cmp(&v[*a].abs()).unwrap().then(a.cmp(b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k, cmp);
    }
    let mut top: Vec<usize> = idx[..k].to_vec();
    top.sort_unstable();
    top
}

/// Ridge refit restricted to `support` via explicit column gather,
/// centering, and full normal-equations solve; returns (dense beta,
/// intercept, objective). **Scalar reference path**: this is the oracle
/// [`polish_support_cached`] is property-tested against (agreement
/// ≤ 1e-9) — production call sites use the Gram-cached path.
pub fn polish_support(
    x: &Matrix,
    y: &[f64],
    support: &[usize],
    lambda2: f64,
) -> (Vec<f64>, f64, f64) {
    let p = x.cols();
    if support.is_empty() {
        let intercept = crate::linalg::mean(y);
        let obj: f64 = y.iter().map(|v| (v - intercept) * (v - intercept)).sum();
        return (vec![0.0; p], intercept, obj);
    }
    let xs = x.select_columns(support);
    // Center y for the intercept, then refit.
    let y_mean = crate::linalg::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    let means = xs.col_means();
    let mut xc = xs.clone();
    for i in 0..xc.rows() {
        let row = xc.row_mut(i);
        for (j, m) in means.iter().enumerate() {
            row[j] -= m;
        }
    }
    let beta_s = least_squares(&xc, &yc, lambda2).unwrap_or_else(|_| vec![0.0; support.len()]);
    let mut beta = vec![0.0; p];
    let mut intercept = y_mean;
    for (jj, &j) in support.iter().enumerate() {
        beta[j] = beta_s[jj];
        intercept -= beta_s[jj] * means[jj];
    }
    let pred = x.matvec(&beta);
    let obj: f64 = y
        .iter()
        .zip(&pred)
        .map(|(yv, pv)| {
            let r = yv - pv - intercept;
            r * r
        })
        .sum::<f64>()
        + lambda2 * dot(&beta, &beta);
    (beta, intercept, obj)
}

/// Gram-cached ridge refit on `support`: builds the centered Gram system
/// in the workspace's [`PolishCache`] (one O(nk²) pass, no column
/// gather/clone) and solves it by Cholesky; the objective is computed
/// from the definition via one fused [`Matrix::residual_into`] pass.
/// Agrees with the [`polish_support`] oracle to ≤ 1e-9 on well-scaled
/// data (enforced by `tests/prop_linalg.rs`).
pub fn polish_support_cached(
    x: &Matrix,
    y: &[f64],
    support: &[usize],
    lambda2: f64,
    ws: &mut L0Workspace,
) -> (Vec<f64>, f64, f64) {
    if support.is_empty() {
        return polish_support(x, y, support, lambda2);
    }
    let (beta, intercept) = polish_cached_core(x, y, support, lambda2, ws);
    x.residual_into(&beta, y, intercept, &mut ws.resid);
    let obj = dot(&ws.resid, &ws.resid) + lambda2 * dot(&beta, &beta);
    (beta, intercept, obj)
}

/// Build + solve of the Gram-cached polish; returns (dense beta,
/// intercept) and leaves the cache populated for the swap search.
fn polish_cached_core(
    x: &Matrix,
    y: &[f64],
    support: &[usize],
    lambda2: f64,
    ws: &mut L0Workspace,
) -> (Vec<f64>, f64) {
    ws.cache.build(x, y, support);
    let beta_s = ws.cache.solve(lambda2).unwrap_or_else(|| vec![0.0; support.len()]);
    let intercept = ws.cache.intercept_for(&beta_s);
    let mut beta = vec![0.0; x.cols()];
    for (jj, &j) in support.iter().enumerate() {
        beta[j] = beta_s[jj];
    }
    (beta, intercept)
}

/// Power-iteration estimate of the largest eigenvalue of `XᵀX / n` —
/// the IHT step size is `1 / L` with `L` this spectral bound (times n).
/// Borrows the workspace's `z`/`pred`/`grad` buffers for the iteration
/// and exits early once the eigenvalue estimate is relatively converged
/// (|Δλ| ≤ 1e-6·λ), which typically halves the 20-iteration budget.
fn lipschitz_estimate(x: &Matrix, ws: &mut L0Workspace) -> f64 {
    let p = x.cols();
    ws.z.clear();
    ws.z.resize(p, 1.0 / (p as f64).sqrt());
    let mut lam = 1.0;
    let mut prev = 0.0;
    for _ in 0..20 {
        x.matvec_into(&ws.z, &mut ws.pred); // X v
        x.matvec_t_into(&ws.pred, &mut ws.grad); // Xᵀ X v
        let norm = crate::linalg::norm2(&ws.grad);
        if norm < 1e-12 {
            return 1.0;
        }
        lam = norm;
        if (lam - prev).abs() <= 1e-6 * lam {
            break;
        }
        prev = lam;
        for (vi, g) in ws.z.iter_mut().zip(&ws.grad) {
            *vi = g / norm;
        }
    }
    lam.max(1e-12)
}

/// Build an [`L0Model`] from a fixed support via ridge polish — the entry
/// point the PJRT runtime uses: the AOT IHT artifact supplies the support,
/// and this refit supplies exact coefficients/objective (identical to what
/// [`l0_fit`] does after its own IHT phase).
pub fn polish_to_model(x: &Matrix, y: &[f64], support: &[usize], lambda2: f64) -> L0Model {
    let mut support = support.to_vec();
    support.sort_unstable();
    support.dedup();
    let (beta, intercept, objective) = polish_support(x, y, &support, lambda2);
    L0Model { beta, intercept, support, objective }
}

/// Fit via IHT + polish + local swaps (one-shot scratch; see
/// [`l0_fit_with`] for the allocation-reusing entry point).
pub fn l0_fit(x: &Matrix, y: &[f64], cfg: &L0Config) -> L0Model {
    l0_fit_with(x, y, cfg, &mut L0Workspace::default())
}

/// Fit via IHT + polish + local swaps, borrowing caller-owned scratch —
/// the entry point of the backbone's `fit_subproblem` hot loop, where one
/// workspace is reused across every subproblem a worker thread solves.
/// Bit-identical to [`l0_fit`] for any workspace state.
pub fn l0_fit_with(x: &Matrix, y: &[f64], cfg: &L0Config, ws: &mut L0Workspace) -> L0Model {
    assert_eq!(x.rows(), y.len());
    let p = x.cols();
    let k = cfg.k.min(p);
    if k == 0 || p == 0 {
        let (beta, intercept, objective) = polish_support(x, y, &[], cfg.lambda2);
        return L0Model { beta, intercept, support: vec![], objective };
    }

    // --- IHT phase -------------------------------------------------------
    let lip = lipschitz_estimate(x, ws) + cfg.lambda2;
    let step = 1.0 / lip;
    ws.beta.clear();
    ws.beta.resize(p, 0.0);
    match &cfg.warm_start {
        Some(w0) if w0.len() == p => {
            // Project the warm start onto the k-sparse ball.
            for &j in &top_k_indices_with(w0, k, &mut ws.idx) {
                ws.beta[j] = w0[j];
            }
        }
        _ => {}
    }
    let mut support: Vec<usize> = Vec::new();
    let mut stable = 0;
    // Iteration counts accumulate locally and post to the metrics
    // registry once per solve — the loop body never touches an atomic.
    let mut iht_iters = 0u64;
    for _ in 0..cfg.max_iter {
        iht_iters += 1;
        // gradient of ½‖y−Xβ‖² + ½λ₂‖β‖² : −Xᵀ(y−Xβ) + λ₂β
        x.residual_into(&ws.beta, y, 0.0, &mut ws.resid); // r = y − Xβ, fused
        x.matvec_t_into(&ws.resid, &mut ws.grad); // = Xᵀ r
        ws.z.clear();
        ws.z.extend(
            ws.beta
                .iter()
                .zip(&ws.grad)
                .map(|(&b, &g)| b + step * (g - cfg.lambda2 * b)),
        );
        let new_support = top_k_indices_with(&ws.z, k, &mut ws.idx);
        ws.beta.iter_mut().for_each(|b| *b = 0.0);
        for &j in &new_support {
            ws.beta[j] = ws.z[j];
        }
        if new_support == support {
            stable += 1;
            if stable >= cfg.patience {
                break;
            }
        } else {
            stable = 0;
        }
        support = new_support;
    }
    // The last IHT iterate feeds the polish below via `support`.

    // --- Polish (Gram-cached) --------------------------------------------
    let (mut beta, mut intercept) = polish_cached_core(x, y, &support, cfg.lambda2, ws);
    // In-search objectives use the cache's O(k²) quadratic form — the same
    // formula for the incumbent and every trial, so comparisons are
    // consistent; the definition-based objective is recomputed once at the
    // end.
    let mut objective = {
        let beta_s: Vec<f64> = ws.cache.features.iter().map(|&f| beta[f]).collect();
        ws.cache.objective_for(&beta_s, cfg.lambda2)
    };

    // --- Local swap search -------------------------------------------------
    // For each swap round: compute the residual correlation of excluded
    // features; try swapping the weakest support member for the strongest
    // excluded candidate; keep if the polished objective improves. Each
    // trial is evaluated incrementally against the cached Gram system.
    let mut swap_rounds = 0u64;
    for _ in 0..cfg.swap_rounds {
        if support.is_empty() || support.len() >= p {
            break;
        }
        swap_rounds += 1;
        x.residual_into(&beta, y, intercept, &mut ws.resid);
        x.matvec_t_into(&ws.resid, &mut ws.grad);
        let corr = &ws.grad;
        // Strongest excluded candidate — O(p) membership-mask scan.
        ws.mask.clear();
        ws.mask.resize(p, false);
        for &j in &ws.cache.features {
            ws.mask[j] = true;
        }
        let mut cand: Option<usize> = None;
        let mut best = f64::NEG_INFINITY;
        for (j, &is_in) in ws.mask.iter().enumerate() {
            if !is_in && corr[j].abs() >= best {
                best = corr[j].abs();
                cand = Some(j);
            }
        }
        let Some(cand) = cand else { break };
        // Weakest support member (smallest |beta|), by cache position.
        let weakest_pos = ws
            .cache
            .features
            .iter()
            .enumerate()
            .min_by(|(_, &a), (_, &b)| beta[a].abs().partial_cmp(&beta[b].abs()).unwrap())
            .map(|(pos, _)| pos)
            .unwrap();
        let Some(eval) = ws.cache.eval_swap(x, y, weakest_pos, cand, cfg.lambda2) else {
            break;
        };
        if eval.objective + 1e-12 < objective {
            let old = ws.cache.features[weakest_pos];
            ws.cache.accept_swap(weakest_pos, cand, &eval);
            // Rebuild the dense iterate from the bordered solve: retained
            // coefficients in order, candidate last.
            beta[old] = 0.0;
            let mut j = 0;
            for (pos, &f) in ws.cache.features.iter().enumerate() {
                if pos == weakest_pos {
                    continue;
                }
                beta[f] = eval.beta[j];
                j += 1;
            }
            beta[cand] = eval.beta[j];
            intercept = eval.intercept;
            objective = eval.objective;
            support = {
                let mut s = ws.cache.features.clone();
                s.sort_unstable();
                s
            };
        } else {
            break; // local optimum
        }
    }

    crate::obs::add_solver_iterations("l0_iht", iht_iters);
    crate::obs::add_solver_iterations("l0_swap", swap_rounds);

    // Definition-based objective of the returned model (one fused pass).
    x.residual_into(&beta, y, intercept, &mut ws.resid);
    let objective = dot(&ws.resid, &ws.resid) + cfg.lambda2 * dot(&beta, &beta);

    L0Model { beta, intercept, support, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse_regression::{generate, SparseRegressionConfig};
    use crate::rng::Rng;

    #[test]
    fn top_k_selects_largest_magnitudes() {
        let v = [0.1, -5.0, 3.0, -0.2, 4.0];
        assert_eq!(top_k_indices(&v, 2), vec![1, 4]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(top_k_indices(&v, 5).len(), 5);
    }

    #[test]
    fn top_k_matches_full_sort_oracle() {
        let mut rng = Rng::seed_from_u64(42);
        for _ in 0..50 {
            let n = 1 + rng.usize_below(40);
            let v: Vec<f64> = (0..n)
                .map(|_| if rng.bernoulli(0.2) { 0.5 } else { rng.normal() })
                .collect();
            for k in [0, 1, n / 2, n.saturating_sub(1), n] {
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_by(|&a, &b| {
                    v[b].abs().partial_cmp(&v[a].abs()).unwrap().then(a.cmp(&b))
                });
                let mut oracle: Vec<usize> = idx.into_iter().take(k).collect();
                oracle.sort_unstable();
                assert_eq!(top_k_indices(&v, k), oracle, "n={n} k={k}");
            }
        }
    }

    #[test]
    fn cached_polish_matches_reference_polish() {
        let cfg_data = SparseRegressionConfig { n: 60, p: 30, k: 4, rho: 0.3, snr: 8.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(21));
        let mut ws = L0Workspace::default();
        for support in [vec![0], vec![1, 7, 12], vec![2, 3, 4, 5, 6, 20, 29]] {
            let (b1, i1, o1) = polish_support(&data.x, &data.y, &support, 1e-3);
            let (b2, i2, o2) =
                polish_support_cached(&data.x, &data.y, &support, 1e-3, &mut ws);
            assert!((i1 - i2).abs() < 1e-9, "intercept {i1} vs {i2}");
            assert!((o1 - o2).abs() < 1e-9 * (1.0 + o1.abs()), "obj {o1} vs {o2}");
            for (a, b) in b1.iter().zip(&b2) {
                assert!((a - b).abs() < 1e-9, "beta {a} vs {b}");
            }
        }
    }

    #[test]
    fn recovers_true_support_no_noise() {
        let cfg_data = SparseRegressionConfig { n: 80, p: 40, k: 4, rho: 0.0, snr: 0.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(1));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 4, ..Default::default() });
        assert_eq!(m.support, data.support_true);
        for &j in &data.support_true {
            assert!((m.beta[j].abs() - 1.0).abs() < 0.05, "beta[{j}]={}", m.beta[j]);
        }
    }

    #[test]
    fn recovers_support_with_noise_and_correlation() {
        let cfg_data = SparseRegressionConfig { n: 200, p: 100, k: 5, rho: 0.3, snr: 10.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(2));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 5, ..Default::default() });
        let rec = crate::metrics::support_recovery(&m.support, &data.support_true);
        assert!(rec.f1 >= 0.8, "f1={}", rec.f1);
        let r2 = crate::metrics::r2_score(&data.y, &m.predict(&data.x));
        assert!(r2 > 0.8, "r2={r2}");
    }

    #[test]
    fn respects_sparsity_budget() {
        let cfg_data = SparseRegressionConfig { n: 50, p: 30, k: 6, rho: 0.1, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(3));
        for k in [1, 3, 6, 10] {
            let m = l0_fit(&data.x, &data.y, &L0Config { k, ..Default::default() });
            assert!(m.support.len() <= k);
            let nnz = m.beta.iter().filter(|&&b| b != 0.0).count();
            assert_eq!(nnz, m.support.len());
        }
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh_scratch() {
        // One workspace reused across differently-shaped fits must give
        // exactly what fresh scratch gives — the contract that lets the
        // batch scheduler hand one workspace per worker thread.
        let mut ws = L0Workspace::default();
        for (n, p, k, seed) in [(40, 30, 3, 10), (60, 80, 5, 11), (25, 12, 2, 12)] {
            let cfg_data = SparseRegressionConfig { n, p, k, rho: 0.2, snr: 5.0 };
            let data = generate(&cfg_data, &mut Rng::seed_from_u64(seed));
            let cfg = L0Config { k, ..Default::default() };
            let fresh = l0_fit(&data.x, &data.y, &cfg);
            let reused = l0_fit_with(&data.x, &data.y, &cfg, &mut ws);
            assert_eq!(fresh.support, reused.support);
            assert_eq!(fresh.beta, reused.beta);
            assert_eq!(fresh.intercept, reused.intercept);
            assert_eq!(fresh.objective, reused.objective);
        }
    }

    #[test]
    fn warm_start_is_deterministic_and_respects_budget() {
        let cfg_data = SparseRegressionConfig { n: 60, p: 40, k: 4, rho: 0.2, snr: 8.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(13));
        // Warm-start from the (noisy) truth: same inputs → same fit.
        let mut warm: Vec<f64> = vec![0.0; 40];
        for &j in &data.support_true {
            warm[j] = 1.0;
        }
        let cfg = L0Config { k: 4, warm_start: Some(warm), ..Default::default() };
        let a = l0_fit(&data.x, &data.y, &cfg);
        let b = l0_fit(&data.x, &data.y, &cfg);
        assert_eq!(a.support, b.support);
        assert_eq!(a.beta, b.beta);
        assert!(a.support.len() <= 4);
        // A wrong-length warm start is ignored, not fatal.
        let cfg_bad =
            L0Config { k: 4, warm_start: Some(vec![1.0; 7]), ..Default::default() };
        let cold = l0_fit(&data.x, &data.y, &L0Config { k: 4, ..Default::default() });
        let ignored = l0_fit(&data.x, &data.y, &cfg_bad);
        assert_eq!(cold.support, ignored.support);
        assert_eq!(cold.beta, ignored.beta);
    }

    #[test]
    fn k_zero_gives_intercept_only() {
        let cfg_data = SparseRegressionConfig { n: 30, p: 10, k: 2, rho: 0.0, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(4));
        let m = l0_fit(&data.x, &data.y, &L0Config { k: 0, ..Default::default() });
        assert!(m.support.is_empty());
        assert!((m.intercept - crate::linalg::mean(&data.y)).abs() < 1e-10);
    }

    #[test]
    fn swap_search_improves_greedy_mistake() {
        // Construct a trap: two features nearly collinear with the target
        // of a third. IHT may pick the decoy; swaps should fix or at least
        // not hurt the objective.
        let cfg_data = SparseRegressionConfig { n: 120, p: 60, k: 3, rho: 0.7, snr: 20.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(5));
        let no_swaps = l0_fit(
            &data.x,
            &data.y,
            &L0Config { k: 3, swap_rounds: 0, ..Default::default() },
        );
        let with_swaps = l0_fit(
            &data.x,
            &data.y,
            &L0Config { k: 3, swap_rounds: 5, ..Default::default() },
        );
        assert!(with_swaps.objective <= no_swaps.objective + 1e-9);
    }

    #[test]
    fn objective_matches_definition() {
        let cfg_data = SparseRegressionConfig { n: 40, p: 20, k: 3, rho: 0.0, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(6));
        let cfg = L0Config { k: 3, lambda2: 0.01, ..Default::default() };
        let m = l0_fit(&data.x, &data.y, &cfg);
        let pred = m.predict(&data.x);
        let rss: f64 = data.y.iter().zip(&pred).map(|(y, p)| (y - p) * (y - p)).sum();
        let expected = rss + cfg.lambda2 * crate::linalg::dot(&m.beta, &m.beta);
        assert!((m.objective - expected).abs() < 1e-8);
    }
}
