//! GLMNet-style elastic-net coordinate descent.
//!
//! Minimizes (with standardized columns handled internally)
//!
//! ```text
//! (1/2n) ‖y − β₀ − Xβ‖² + λ [ α‖β‖₁ + (1−α)/2 ‖β‖₂² ]
//! ```
//!
//! via cyclic coordinate descent with covariance-free residual updates, an
//! active-set outer loop (iterate over nonzeros until stable, then one
//! full sweep to admit violators), and a warm-started geometric λ path
//! from `λ_max` (smallest λ with an all-zero solution) down — the same
//! scheme as Friedman et al.'s `glmnet`.

use crate::linalg::{dot, Matrix};
use super::soft_threshold;

/// Elastic-net hyperparameters.
#[derive(Debug, Clone)]
pub struct ElasticNetConfig {
    /// L1 ratio α ∈ (0, 1]; α = 1 is the lasso.
    pub alpha: f64,
    /// Number of λ values on the path.
    pub n_lambda: usize,
    /// `λ_min = lambda_min_ratio · λ_max`.
    pub lambda_min_ratio: f64,
    /// Convergence tolerance on the max coefficient change per sweep.
    pub tol: f64,
    /// Max coordinate-descent sweeps per λ.
    pub max_iter: usize,
}

impl Default for ElasticNetConfig {
    fn default() -> Self {
        Self { alpha: 1.0, n_lambda: 50, lambda_min_ratio: 1e-3, tol: 1e-7, max_iter: 1000 }
    }
}

/// A fitted elastic-net model (coefficients on the *original* scale).
#[derive(Debug, Clone)]
pub struct ElasticNetModel {
    pub beta: Vec<f64>,
    pub intercept: f64,
    pub lambda: f64,
}

impl ElasticNetModel {
    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        x.matvec(&self.beta).iter().map(|v| v + self.intercept).collect()
    }

    /// Indices of nonzero coefficients.
    pub fn support(&self) -> Vec<usize> {
        self.beta
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, _)| j)
            .collect()
    }
}

/// A full regularization path (λ descending).
#[derive(Debug, Clone)]
pub struct ElasticNetPath {
    pub models: Vec<ElasticNetModel>,
}

impl ElasticNetPath {
    /// Model with the best R² on a validation set.
    pub fn select_best(&self, x_val: &Matrix, y_val: &[f64]) -> &ElasticNetModel {
        self.models
            .iter()
            .max_by(|a, b| {
                let ra = crate::metrics::r2_score(y_val, &a.predict(x_val));
                let rb = crate::metrics::r2_score(y_val, &b.predict(x_val));
                ra.partial_cmp(&rb).unwrap()
            })
            .expect("empty path")
    }

    /// Union of supports along the path (what the backbone unions into B
    /// when GLMNet is the subproblem fitter).
    pub fn support_union(&self) -> Vec<usize> {
        let mut s: Vec<usize> = self.models.iter().flat_map(|m| m.support()).collect();
        s.sort_unstable();
        s.dedup();
        s
    }
}

/// Internal standardized problem state shared by single fits and paths.
///
/// The design is stored **transposed** (`xt`, p × n) so each coordinate's
/// column is a contiguous slice — the CD inner loop is a dot + axpy over
/// `x_j`, and column gathers through a row-major matrix were the dominant
/// cache-miss source (§Perf: ~1.9 s → ~0.35 s for a 50-λ path at
/// 200 × 1000).
struct Workspace {
    xt: Matrix,               // standardized design, transposed (p × n)
    ys: Vec<f64>,             // centered response
    x_scale: Vec<(f64, f64)>, // per-column (mean, scale)
    y_mean: f64,
}

impl Workspace {
    fn new(x: &Matrix, y: &[f64]) -> Self {
        let mut xs = x.clone();
        let x_scale = xs.standardize_columns();
        let y_mean = crate::linalg::mean(y);
        let ys: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
        Self { xt: xs.transpose(), ys, x_scale, y_mean }
    }

    /// Map standardized-scale coefficients back to the original scale.
    fn denormalize(&self, beta_std: &[f64], lambda: f64) -> ElasticNetModel {
        let mut beta = vec![0.0; beta_std.len()];
        let mut intercept = self.y_mean;
        for (j, &bs) in beta_std.iter().enumerate() {
            if bs != 0.0 {
                let (mean, scale) = self.x_scale[j];
                beta[j] = bs / scale;
                intercept -= beta[j] * mean;
            }
        }
        ElasticNetModel { beta, intercept, lambda }
    }

    /// λ_max: the smallest λ for which β = 0 is optimal.
    fn lambda_max(&self, alpha: f64) -> f64 {
        let n = self.xt.cols() as f64;
        let grad = self.xt.matvec(&self.ys);
        let max_abs = grad.iter().fold(0.0f64, |m, g| m.max(g.abs()));
        max_abs / (n * alpha.max(1e-3))
    }

    /// Cyclic CD at a fixed λ, warm-started from `beta`; `residual` must
    /// equal `ys − Xs·beta` on entry and is maintained on exit.
    fn descend(
        &self,
        beta: &mut [f64],
        residual: &mut [f64],
        lambda: f64,
        cfg: &ElasticNetConfig,
    ) {
        let n = self.xt.cols() as f64;
        let p = self.xt.rows();
        let l1 = lambda * cfg.alpha;
        let l2 = lambda * (1.0 - cfg.alpha);
        // Standardized columns have ‖x_j‖²/n = 1, so the coordinate update
        // denominator is 1 + l2.
        let denom = 1.0 + l2;

        let sweep = |beta: &mut [f64], residual: &mut [f64], active_only: bool| -> f64 {
            let mut max_delta = 0.0f64;
            for j in 0..p {
                let old = beta[j];
                if active_only && old == 0.0 {
                    continue;
                }
                let col = self.xt.row(j); // contiguous x_j
                // ρ_j = (1/n) x_jᵀ r + old (covariance-free partial residual)
                let xj_r = dot(col, residual);
                let rho = xj_r / n + old;
                let new = soft_threshold(rho, l1) / denom;
                if new != old {
                    let delta = new - old;
                    crate::linalg::axpy(-delta, col, residual);
                    beta[j] = new;
                    max_delta = max_delta.max(delta.abs());
                }
            }
            max_delta
        };

        let mut iter = 0;
        loop {
            // Full sweep to admit new actives.
            let delta_full = sweep(beta, residual, false);
            iter += 1;
            if delta_full < cfg.tol || iter >= cfg.max_iter {
                break;
            }
            // Inner active-set sweeps until stable.
            loop {
                let delta = sweep(beta, residual, true);
                iter += 1;
                if delta < cfg.tol || iter >= cfg.max_iter {
                    break;
                }
            }
            if iter >= cfg.max_iter {
                break;
            }
        }
    }
}

/// Fit a single elastic-net model at the given λ.
pub fn elastic_net_fit(
    x: &Matrix,
    y: &[f64],
    lambda: f64,
    cfg: &ElasticNetConfig,
) -> ElasticNetModel {
    assert_eq!(x.rows(), y.len());
    let ws = Workspace::new(x, y);
    let mut beta = vec![0.0; x.cols()];
    let mut residual = ws.ys.clone();
    ws.descend(&mut beta, &mut residual, lambda, cfg);
    ws.denormalize(&beta, lambda)
}

/// Compute the warm-started regularization path (λ descending from λ_max).
pub fn elastic_net_path(x: &Matrix, y: &[f64], cfg: &ElasticNetConfig) -> ElasticNetPath {
    assert_eq!(x.rows(), y.len());
    assert!(cfg.n_lambda >= 1);
    let ws = Workspace::new(x, y);
    let lam_max = ws.lambda_max(cfg.alpha).max(1e-12);
    let lam_min = lam_max * cfg.lambda_min_ratio;
    let ratio = if cfg.n_lambda == 1 {
        1.0
    } else {
        (lam_min / lam_max).powf(1.0 / (cfg.n_lambda - 1) as f64)
    };

    let mut beta = vec![0.0; x.cols()];
    let mut residual = ws.ys.clone();
    let mut models = Vec::with_capacity(cfg.n_lambda);
    let mut lambda = lam_max;
    for _ in 0..cfg.n_lambda {
        ws.descend(&mut beta, &mut residual, lambda, cfg);
        models.push(ws.denormalize(&beta, lambda));
        lambda *= ratio;
    }
    ElasticNetPath { models }
}

/// In-sample R² of a model (convenience used by benches).
pub fn r2_in_sample(model: &ElasticNetModel, x: &Matrix, y: &[f64]) -> f64 {
    crate::metrics::r2_score(y, &model.predict(x))
}

#[allow(dead_code)]
fn residual_check(ws: &Workspace, beta: &[f64], residual: &[f64]) -> f64 {
    // Debug helper: ‖(ys − Xs β) − residual‖∞.
    let pred = ws.xt.matvec_t(beta);
    ws.ys
        .iter()
        .zip(&pred)
        .zip(residual)
        .map(|((y, p), r)| ((y - p) - r).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse_regression::{generate, SparseRegressionConfig};
    use crate::rng::Rng;

    fn toy_data() -> (Matrix, Vec<f64>) {
        // y = 2·x0 − 3·x2 + noise-free, x1 pure noise.
        let mut rng = Rng::seed_from_u64(1);
        let n = 60;
        let mut x = Matrix::zeros(n, 3);
        for i in 0..n {
            for j in 0..3 {
                x.set(i, j, rng.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|i| 2.0 * x.get(i, 0) - 3.0 * x.get(i, 2)).collect();
        (x, y)
    }

    #[test]
    fn lasso_recovers_sparse_signal() {
        let (x, y) = toy_data();
        let cfg = ElasticNetConfig::default();
        let m = elastic_net_fit(&x, &y, 0.01, &cfg);
        assert!((m.beta[0] - 2.0).abs() < 0.1, "beta={:?}", m.beta);
        assert!((m.beta[2] + 3.0).abs() < 0.1);
        assert!(m.beta[1].abs() < 0.05);
    }

    #[test]
    fn heavy_lambda_kills_all_coefficients() {
        let (x, y) = toy_data();
        let cfg = ElasticNetConfig::default();
        let ws_lambda = {
            let ws = super::Workspace::new(&x, &y);
            ws.lambda_max(1.0)
        };
        let m = elastic_net_fit(&x, &y, ws_lambda * 1.01, &cfg);
        assert!(m.beta.iter().all(|&b| b == 0.0), "beta={:?}", m.beta);
    }

    #[test]
    fn path_is_monotone_in_sparsity_head() {
        let (x, y) = toy_data();
        let cfg = ElasticNetConfig { n_lambda: 20, ..Default::default() };
        let path = elastic_net_path(&x, &y, &cfg);
        assert_eq!(path.models.len(), 20);
        // First model (λ_max) is all-zero; last is dense(ish).
        assert_eq!(path.models[0].support().len(), 0);
        assert!(path.models.last().unwrap().support().len() >= 2);
        // λ strictly decreasing.
        for w in path.models.windows(2) {
            assert!(w[1].lambda < w[0].lambda);
        }
    }

    #[test]
    fn path_end_matches_cold_fit() {
        let (x, y) = toy_data();
        let cfg = ElasticNetConfig { n_lambda: 30, ..Default::default() };
        let path = elastic_net_path(&x, &y, &cfg);
        let last = path.models.last().unwrap();
        let cold = elastic_net_fit(&x, &y, last.lambda, &cfg);
        for (a, b) in last.beta.iter().zip(&cold.beta) {
            assert!((a - b).abs() < 1e-5, "warm {a} vs cold {b}");
        }
    }

    #[test]
    fn ridge_component_keeps_correlated_pair() {
        // Two highly correlated informative columns: lasso picks one,
        // elastic net (α = 0.3) keeps both.
        let mut rng = Rng::seed_from_u64(2);
        let n = 100;
        let mut x = Matrix::zeros(n, 2);
        for i in 0..n {
            let z = rng.normal();
            x.set(i, 0, z + 0.01 * rng.normal());
            x.set(i, 1, z + 0.01 * rng.normal());
        }
        let y: Vec<f64> = (0..n).map(|i| x.get(i, 0) + x.get(i, 1)).collect();
        let enet = elastic_net_fit(
            &x,
            &y,
            0.1,
            &ElasticNetConfig { alpha: 0.3, ..Default::default() },
        );
        assert!(enet.beta[0] != 0.0 && enet.beta[1] != 0.0, "beta={:?}", enet.beta);
        let ratio = enet.beta[0] / enet.beta[1];
        assert!(ratio > 0.5 && ratio < 2.0, "ratio={ratio}");
    }

    #[test]
    fn intercept_handling() {
        // y = 10 + x0 → intercept must absorb the offset.
        let mut rng = Rng::seed_from_u64(3);
        let n = 50;
        let mut x = Matrix::zeros(n, 1);
        for i in 0..n {
            x.set(i, 0, rng.normal());
        }
        let y: Vec<f64> = (0..n).map(|i| 10.0 + x.get(i, 0)).collect();
        let m = elastic_net_fit(&x, &y, 0.001, &ElasticNetConfig::default());
        assert!((m.intercept - 10.0).abs() < 0.1, "intercept={}", m.intercept);
        let r2 = crate::metrics::r2_score(&y, &m.predict(&x));
        assert!(r2 > 0.99);
    }

    #[test]
    fn path_on_generated_data_reaches_high_r2() {
        let cfg_data = SparseRegressionConfig { n: 100, p: 50, k: 5, rho: 0.1, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(4));
        let path = elastic_net_path(&data.x, &data.y, &ElasticNetConfig::default());
        let best = path.select_best(&data.x, &data.y);
        let r2 = crate::metrics::r2_score(&data.y, &best.predict(&data.x));
        assert!(r2 > 0.75, "r2={r2}");
        // Union of supports along the path contains the true support.
        let union = path.support_union();
        for j in &data.support_true {
            assert!(union.contains(j), "missing true feature {j}");
        }
    }
}
