//! Solver substrates.
//!
//! The paper composes off-the-shelf solvers (GLMNet, L0Learn, L0BnB,
//! scikit-learn, ODTLearn, Cbc); this crate rebuilds each one:
//!
//! | paper dependency | module | role in the backbone |
//! |---|---|---|
//! | GLMNet            | [`cd`]         | heuristic baseline + subproblem fitter |
//! | L0Learn           | [`cd`] (`l0`)  | heuristic subproblem fitter |
//! | L0BnB             | [`l0bnb`]      | exact reduced-problem solver (sparse regression) |
//! | scikit-learn CART | [`cart`]       | heuristic baseline + subproblem fitter (trees) |
//! | ODTLearn          | [`exact_tree`] | exact reduced-problem solver (trees) |
//! | scikit-learn KMeans | [`kmeans`]   | heuristic baseline + subproblem fitter (clustering) |
//! | Cbc (LP)          | [`lp`]         | LP relaxations for the MILP branch-and-bound |
//! | Cbc (MILP)        | [`mip`]        | generic binary MILP branch-and-bound |
//! | PuLP + Cbc        | [`clique`]     | exact clique-partitioning clustering |

pub mod cart;
pub mod cd;
pub mod clique;
pub mod exact_tree;
pub mod kmeans;
pub mod l0bnb;
pub mod logistic;
pub mod lp;
pub mod mip;

/// Termination status shared by the exact solvers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveStatus {
    /// Proven optimal (gap below tolerance).
    Optimal,
    /// Stopped at the time budget; best incumbent returned.
    TimedOut,
    /// Stopped at a node/iteration cap; best incumbent returned.
    NodeLimit,
    /// Problem proven infeasible.
    Infeasible,
    /// Objective unbounded below.
    Unbounded,
}

impl SolveStatus {
    /// Whether an incumbent solution accompanies this status.
    pub fn has_solution(self) -> bool {
        matches!(self, SolveStatus::Optimal | SolveStatus::TimedOut | SolveStatus::NodeLimit)
    }
}
