//! Exact cardinality-constrained (L0L2) sparse regression via
//! branch-and-bound — the role L0BnB (Hazimeh, Mazumder & Saab, 2022)
//! plays in the paper.
//!
//! Problem:
//!
//! ```text
//! min_β ‖y − Xβ‖² + λ₂‖β‖²   s.t.  ‖β‖₀ ≤ k        (centered X, y)
//! ```
//!
//! Branch-and-bound over feature-inclusion indicators. A node fixes some
//! features *in* (I) and some *out* (O); the remaining features are free
//! (F). The node lower bound is the ridge relaxation that allows **all**
//! of I ∪ F (dropping the cardinality constraint on F), which is valid
//! because every feasible completion of the node uses a subset of I ∪ F.
//! Leaves occur when |I| = k (support fully decided) or |I| + |F| ≤ k
//! (constraint slack — relaxation is exact). Branching follows the
//! most-fractional-analogue rule: the free feature with the largest
//! relaxation coefficient. The incumbent starts from the L0Learn-style
//! heuristic ([`crate::solvers::cd::l0_fit`]) so time-outs still return a
//! high-quality solution, mirroring how the paper reports L0BnB rows at
//! its one-hour cap.

use crate::linalg::{dot, least_squares, Matrix};
use crate::solvers::cd::{l0_fit, L0Config};
use crate::solvers::SolveStatus;
use crate::util::Budget;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Solver hyperparameters.
#[derive(Debug, Clone)]
pub struct L0BnbConfig {
    /// Cardinality bound k.
    pub k: usize,
    /// Ridge penalty λ₂.
    pub lambda2: f64,
    /// Relative optimality-gap tolerance (the paper reports < 1% gaps).
    pub gap_tol: f64,
    /// Node cap (safety valve; 0 = unlimited).
    pub max_nodes: usize,
}

impl Default for L0BnbConfig {
    fn default() -> Self {
        Self { k: 10, lambda2: 1e-3, gap_tol: 0.01, max_nodes: 0 }
    }
}

/// Result of a branch-and-bound solve.
#[derive(Debug, Clone)]
pub struct L0BnbResult {
    pub beta: Vec<f64>,
    pub intercept: f64,
    /// Sorted optimal (or incumbent) support.
    pub support: Vec<usize>,
    /// Incumbent objective (centered form).
    pub objective: f64,
    /// Best lower bound at termination.
    pub lower_bound: f64,
    /// Relative gap `(obj − bound) / max(|obj|, ε)`.
    pub gap: f64,
    pub status: SolveStatus,
    pub nodes_explored: usize,
    pub elapsed_secs: f64,
}

impl L0BnbResult {
    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        x.matvec(&self.beta).iter().map(|v| v + self.intercept).collect()
    }
}

/// One open node of the search tree.
struct Node {
    bound: f64,
    fixed_in: Vec<usize>,
    fixed_out: Vec<usize>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; we want the smallest bound on top
        // (best-first), so reverse.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Precomputed sufficient statistics of the centered problem: the Gram
/// matrix `G = XᵀX`, the correlation vector `c = Xᵀy`, and `yᵀy`.
///
/// Every node's ridge relaxation reduces to a solve over a *subset* of
/// G's rows/columns — O(s²) extraction + O(s³) Cholesky instead of the
/// O(n·s²) Gram rebuild a naive per-node `least_squares` would pay. For
/// the backbone's reduced problems (n = 500, s ≤ ~100) this is the
/// difference between ~1.4 s and ~0.1 s per exact solve (§Perf).
struct GramCache {
    g: Matrix,
    xty: Vec<f64>,
    yty: f64,
}

impl GramCache {
    fn new(xc: &Matrix, yc: &[f64]) -> Self {
        Self { g: xc.gram(), xty: xc.matvec_t(yc), yty: dot(yc, yc) }
    }

    /// Ridge objective on a subset: solve (G_SS + λ₂I) β = c_S and use
    /// RSS = yᵀy − 2βᵀc_S + βᵀG_SSβ (all from cached statistics).
    fn ridge_objective(&self, subset: &[usize], lambda2: f64) -> (Vec<f64>, f64) {
        if subset.is_empty() {
            return (Vec::new(), self.yty);
        }
        let s = subset.len();
        let mut gss = Matrix::zeros(s, s);
        for (a, &ja) in subset.iter().enumerate() {
            let grow = self.g.row(ja);
            let dst = gss.row_mut(a);
            for (b, &jb) in subset.iter().enumerate() {
                dst[b] = grow[jb];
            }
        }
        let cs: Vec<f64> = subset.iter().map(|&j| self.xty[j]).collect();
        let mut greg = gss.clone();
        for i in 0..s {
            greg.set(i, i, greg.get(i, i) + lambda2);
        }
        let beta = match crate::linalg::solve_spd(&greg, &cs) {
            Ok(b) => b,
            Err(_) => {
                // Singular (collinear subset): jitter retry.
                let jitter = 1e-8 * (greg.frobenius_norm() / s as f64).max(1e-8);
                for i in 0..s {
                    greg.set(i, i, greg.get(i, i) + jitter);
                }
                crate::linalg::solve_spd(&greg, &cs).unwrap_or_else(|_| vec![0.0; s])
            }
        };
        // RSS = yᵀy − 2 βᵀc + βᵀ G β ; obj = RSS + λ₂‖β‖².
        let gb = gss.matvec(&beta);
        let obj = self.yty - 2.0 * dot(&beta, &cs) + dot(&beta, &gb) + lambda2 * dot(&beta, &beta);
        (beta, obj.max(0.0))
    }
}

/// Centered ridge fit on a feature subset (uncached reference; used by
/// `brute_force` and tests).
fn ridge_objective(
    xc: &Matrix,
    yc: &[f64],
    subset: &[usize],
    lambda2: f64,
) -> (Vec<f64>, f64) {
    if subset.is_empty() {
        return (Vec::new(), dot(yc, yc));
    }
    let xs = xc.select_columns(subset);
    let beta = least_squares(&xs, yc, lambda2).unwrap_or_else(|_| vec![0.0; subset.len()]);
    let pred = xs.matvec(&beta);
    let rss: f64 = yc.iter().zip(&pred).map(|(y, p)| (y - p) * (y - p)).sum();
    let obj = rss + lambda2 * dot(&beta, &beta);
    (beta, obj)
}

/// Solve the cardinality-constrained problem exactly (up to `gap_tol`)
/// within the given wall-clock budget.
pub fn l0bnb_solve(x: &Matrix, y: &[f64], cfg: &L0BnbConfig, budget: &Budget) -> L0BnbResult {
    assert_eq!(x.rows(), y.len());
    let p = x.cols();
    let k = cfg.k.min(p);
    let start = Budget::unlimited(); // local stopwatch

    // Center once; intercept recovered at the end.
    let y_mean = crate::linalg::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    let col_means = x.col_means();
    let mut xc = x.clone();
    for i in 0..xc.rows() {
        let row = xc.row_mut(i);
        for (j, m) in col_means.iter().enumerate() {
            row[j] -= m;
        }
    }

    // Sufficient statistics shared by every node (§Perf: Gram caching).
    let cache = GramCache::new(&xc, &yc);

    // Root relaxation first: its dense iterate warm-starts the IHT
    // heuristic below (the bnb "pipeline" refits nested subsets of the
    // same problem, so the relaxation is exactly the kind of overlapping
    // previous iterate `L0Config::warm_start` exists for). Deterministic:
    // the warm start is an explicit input, not hidden state.
    let (beta_root, root_bound) = cache.ridge_objective(&(0..p).collect::<Vec<_>>(), cfg.lambda2);

    // Incumbent from the heuristic (warm-started from the relaxation).
    let heur = l0_fit(
        x,
        y,
        &L0Config {
            k,
            lambda2: cfg.lambda2,
            warm_start: if beta_root.len() == p { Some(beta_root) } else { None },
            ..Default::default()
        },
    );
    let (mut inc_support, mut inc_obj) = {
        let (_, obj) = cache.ridge_objective(&heur.support, cfg.lambda2);
        (heur.support.clone(), obj)
    };

    let finish = |support: Vec<usize>,
                  objective: f64,
                  lower_bound: f64,
                  status: SolveStatus,
                  nodes: usize| {
        crate::obs::add_solver_iterations("l0bnb_nodes", nodes as u64);
        let (beta_s, _) = cache.ridge_objective(&support, cfg.lambda2);
        let mut beta = vec![0.0; p];
        let mut intercept = y_mean;
        for (jj, &j) in support.iter().enumerate() {
            beta[j] = beta_s[jj];
            intercept -= beta_s[jj] * col_means[j];
        }
        let gap = if objective.abs() > 1e-12 {
            ((objective - lower_bound) / objective.abs()).max(0.0)
        } else {
            0.0
        };
        L0BnbResult {
            beta,
            intercept,
            support,
            objective,
            lower_bound,
            gap,
            status,
            nodes_explored: nodes,
            elapsed_secs: start.elapsed_secs(),
        }
    };

    if k == 0 || p == 0 {
        let obj = dot(&yc, &yc);
        return finish(vec![], obj, obj, SolveStatus::Optimal, 0);
    }

    // Root node (bound already computed for the warm start above).
    let mut heap: BinaryHeap<Node> = BinaryHeap::new();
    heap.push(Node { bound: root_bound, fixed_in: vec![], fixed_out: vec![] });

    let mut nodes = 0usize;
    let mut best_open_bound;

    while let Some(node) = heap.pop() {
        best_open_bound = node.bound;
        // Global optimality test: the best open node can no longer improve
        // the incumbent beyond the gap tolerance.
        if inc_obj - best_open_bound <= cfg.gap_tol * inc_obj.abs().max(1e-12) {
            return finish(inc_support, inc_obj, best_open_bound, SolveStatus::Optimal, nodes);
        }
        if budget.expired() {
            return finish(inc_support, inc_obj, best_open_bound, SolveStatus::TimedOut, nodes);
        }
        if cfg.max_nodes > 0 && nodes >= cfg.max_nodes {
            return finish(inc_support, inc_obj, best_open_bound, SolveStatus::NodeLimit, nodes);
        }
        nodes += 1;

        let free: Vec<usize> = (0..p)
            .filter(|j| !node.fixed_in.contains(j) && !node.fixed_out.contains(j))
            .collect();

        // Leaf cases.
        if node.fixed_in.len() == k || free.is_empty() {
            let (_, obj) = cache.ridge_objective(&node.fixed_in, cfg.lambda2);
            if obj < inc_obj {
                inc_obj = obj;
                inc_support = node.fixed_in.clone();
            }
            continue;
        }
        if node.fixed_in.len() + free.len() <= k {
            // Cardinality slack: the relaxation (all allowed features) is
            // feasible and therefore optimal for this subtree.
            let mut allowed = node.fixed_in.clone();
            allowed.extend_from_slice(&free);
            allowed.sort_unstable();
            let (_, obj) = cache.ridge_objective(&allowed, cfg.lambda2);
            if obj < inc_obj {
                inc_obj = obj;
                inc_support = allowed;
            }
            continue;
        }

        // Relaxation on I ∪ F for bounding + branching signal.
        let mut allowed = node.fixed_in.clone();
        allowed.extend_from_slice(&free);
        allowed.sort_unstable();
        let (beta_relax, bound) = cache.ridge_objective(&allowed, cfg.lambda2);
        if bound >= inc_obj {
            continue; // pruned
        }

        // Secondary incumbent: polish the top-k of the relaxation.
        let mut mag: Vec<(f64, usize)> = allowed
            .iter()
            .enumerate()
            .map(|(pos, &j)| (beta_relax[pos].abs(), j))
            .collect();
        mag.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut cand: Vec<usize> = mag.iter().take(k).map(|&(_, j)| j).collect();
        // Fixed-in features must stay; replace the tail if any were dropped.
        for &j in &node.fixed_in {
            if !cand.contains(&j) {
                cand.pop();
                cand.push(j);
            }
        }
        cand.sort_unstable();
        cand.dedup();
        let (_, cand_obj) = cache.ridge_objective(&cand, cfg.lambda2);
        if cand_obj < inc_obj {
            inc_obj = cand_obj;
            inc_support = cand;
        }

        // Branch on the free feature with the largest relaxation weight.
        let branch = free
            .iter()
            .copied()
            .max_by(|&a, &b| {
                let wa = beta_relax[allowed.binary_search(&a).unwrap()].abs();
                let wb = beta_relax[allowed.binary_search(&b).unwrap()].abs();
                wa.partial_cmp(&wb).unwrap()
            })
            .unwrap();

        // Child 1: include `branch` (same relaxation bound still valid).
        let mut in1 = node.fixed_in.clone();
        in1.push(branch);
        in1.sort_unstable();
        heap.push(Node { bound, fixed_in: in1, fixed_out: node.fixed_out.clone() });

        // Child 2: exclude `branch` — recompute the (tighter) bound.
        let mut out2 = node.fixed_out.clone();
        out2.push(branch);
        out2.sort_unstable();
        let allowed2: Vec<usize> =
            allowed.iter().copied().filter(|&j| j != branch).collect();
        let (_, bound2) = cache.ridge_objective(&allowed2, cfg.lambda2);
        if bound2 < inc_obj {
            heap.push(Node { bound: bound2, fixed_in: node.fixed_in, fixed_out: out2 });
        }
    }

    // Heap exhausted: incumbent is optimal.
    finish(inc_support, inc_obj, inc_obj, SolveStatus::Optimal, nodes)
}

/// Exhaustive reference solver (for tests): enumerate all supports of size
/// ≤ k. Exponential — only call with tiny p.
pub fn brute_force(x: &Matrix, y: &[f64], cfg: &L0BnbConfig) -> (Vec<usize>, f64) {
    let p = x.cols();
    assert!(p <= 20, "brute_force is exponential; p too large");
    let y_mean = crate::linalg::mean(y);
    let yc: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    let col_means = x.col_means();
    let mut xc = x.clone();
    for i in 0..xc.rows() {
        let row = xc.row_mut(i);
        for (j, m) in col_means.iter().enumerate() {
            row[j] -= m;
        }
    }
    let mut best = (vec![], dot(&yc, &yc));
    for mask in 0u32..(1 << p) {
        if (mask.count_ones() as usize) > cfg.k {
            continue;
        }
        let subset: Vec<usize> = (0..p).filter(|j| mask & (1 << j) != 0).collect();
        let (_, obj) = ridge_objective(&xc, &yc, &subset, cfg.lambda2);
        if obj < best.1 {
            best = (subset, obj);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse_regression::{generate, SparseRegressionConfig};
    use crate::rng::Rng;

    #[test]
    fn matches_brute_force_on_small_problems() {
        for seed in 0..5 {
            let cfg_data = SparseRegressionConfig { n: 40, p: 10, k: 3, rho: 0.4, snr: 3.0 };
            let data = generate(&cfg_data, &mut Rng::seed_from_u64(seed));
            let cfg = L0BnbConfig { k: 3, lambda2: 0.01, gap_tol: 1e-9, max_nodes: 0 };
            let bnb = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
            let (bf_support, bf_obj) = brute_force(&data.x, &data.y, &cfg);
            assert_eq!(bnb.status, SolveStatus::Optimal, "seed {seed}");
            assert!(
                (bnb.objective - bf_obj).abs() <= 1e-6 * bf_obj.max(1e-9),
                "seed {seed}: bnb {} vs brute {}",
                bnb.objective,
                bf_obj
            );
            assert_eq!(bnb.support, bf_support, "seed {seed}");
        }
    }

    #[test]
    fn recovers_true_support_clean_signal() {
        let cfg_data = SparseRegressionConfig { n: 100, p: 30, k: 4, rho: 0.2, snr: 50.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(7));
        let cfg = L0BnbConfig { k: 4, lambda2: 1e-4, gap_tol: 1e-6, max_nodes: 0 };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
        assert_eq!(res.support, data.support_true);
        assert_eq!(res.status, SolveStatus::Optimal);
        let r2 = crate::metrics::r2_score(&data.y, &res.predict(&data.x));
        assert!(r2 > 0.95, "r2={r2}");
    }

    #[test]
    fn timeout_returns_incumbent() {
        let cfg_data = SparseRegressionConfig { n: 100, p: 60, k: 8, rho: 0.5, snr: 2.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(8));
        let cfg = L0BnbConfig { k: 8, lambda2: 1e-3, gap_tol: 1e-12, max_nodes: 0 };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::seconds(0.0));
        assert_eq!(res.status, SolveStatus::TimedOut);
        assert_eq!(res.support.len(), 8);
        assert!(res.objective.is_finite());
        assert!(res.gap >= 0.0);
    }

    #[test]
    fn node_limit_respected() {
        let cfg_data = SparseRegressionConfig { n: 80, p: 40, k: 5, rho: 0.6, snr: 1.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(9));
        let cfg = L0BnbConfig { k: 5, lambda2: 1e-3, gap_tol: 1e-12, max_nodes: 3 };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
        assert!(matches!(res.status, SolveStatus::NodeLimit | SolveStatus::Optimal));
        assert!(res.nodes_explored <= 4);
    }

    #[test]
    fn lower_bound_never_exceeds_objective() {
        let cfg_data = SparseRegressionConfig { n: 60, p: 25, k: 4, rho: 0.3, snr: 3.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(10));
        let cfg = L0BnbConfig { k: 4, lambda2: 0.01, gap_tol: 0.01, max_nodes: 0 };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
        assert!(res.lower_bound <= res.objective + 1e-9);
        assert!(res.gap <= 0.01 + 1e-9);
    }

    #[test]
    fn k_zero_intercept_only() {
        let cfg_data = SparseRegressionConfig { n: 30, p: 10, k: 2, rho: 0.0, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(11));
        let cfg = L0BnbConfig { k: 0, ..Default::default() };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
        assert!(res.support.is_empty());
        assert_eq!(res.status, SolveStatus::Optimal);
        assert!((res.intercept - crate::linalg::mean(&data.y)).abs() < 1e-10);
    }

    #[test]
    fn beta_is_zero_outside_support() {
        let cfg_data = SparseRegressionConfig { n: 50, p: 20, k: 3, rho: 0.2, snr: 5.0 };
        let data = generate(&cfg_data, &mut Rng::seed_from_u64(12));
        let cfg = L0BnbConfig { k: 3, ..Default::default() };
        let res = l0bnb_solve(&data.x, &data.y, &cfg, &Budget::unlimited());
        for (j, &b) in res.beta.iter().enumerate() {
            if !res.support.contains(&j) {
                assert_eq!(b, 0.0, "beta[{j}] nonzero outside support");
            }
        }
    }
}
