//! Greedy classification trees (CART with Gini impurity).
//!
//! Plays scikit-learn's `DecisionTreeClassifier` role: the heuristic
//! baseline of Table 1's decision-tree block and the backbone's
//! `fit_subproblem` for trees (with per-subproblem feature restriction via
//! [`CartConfig::feature_subset`]). Binary labels in `{0, 1}`; split
//! search scans sorted unique thresholds with incremental class counts
//! (O(n log n) per feature per node); importances are Gini-weighted
//! impurity decreases, normalized to sum to one.

use crate::linalg::Matrix;

/// CART hyperparameters.
#[derive(Debug, Clone)]
pub struct CartConfig {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// If set, split search is restricted to these feature indices — the
    /// backbone's subproblem mechanism.
    pub feature_subset: Option<Vec<usize>>,
}

impl Default for CartConfig {
    fn default() -> Self {
        Self { max_depth: 5, min_samples_split: 2, min_samples_leaf: 1, feature_subset: None }
    }
}

/// A tree node.
#[derive(Debug, Clone)]
pub enum TreeNode {
    Leaf {
        /// P(y = 1) among training samples reaching this leaf.
        prob: f64,
        /// Training samples at the leaf.
        n: usize,
    },
    Split {
        feature: usize,
        /// Samples with `x[feature] <= threshold` go left.
        threshold: f64,
        left: Box<TreeNode>,
        right: Box<TreeNode>,
    },
}

/// A fitted CART model.
#[derive(Debug, Clone)]
pub struct CartModel {
    pub root: TreeNode,
    /// Normalized Gini importance per feature (length p).
    pub importances: Vec<f64>,
    pub depth: usize,
}

impl CartModel {
    /// P(y = 1) for each row.
    pub fn predict_proba(&self, x: &Matrix) -> Vec<f64> {
        (0..x.rows()).map(|i| proba_row(&self.root, x.row(i))).collect()
    }

    /// Hard labels at threshold 0.5.
    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        self.predict_proba(x)
            .into_iter()
            .map(|p| if p >= 0.5 { 1.0 } else { 0.0 })
            .collect()
    }

    /// Features used in at least one split.
    pub fn features_used(&self) -> Vec<usize> {
        let mut out = Vec::new();
        collect_features(&self.root, &mut out);
        out.sort_unstable();
        out.dedup();
        out
    }
}

fn proba_row(node: &TreeNode, row: &[f64]) -> f64 {
    match node {
        TreeNode::Leaf { prob, .. } => *prob,
        TreeNode::Split { feature, threshold, left, right } => {
            if row[*feature] <= *threshold {
                proba_row(left, row)
            } else {
                proba_row(right, row)
            }
        }
    }
}

fn collect_features(node: &TreeNode, out: &mut Vec<usize>) {
    if let TreeNode::Split { feature, left, right, .. } = node {
        out.push(*feature);
        collect_features(left, out);
        collect_features(right, out);
    }
}

#[inline]
fn gini(pos: f64, total: f64) -> f64 {
    if total <= 0.0 {
        return 0.0;
    }
    let p = pos / total;
    2.0 * p * (1.0 - p)
}

/// Reusable scratch for [`cart_fit_with`]: one feature-values buffer and
/// one argsort index buffer that split search refills once per (node,
/// feature) — labels are read through the sorted indices instead of
/// sorting `(value, label)` pairs. One `Default` workspace serves any
/// problem shape; contents never affect results.
#[derive(Debug, Clone, Default)]
pub struct CartWorkspace {
    vals: Vec<f64>,
    order: Vec<usize>,
}

/// Best split of `rows` on `feature`: returns (threshold, weighted child
/// impurity, n_left) or None if no valid split exists. `ws` provides the
/// caller-owned value/argsort buffers (overwritten before use). The
/// stable argsort by value induces exactly the tie order of the previous
/// pair sort — results are bit-identical.
fn best_split_on_feature(
    x: &Matrix,
    y: &[f64],
    rows: &[usize],
    feature: usize,
    min_leaf: usize,
    ws: &mut CartWorkspace,
) -> Option<(f64, f64, usize)> {
    let n = rows.len();
    let (vals, order) = (&mut ws.vals, &mut ws.order);
    vals.clear();
    vals.extend(rows.iter().map(|&i| x.get(i, feature)));
    order.clear();
    order.extend(0..n);
    order.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    let total_pos = crate::linalg::gather_sum(y, rows);

    let mut best: Option<(f64, f64, usize)> = None;
    let mut left_pos = 0.0;
    for i in 0..n - 1 {
        let (ra, rb) = (order[i], order[i + 1]);
        left_pos += y[rows[ra]];
        // Only split between distinct values.
        if vals[ra] == vals[rb] {
            continue;
        }
        let n_left = i + 1;
        let n_right = n - n_left;
        if n_left < min_leaf || n_right < min_leaf {
            continue;
        }
        let impurity = (n_left as f64 * gini(left_pos, n_left as f64)
            + n_right as f64 * gini(total_pos - left_pos, n_right as f64))
            / n as f64;
        let threshold = 0.5 * (vals[ra] + vals[rb]);
        if best.map_or(true, |(_, bi, _)| impurity < bi) {
            best = Some((threshold, impurity, n_left));
        }
    }
    best
}

struct Builder<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    cfg: &'a CartConfig,
    ws: &'a mut CartWorkspace,
    importances: Vec<f64>,
    n_total: f64,
    max_depth_seen: usize,
}

impl<'a> Builder<'a> {
    fn leaf(&self, rows: &[usize]) -> TreeNode {
        let pos = crate::linalg::gather_sum(self.y, rows);
        TreeNode::Leaf { prob: pos / rows.len().max(1) as f64, n: rows.len() }
    }

    fn build(&mut self, rows: Vec<usize>, depth: usize) -> TreeNode {
        self.max_depth_seen = self.max_depth_seen.max(depth);
        let pos = crate::linalg::gather_sum(self.y, &rows);
        let node_impurity = gini(pos, rows.len() as f64);
        if depth >= self.cfg.max_depth
            || rows.len() < self.cfg.min_samples_split
            || node_impurity == 0.0
        {
            return self.leaf(&rows);
        }

        let features: Vec<usize> = match &self.cfg.feature_subset {
            Some(s) => s.clone(),
            None => (0..self.x.cols()).collect(),
        };

        let mut best: Option<(usize, f64, f64, usize)> = None; // (feat, thr, imp, n_left)
        for &f in &features {
            if let Some((thr, imp, n_left)) = best_split_on_feature(
                self.x,
                self.y,
                &rows,
                f,
                self.cfg.min_samples_leaf,
                self.ws,
            ) {
                if best.map_or(true, |(_, _, bi, _)| imp < bi) {
                    best = Some((f, thr, imp, n_left));
                }
            }
        }

        let Some((feature, threshold, child_impurity, _)) = best else {
            return self.leaf(&rows);
        };
        // No impurity decrease → stop (prevents useless splits).
        if node_impurity - child_impurity <= 1e-12 {
            return self.leaf(&rows);
        }
        self.importances[feature] +=
            rows.len() as f64 / self.n_total * (node_impurity - child_impurity);

        let (left_rows, right_rows): (Vec<usize>, Vec<usize>) =
            rows.into_iter().partition(|&i| self.x.get(i, feature) <= threshold);
        let left = Box::new(self.build(left_rows, depth + 1));
        let right = Box::new(self.build(right_rows, depth + 1));
        TreeNode::Split { feature, threshold, left, right }
    }
}

/// Fit a CART classifier (one-shot scratch; see [`cart_fit_with`]).
pub fn cart_fit(x: &Matrix, y: &[f64], cfg: &CartConfig) -> CartModel {
    cart_fit_with(x, y, cfg, &mut CartWorkspace::default())
}

/// Fit a CART classifier borrowing caller-owned scratch — the backbone's
/// `fit_subproblem` entry point for decision trees. Bit-identical to
/// [`cart_fit`] for any workspace state.
pub fn cart_fit_with(
    x: &Matrix,
    y: &[f64],
    cfg: &CartConfig,
    ws: &mut CartWorkspace,
) -> CartModel {
    assert_eq!(x.rows(), y.len());
    assert!(x.rows() > 0, "empty training set");
    let mut b = Builder {
        x,
        y,
        cfg,
        ws,
        importances: vec![0.0; x.cols()],
        n_total: x.rows() as f64,
        max_depth_seen: 0,
    };
    let root = b.build((0..x.rows()).collect(), 0);
    // Normalize importances.
    let total: f64 = b.importances.iter().sum();
    if total > 0.0 {
        for imp in b.importances.iter_mut() {
            *imp /= total;
        }
    }
    CartModel { root, importances: b.importances, depth: b.max_depth_seen }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::classification::{generate, ClassificationConfig};
    use crate::rng::Rng;

    fn xor_data() -> (Matrix, Vec<f64>) {
        // XOR in 2D needs depth 2 — classic CART sanity check.
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for &(a, b, label) in
            &[(0.0, 0.0, 0.0), (0.0, 1.0, 1.0), (1.0, 0.0, 1.0), (1.0, 1.0, 0.0)]
        {
            for d in 0..5 {
                let eps = d as f64 * 0.01;
                rows.push(vec![a + eps, b - eps]);
                y.push(label);
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn greedy_cart_fails_xor_but_learns_and() {
        // XOR has no single split with Gini gain, so *greedy* CART stalls
        // at the root — the classic motivation for optimal trees (and for
        // the paper's exact-tree backbone). AND is greedily learnable.
        let (x, y) = xor_data();
        let m = cart_fit(&x, &y, &CartConfig { max_depth: 2, ..Default::default() });
        let acc = crate::metrics::accuracy(&y, &m.predict_proba(&x));
        assert!(acc <= 0.75, "greedy CART unexpectedly solved XOR: acc={acc}");

        let y_and: Vec<f64> = (0..x.rows())
            .map(|i| if x.get(i, 0) > 0.5 && x.get(i, 1) > 0.5 { 1.0 } else { 0.0 })
            .collect();
        let m2 = cart_fit(&x, &y_and, &CartConfig { max_depth: 2, ..Default::default() });
        let acc2 = crate::metrics::accuracy(&y_and, &m2.predict_proba(&x));
        assert!(acc2 > 0.95, "acc2={acc2}");
        assert!(m2.depth <= 2);
    }

    #[test]
    fn depth_one_cannot_learn_xor() {
        let (x, y) = xor_data();
        let m = cart_fit(&x, &y, &CartConfig { max_depth: 1, ..Default::default() });
        let acc = crate::metrics::accuracy(&y, &m.predict_proba(&x));
        assert!(acc < 0.8, "acc={acc} (depth-1 should fail XOR)");
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = Matrix::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let y = vec![1.0, 1.0, 1.0];
        let m = cart_fit(&x, &y, &CartConfig::default());
        assert!(matches!(m.root, TreeNode::Leaf { prob, .. } if prob == 1.0));
    }

    #[test]
    fn respects_feature_subset() {
        let mut rng = Rng::seed_from_u64(1);
        let d = generate(
            &ClassificationConfig {
                n: 300,
                p: 10,
                k: 3,
                n_redundant: 0,
                n_clusters: 2,
                class_sep: 2.0,
                flip_y: 0.0,
            },
            &mut rng,
        );
        let subset = vec![0, 1];
        let m = cart_fit(
            &d.x,
            &d.y,
            &CartConfig { feature_subset: Some(subset.clone()), ..Default::default() },
        );
        for f in m.features_used() {
            assert!(subset.contains(&f), "used feature {f} outside subset");
        }
    }

    #[test]
    fn importances_concentrate_on_informative_features() {
        let mut rng = Rng::seed_from_u64(2);
        let d = generate(
            &ClassificationConfig {
                n: 500,
                p: 12,
                k: 2,
                n_redundant: 0,
                n_clusters: 2,
                class_sep: 2.5,
                flip_y: 0.0,
            },
            &mut rng,
        );
        let m = cart_fit(&d.x, &d.y, &CartConfig { max_depth: 4, ..Default::default() });
        let info_mass: f64 = d.informative.iter().map(|&j| m.importances[j]).sum();
        assert!(info_mass > 0.7, "informative importance mass = {info_mass}");
        let total: f64 = m.importances.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let (x, y) = xor_data();
        let m = cart_fit(
            &x,
            &y,
            &CartConfig { max_depth: 10, min_samples_leaf: 8, ..Default::default() },
        );
        fn check(node: &TreeNode, min_leaf: usize) {
            match node {
                TreeNode::Leaf { n, .. } => assert!(*n >= min_leaf),
                TreeNode::Split { left, right, .. } => {
                    check(left, min_leaf);
                    check(right, min_leaf);
                }
            }
        }
        check(&m.root, 8);
    }

    #[test]
    fn generalizes_on_synthetic_classification() {
        let mut rng = Rng::seed_from_u64(3);
        let d = generate(&ClassificationConfig::default(), &mut rng);
        let split = crate::data::train_test_split(&d.x, &d.y, 0.3, &mut rng);
        let m = cart_fit(
            &split.x_train,
            &split.y_train,
            &CartConfig { max_depth: 4, ..Default::default() },
        );
        let auc = crate::metrics::auc(&split.y_test, &m.predict_proba(&split.x_test));
        assert!(auc > 0.6, "auc={auc}");
    }
}
