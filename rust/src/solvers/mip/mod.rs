//! Binary MILP branch-and-bound with lazy cutting planes.
//!
//! Plays Cbc's MILP role for the exact clique-partitioning clustering
//! solver. Best-first search over LP relaxations ([`crate::solvers::lp`]),
//! branching on the most fractional binary variable, with:
//!
//! - a **lazy-cut callback**: after each relaxation solve the callback may
//!   return violated valid inequalities (e.g. triangle inequalities for
//!   clique partitioning), which join a global cut pool shared by all
//!   nodes — the Grötschel–Wakabayashi cutting-plane scheme the paper's
//!   clustering formulation cites;
//! - a **rounding-heuristic callback** giving incumbents from fractional
//!   solutions, so time-outs still return a feasible solution;
//! - a wall-clock [`Budget`] honoured at node granularity.

use crate::solvers::lp::{self, Constraint, LinearProgram};
use crate::solvers::SolveStatus;
use crate::util::Budget;
use anyhow::Result;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// MILP model: an LP plus the set of variables restricted to {0, 1}.
#[derive(Debug, Clone)]
pub struct Mip {
    pub lp: LinearProgram,
    /// Indices of binary variables (bounds must be within [0, 1]).
    pub binaries: Vec<usize>,
}

/// Solver knobs.
#[derive(Debug, Clone)]
pub struct MipConfig {
    /// Relative optimality-gap tolerance.
    pub gap_tol: f64,
    /// Node cap (0 = unlimited).
    pub max_nodes: usize,
    /// Max cut-generation rounds per node.
    pub max_cut_rounds: usize,
    /// Integrality tolerance.
    pub int_tol: f64,
}

impl Default for MipConfig {
    fn default() -> Self {
        Self { gap_tol: 1e-6, max_nodes: 0, max_cut_rounds: 25, int_tol: 1e-6 }
    }
}

/// Callbacks customizing the search (both optional).
pub struct Callbacks<'a> {
    /// Given a fractional LP solution, return violated valid inequalities.
    pub cuts: Option<&'a dyn Fn(&[f64]) -> Vec<Constraint>>,
    /// Given a fractional LP solution, return a feasible integral solution
    /// (used to update the incumbent).
    pub heuristic: Option<&'a dyn Fn(&[f64]) -> Option<Vec<f64>>>,
}

impl<'a> Default for Callbacks<'a> {
    fn default() -> Self {
        Self { cuts: None, heuristic: None }
    }
}

/// Result of a MILP solve.
#[derive(Debug, Clone)]
pub struct MipResult {
    pub status: SolveStatus,
    /// Incumbent solution (empty if none found).
    pub x: Vec<f64>,
    pub objective: f64,
    pub lower_bound: f64,
    pub gap: f64,
    pub nodes_explored: usize,
    pub cuts_added: usize,
    pub elapsed_secs: f64,
}

struct Node {
    bound: f64,
    /// (variable, lower, upper) overrides relative to the root LP.
    fixings: Vec<(usize, f64, f64)>,
}

impl PartialEq for Node {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Node {
    fn cmp(&self, other: &Self) -> Ordering {
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

/// Check integrality of the binary variables.
fn fractional_var(x: &[f64], binaries: &[usize], tol: f64) -> Option<usize> {
    let mut worst: Option<(usize, f64)> = None;
    for &j in binaries {
        let frac = (x[j] - x[j].round()).abs();
        if frac > tol && worst.map_or(true, |(_, w)| (0.5 - frac).abs() < (0.5 - w).abs()) {
            worst = Some((j, frac));
        }
    }
    worst.map(|(j, _)| j)
}

/// Objective value of a point under the MIP objective.
fn obj_value(lp: &LinearProgram, x: &[f64]) -> f64 {
    lp.objective.iter().zip(x).map(|(c, v)| c * v).sum()
}

/// Feasibility check of an integral candidate against all constraints.
fn is_feasible(lp: &LinearProgram, cuts: &[Constraint], x: &[f64], tol: f64) -> bool {
    let check = |c: &Constraint| {
        let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * x[j]).sum();
        match c.sense {
            lp::Sense::Le => lhs <= c.rhs + tol,
            lp::Sense::Ge => lhs >= c.rhs - tol,
            lp::Sense::Eq => (lhs - c.rhs).abs() <= tol,
        }
    };
    lp.constraints.iter().all(check)
        && cuts.iter().all(check)
        && lp
            .bounds
            .iter()
            .enumerate()
            .all(|(j, &(l, u))| x[j] >= l - tol && x[j] <= u + tol)
}

/// Solve the MILP (minimization).
pub fn mip_solve(
    mip: &Mip,
    cfg: &MipConfig,
    budget: &Budget,
    callbacks: &Callbacks,
) -> Result<MipResult> {
    let watch = crate::util::Stopwatch::start();
    let mut cut_pool: Vec<Constraint> = Vec::new();
    let mut incumbent: Option<(Vec<f64>, f64)> = None;
    let mut nodes = 0usize;
    let mut cuts_added = 0usize;

    let mut heap: BinaryHeap<Node> = BinaryHeap::new();
    heap.push(Node { bound: f64::NEG_INFINITY, fixings: vec![] });
    let mut best_open;

    let result = |incumbent: &Option<(Vec<f64>, f64)>,
                  lower: f64,
                  status: SolveStatus,
                  nodes: usize,
                  cuts_added: usize,
                  watch: &crate::util::Stopwatch| {
        let (x, objective) = match incumbent {
            Some((x, o)) => (x.clone(), *o),
            None => (vec![], f64::INFINITY),
        };
        // The incumbent is attained, so the global lower bound can never
        // exceed it even when every open node's bound does.
        let lower = if objective.is_finite() { lower.min(objective) } else { lower };
        let gap = if objective.is_finite() && objective.abs() > 1e-12 {
            ((objective - lower) / objective.abs()).max(0.0)
        } else if objective.is_finite() {
            0.0
        } else {
            f64::INFINITY
        };
        MipResult {
            status,
            x,
            objective,
            lower_bound: lower,
            gap,
            nodes_explored: nodes,
            cuts_added,
            elapsed_secs: watch.elapsed_secs(),
        }
    };

    while let Some(node) = heap.pop() {
        best_open = node.bound;
        if let Some((_, inc_obj)) = &incumbent {
            if node.bound.is_finite()
                && inc_obj - node.bound <= cfg.gap_tol * inc_obj.abs().max(1e-12)
            {
                return Ok(result(
                    &incumbent,
                    node.bound,
                    SolveStatus::Optimal,
                    nodes,
                    cuts_added,
                    &watch,
                ));
            }
        }
        if budget.expired() {
            let status = if incumbent.is_some() {
                SolveStatus::TimedOut
            } else {
                SolveStatus::TimedOut
            };
            return Ok(result(&incumbent, best_open, status, nodes, cuts_added, &watch));
        }
        if cfg.max_nodes > 0 && nodes >= cfg.max_nodes {
            return Ok(result(
                &incumbent,
                best_open,
                SolveStatus::NodeLimit,
                nodes,
                cuts_added,
                &watch,
            ));
        }
        nodes += 1;

        // Build the node LP: root LP + cut pool + bound fixings.
        let mut node_lp = mip.lp.clone();
        node_lp.constraints.extend(cut_pool.iter().cloned());
        for &(j, l, u) in &node.fixings {
            node_lp.bounds[j] = (l, u);
        }

        // Cut loop: solve, ask for violated cuts, repeat. An LP failure
        // (iteration limit on a degenerate relaxation) is treated like
        // budget exhaustion: return the incumbent honestly as TimedOut
        // rather than crashing the whole experiment.
        let mut sol = match lp::solve(&node_lp) {
            Ok(s) => s,
            Err(_) => {
                return Ok(result(
                    &incumbent,
                    best_open,
                    SolveStatus::TimedOut,
                    nodes,
                    cuts_added,
                    &watch,
                ));
            }
        };
        let mut rounds = 0;
        while sol.status == SolveStatus::Optimal && rounds < cfg.max_cut_rounds {
            if budget.expired() {
                break;
            }
            let Some(cut_fn) = callbacks.cuts else { break };
            let new_cuts = cut_fn(&sol.x);
            if new_cuts.is_empty() {
                break;
            }
            rounds += 1;
            cuts_added += new_cuts.len();
            for c in new_cuts {
                node_lp.constraints.push(c.clone());
                cut_pool.push(c);
            }
            sol = match lp::solve(&node_lp) {
                Ok(s) => s,
                Err(_) => {
                    return Ok(result(
                        &incumbent,
                        best_open,
                        SolveStatus::TimedOut,
                        nodes,
                        cuts_added,
                        &watch,
                    ));
                }
            };
        }

        match sol.status {
            SolveStatus::Infeasible => continue, // prune
            SolveStatus::Unbounded => {
                // Binary MIPs over bounded boxes cannot be unbounded unless
                // continuous vars are; surface as unbounded.
                return Ok(result(
                    &incumbent,
                    f64::NEG_INFINITY,
                    SolveStatus::Unbounded,
                    nodes,
                    cuts_added,
                    &watch,
                ));
            }
            _ => {}
        }
        let bound = sol.objective;
        if let Some((_, inc_obj)) = &incumbent {
            if bound >= inc_obj - cfg.gap_tol * inc_obj.abs().max(1e-12) {
                continue; // prune by bound
            }
        }

        // Heuristic incumbent from the fractional solution.
        if let Some(heur_fn) = callbacks.heuristic {
            if let Some(cand) = heur_fn(&sol.x) {
                if is_feasible(&mip.lp, &cut_pool, &cand, 1e-6)
                    && fractional_var(&cand, &mip.binaries, cfg.int_tol).is_none()
                {
                    let obj = obj_value(&mip.lp, &cand);
                    if incumbent.as_ref().map_or(true, |(_, o)| obj < *o) {
                        incumbent = Some((cand, obj));
                    }
                }
            }
        }

        match fractional_var(&sol.x, &mip.binaries, cfg.int_tol) {
            None => {
                // Integral: before accepting, give the lazy-cut callback a
                // final veto — the cut-round cap above may have left valid
                // inequalities ungenerated (e.g. transitivity triangles),
                // in which case the point is NOT feasible for the true
                // model and the node must be re-queued with the new cuts.
                if let Some(cut_fn) = callbacks.cuts {
                    let veto = cut_fn(&sol.x);
                    if !veto.is_empty() {
                        cuts_added += veto.len();
                        cut_pool.extend(veto);
                        heap.push(Node { bound, fixings: node.fixings });
                        continue;
                    }
                }
                let obj = sol.objective;
                if incumbent.as_ref().map_or(true, |(_, o)| obj < *o) {
                    // Round binaries exactly.
                    let mut x = sol.x.clone();
                    for &j in &mip.binaries {
                        x[j] = x[j].round();
                    }
                    incumbent = Some((x, obj));
                }
            }
            Some(j) => {
                // Branch.
                let mut fix0 = node.fixings.clone();
                fix0.push((j, 0.0, 0.0));
                heap.push(Node { bound, fixings: fix0 });
                let mut fix1 = node.fixings;
                fix1.push((j, 1.0, 1.0));
                heap.push(Node { bound, fixings: fix1 });
            }
        }
    }

    // Tree exhausted.
    let status = if incumbent.is_some() { SolveStatus::Optimal } else { SolveStatus::Infeasible };
    let lower = incumbent.as_ref().map_or(f64::INFINITY, |(_, o)| *o);
    Ok(result(&incumbent, lower, status, nodes, cuts_added, &watch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::lp::Sense;

    /// Brute-force binary optimum for cross-checking (all vars binary).
    fn brute(mip: &Mip) -> Option<(Vec<f64>, f64)> {
        let n = mip.lp.n_vars;
        assert!(n <= 20);
        let mut best: Option<(Vec<f64>, f64)> = None;
        for mask in 0u32..(1 << n) {
            let x: Vec<f64> =
                (0..n).map(|j| if mask & (1 << j) != 0 { 1.0 } else { 0.0 }).collect();
            if is_feasible(&mip.lp, &[], &x, 1e-9) {
                let obj = obj_value(&mip.lp, &x);
                if best.as_ref().map_or(true, |(_, o)| obj < *o) {
                    best = Some((x, obj));
                }
            }
        }
        best
    }

    fn knapsack(values: &[f64], weights: &[f64], cap: f64) -> Mip {
        let n = values.len();
        let mut lp = LinearProgram::new(n);
        lp.objective = values.iter().map(|v| -v).collect(); // maximize value
        lp.bounds = vec![(0.0, 1.0); n];
        lp.add_constraint(
            weights.iter().enumerate().map(|(j, &w)| (j, w)).collect(),
            Sense::Le,
            cap,
        );
        Mip { lp, binaries: (0..n).collect() }
    }

    #[test]
    fn solves_knapsack_exactly() {
        let mip = knapsack(&[10.0, 13.0, 7.0, 8.0], &[3.0, 4.0, 2.0, 3.0], 7.0);
        let res =
            mip_solve(&mip, &MipConfig::default(), &Budget::unlimited(), &Callbacks::default())
                .unwrap();
        assert_eq!(res.status, SolveStatus::Optimal);
        let (bx, bobj) = brute(&mip).unwrap();
        assert!((res.objective - bobj).abs() < 1e-6, "{} vs {bobj}", res.objective);
        assert_eq!(res.x, bx);
    }

    #[test]
    fn random_binary_mips_match_brute_force() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from_u64(21);
        for trial in 0..10 {
            let n = 8;
            let mut lp = LinearProgram::new(n);
            lp.bounds = vec![(0.0, 1.0); n];
            for j in 0..n {
                lp.objective[j] = rng.uniform(-1.0, 1.0);
            }
            for _ in 0..3 {
                let coeffs: Vec<(usize, f64)> =
                    (0..n).map(|j| (j, rng.uniform(-1.0, 1.0))).collect();
                lp.add_constraint(coeffs, Sense::Le, rng.uniform(0.0, 2.0));
            }
            let mip = Mip { lp, binaries: (0..n).collect() };
            let res = mip_solve(
                &mip,
                &MipConfig::default(),
                &Budget::unlimited(),
                &Callbacks::default(),
            )
            .unwrap();
            match brute(&mip) {
                Some((_, bobj)) => {
                    assert_eq!(res.status, SolveStatus::Optimal, "trial {trial}");
                    assert!(
                        (res.objective - bobj).abs() < 1e-6,
                        "trial {trial}: {} vs {bobj}",
                        res.objective
                    );
                }
                None => {
                    assert_eq!(res.status, SolveStatus::Infeasible, "trial {trial}");
                }
            }
        }
    }

    #[test]
    fn infeasible_mip_detected() {
        let mut lp = LinearProgram::new(2);
        lp.bounds = vec![(0.0, 1.0); 2];
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Sense::Ge, 3.0);
        let mip = Mip { lp, binaries: vec![0, 1] };
        let res =
            mip_solve(&mip, &MipConfig::default(), &Budget::unlimited(), &Callbacks::default())
                .unwrap();
        assert_eq!(res.status, SolveStatus::Infeasible);
    }

    #[test]
    fn timeout_returns_heuristic_incumbent() {
        let mip = knapsack(
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
            &[1.0; 8],
            4.0,
        );
        let heuristic = |x: &[f64]| -> Option<Vec<f64>> {
            // Greedy rounding: take the 4 largest fractional values.
            let mut idx: Vec<usize> = (0..x.len()).collect();
            idx.sort_by(|&a, &b| x[b].partial_cmp(&x[a]).unwrap());
            let mut out = vec![0.0; x.len()];
            for &j in idx.iter().take(4) {
                out[j] = 1.0;
            }
            Some(out)
        };
        let callbacks = Callbacks { cuts: None, heuristic: Some(&heuristic) };
        // Budget expires after the first node (enough to run the heuristic once).
        let res = mip_solve(
            &mip,
            &MipConfig { max_nodes: 1, ..Default::default() },
            &Budget::unlimited(),
            &callbacks,
        )
        .unwrap();
        // Either finished optimally in one node or returned the rounded incumbent.
        assert!(res.status.has_solution());
        assert!(!res.x.is_empty());
    }

    #[test]
    fn cut_callback_tightens_relaxation() {
        // min -(x+y) s.t. x + y ≤ 1.5 → LP gives 1.5; cut x + y ≤ 1 forces
        // the integral optimum in fewer nodes.
        let mut lp = LinearProgram::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.bounds = vec![(0.0, 1.0); 2];
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Sense::Le, 1.5);
        let mip = Mip { lp, binaries: vec![0, 1] };
        let cuts = |x: &[f64]| -> Vec<Constraint> {
            if x[0] + x[1] > 1.0 + 1e-6 {
                vec![Constraint { coeffs: vec![(0, 1.0), (1, 1.0)], sense: Sense::Le, rhs: 1.0 }]
            } else {
                vec![]
            }
        };
        let callbacks = Callbacks { cuts: Some(&cuts), heuristic: None };
        let res =
            mip_solve(&mip, &MipConfig::default(), &Budget::unlimited(), &callbacks).unwrap();
        assert_eq!(res.status, SolveStatus::Optimal);
        assert!((res.objective + 1.0).abs() < 1e-6);
        assert!(res.cuts_added >= 1);
        assert_eq!(res.nodes_explored, 1, "cut should close the root node");
    }

    #[test]
    fn respects_node_limit() {
        let mip = knapsack(&[5.0, 4.0, 3.0, 2.0, 1.0, 6.0], &[2.0, 3.0, 1.0, 4.0, 2.0, 3.0], 6.0);
        let res = mip_solve(
            &mip,
            &MipConfig { max_nodes: 2, ..Default::default() },
            &Budget::unlimited(),
            &Callbacks::default(),
        )
        .unwrap();
        assert!(res.nodes_explored <= 2);
    }
}
