//! k-means clustering (k-means++ initialization + Lloyd iterations).
//!
//! Plays scikit-learn's `KMeans` role: the heuristic baseline of Table 1's
//! clustering block and the backbone's `fit_subproblem` for clustering.
//! `n_init` restarts keep the best inertia, matching sklearn defaults.
//!
//! The Lloyd assignment step (pairwise point↔centroid distances) is the
//! clustering hot spot; when a PJRT artifact of matching shape is loaded,
//! the backbone routes it through the AOT-compiled Pallas
//! `pairwise_sqdist` kernel (see `runtime`), with this implementation as
//! the fallback/oracle.
//!
//! Native distances use the expanded form `‖x−c‖² = ‖x‖² + ‖c‖² − 2·x·c`
//! (clamped at zero against rounding): point norms come from the
//! matrix-level memo ([`Matrix::row_sq_norms`], computed once per fit and
//! shared by every restart), centroid norms from the same memo on the
//! centroid matrix (recomputed lazily only after an update step mutates
//! it). Each point↔centroid candidate then costs a single dot product.
//! Both [`dot`] and the [`sqdist`] used for exact distances (empty-cluster
//! reseeding, tolerance checks) are backend-dispatched 4-accumulator
//! kernels (blocked scalar or AVX2 — bit-identical; see
//! `linalg::backend`).

use crate::linalg::{dot, sqdist, Matrix};
use crate::rng::Rng;

/// k-means hyperparameters.
#[derive(Debug, Clone)]
pub struct KMeansConfig {
    /// Number of clusters.
    pub k: usize,
    /// Independent restarts (best inertia kept).
    pub n_init: usize,
    /// Max Lloyd iterations per restart.
    pub max_iter: usize,
    /// Convergence tolerance on centroid movement (squared L2).
    pub tol: f64,
}

impl Default for KMeansConfig {
    fn default() -> Self {
        Self { k: 5, n_init: 10, max_iter: 300, tol: 1e-8 }
    }
}

/// A fitted k-means model.
#[derive(Debug, Clone)]
pub struct KMeansModel {
    /// Cluster index per point.
    pub labels: Vec<usize>,
    /// k × p centroid matrix.
    pub centroids: Matrix,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    /// Lloyd iterations used by the winning restart.
    pub iterations: usize,
}

impl KMeansModel {
    /// Assign new points to the nearest centroid.
    pub fn predict(&self, x: &Matrix) -> Vec<usize> {
        let xn = x.row_sq_norms();
        let cn = self.centroids.row_sq_norms();
        (0..x.rows())
            .map(|i| nearest_centroid_normed(x.row(i), xn[i], &self.centroids, cn).0)
            .collect()
    }
}

/// Reusable scratch for [`kmeans_fit_with`]: the Lloyd-iteration label /
/// distance / accumulator buffers plus a reusable point-subset matrix for
/// callers that restrict rows per fit. Buffers are resized on entry, so
/// one `Default` workspace serves any problem shape; contents never affect
/// results.
#[derive(Debug, Clone, Default)]
pub struct KMeansWorkspace {
    /// Caller-owned row-restricted point matrix (`select_rows_into`).
    pub xs: Matrix,
    labels: Vec<usize>,
    d2: Vec<f64>,
    sums: Matrix,
    counts: Vec<usize>,
}

/// Nearest centroid via cached squared norms: `point_sq` is `‖point‖²`,
/// `cent_sq[c]` is `‖centroid_c‖²`. Used identically by Lloyd's final
/// assignment and [`KMeansModel::predict`], so training labels and
/// re-prediction agree bit-for-bit.
fn nearest_centroid_normed(
    point: &[f64],
    point_sq: f64,
    centroids: &Matrix,
    cent_sq: &[f64],
) -> (usize, f64) {
    let mut best = (0, f64::INFINITY);
    for c in 0..centroids.rows() {
        let d = (point_sq + cent_sq[c] - 2.0 * dot(point, centroids.row(c))).max(0.0);
        if d < best.1 {
            best = (c, d);
        }
    }
    best
}

/// k-means++ seeding: first center uniform, subsequent centers sampled
/// with probability proportional to the squared distance to the nearest
/// chosen center. `d2` is a caller-owned distance buffer.
fn kmeanspp_init(x: &Matrix, k: usize, rng: &mut Rng, d2: &mut Vec<f64>) -> Matrix {
    let n = x.rows();
    let xn = x.row_sq_norms();
    // Point-to-point distance from the shared norm memo (clamped ≥ 0).
    let sq = |a: usize, b: usize| (xn[a] + xn[b] - 2.0 * dot(x.row(a), x.row(b))).max(0.0);
    let mut centers: Vec<usize> = vec![rng.usize_below(n)];
    d2.clear();
    d2.extend((0..n).map(|i| sq(i, centers[0])));
    while centers.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-300 {
            // All points coincide with chosen centers; pick uniformly.
            rng.usize_below(n)
        } else {
            rng.categorical(&d2[..])
        };
        centers.push(next);
        for i in 0..n {
            d2[i] = d2[i].min(sq(i, next));
        }
    }
    let mut c = Matrix::zeros(k, x.cols());
    for (ci, &i) in centers.iter().enumerate() {
        c.row_mut(ci).copy_from_slice(x.row(i));
    }
    c
}

/// One restart of Lloyd's algorithm from the given initial centroids,
/// borrowing the workspace's label/accumulator buffers.
fn lloyd(
    x: &Matrix,
    mut centroids: Matrix,
    cfg: &KMeansConfig,
    ws: &mut KMeansWorkspace,
) -> KMeansModel {
    let (n, p) = (x.rows(), x.cols());
    let k = centroids.rows();
    let xn = x.row_sq_norms(); // memoized once, shared across restarts
    ws.labels.clear();
    ws.labels.resize(n, 0);
    let mut iterations = 0;
    for it in 0..cfg.max_iter {
        iterations = it + 1;
        // Assignment step. Centroid norms are the matrix memo: the update
        // step's mutations invalidated it, so this recomputes O(kp) once
        // per iteration, then every candidate is a single dot product.
        let cn = centroids.row_sq_norms();
        for i in 0..n {
            ws.labels[i] = nearest_centroid_normed(x.row(i), xn[i], &centroids, cn).0;
        }
        // Update step (sums/counts reused across iterations and fits).
        if ws.sums.rows() != k || ws.sums.cols() != p {
            ws.sums = Matrix::zeros(k, p);
        } else {
            ws.sums.data_mut().iter_mut().for_each(|v| *v = 0.0);
        }
        ws.counts.clear();
        ws.counts.resize(k, 0);
        for i in 0..n {
            let li = ws.labels[i];
            ws.counts[li] += 1;
            let row = x.row(i);
            let srow = ws.sums.row_mut(li);
            for (s, &v) in srow.iter_mut().zip(row) {
                *s += v;
            }
        }
        let mut movement = 0.0f64;
        for c in 0..k {
            if ws.counts[c] == 0 {
                // Empty cluster: re-seed at the point farthest from its
                // centroid (standard fix; keeps k clusters alive).
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sqdist(x.row(a), centroids.row(ws.labels[a]));
                        let db = sqdist(x.row(b), centroids.row(ws.labels[b]));
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                let target: Vec<f64> = x.row(far).to_vec();
                movement += sqdist(centroids.row(c), &target);
                centroids.row_mut(c).copy_from_slice(&target);
                continue;
            }
            let inv = 1.0 / ws.counts[c] as f64;
            let new: Vec<f64> = ws.sums.row(c).iter().map(|s| s * inv).collect();
            movement += sqdist(centroids.row(c), &new);
            centroids.row_mut(c).copy_from_slice(&new);
        }
        if movement < cfg.tol {
            break;
        }
    }
    crate::obs::add_solver_iterations("lloyd", iterations as u64);
    // Final assignment + inertia.
    let cn = centroids.row_sq_norms();
    let mut inertia = 0.0;
    for i in 0..n {
        let (c, d) = nearest_centroid_normed(x.row(i), xn[i], &centroids, cn);
        ws.labels[i] = c;
        inertia += d;
    }
    KMeansModel { labels: ws.labels.clone(), centroids, inertia, iterations }
}

/// Fit k-means with `cfg.n_init` k-means++ restarts (one-shot scratch;
/// see [`kmeans_fit_with`]).
pub fn kmeans_fit(x: &Matrix, cfg: &KMeansConfig, rng: &mut Rng) -> KMeansModel {
    kmeans_fit_with(x, cfg, rng, &mut KMeansWorkspace::default())
}

/// Fit k-means borrowing caller-owned scratch — the backbone's
/// `fit_subproblem` entry point for clustering. Bit-identical to
/// [`kmeans_fit`] for any workspace state.
pub fn kmeans_fit_with(
    x: &Matrix,
    cfg: &KMeansConfig,
    rng: &mut Rng,
    ws: &mut KMeansWorkspace,
) -> KMeansModel {
    assert!(cfg.k >= 1 && x.rows() >= cfg.k, "need at least k points");
    let mut best: Option<KMeansModel> = None;
    for _ in 0..cfg.n_init.max(1) {
        let init = kmeanspp_init(x, cfg.k, rng, &mut ws.d2);
        let model = lloyd(x, init, cfg, ws);
        if best.as_ref().map_or(true, |b| model.inertia < b.inertia) {
            best = Some(model);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{generate, BlobsConfig};
    use crate::metrics::adjusted_rand_index;

    fn blob_data(k: usize) -> crate::data::blobs::BlobsData {
        let cfg = BlobsConfig {
            n: 150,
            p: 2,
            true_clusters: k,
            cluster_std: 0.4,
            center_box: 10.0,
            min_center_dist: 6.0,
        };
        generate(&cfg, &mut Rng::seed_from_u64(3))
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let data = blob_data(3);
        let m = kmeans_fit(
            &data.x,
            &KMeansConfig { k: 3, ..Default::default() },
            &mut Rng::seed_from_u64(1),
        );
        let ari = adjusted_rand_index(&m.labels, &data.labels_true);
        assert!(ari > 0.95, "ari={ari}");
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let data = blob_data(3);
        let mut prev = f64::INFINITY;
        for k in [1, 2, 3, 5] {
            let m = kmeans_fit(
                &data.x,
                &KMeansConfig { k, ..Default::default() },
                &mut Rng::seed_from_u64(2),
            );
            assert!(m.inertia <= prev + 1e-9, "k={k}: {} > {prev}", m.inertia);
            prev = m.inertia;
        }
    }

    #[test]
    fn workspace_reuse_is_bit_identical_to_fresh_scratch() {
        // One workspace reused across differently-shaped fits must give
        // exactly what fresh scratch gives — the contract that lets the
        // batch scheduler hand one workspace per worker thread.
        let mut ws = KMeansWorkspace::default();
        for (k, seed) in [(2usize, 4u64), (5, 5), (3, 6)] {
            let data = blob_data(3);
            let cfg = KMeansConfig { k, ..Default::default() };
            let fresh = kmeans_fit(&data.x, &cfg, &mut Rng::seed_from_u64(seed));
            let reused =
                kmeans_fit_with(&data.x, &cfg, &mut Rng::seed_from_u64(seed), &mut ws);
            assert_eq!(fresh.labels, reused.labels);
            assert_eq!(fresh.inertia, reused.inertia);
            assert_eq!(fresh.centroids, reused.centroids);
        }
    }

    #[test]
    fn all_clusters_nonempty() {
        let data = blob_data(3);
        let m = kmeans_fit(
            &data.x,
            &KMeansConfig { k: 5, ..Default::default() },
            &mut Rng::seed_from_u64(4),
        );
        for c in 0..5 {
            assert!(m.labels.iter().any(|&l| l == c), "cluster {c} empty");
        }
    }

    #[test]
    fn predict_consistent_with_training_labels() {
        let data = blob_data(3);
        let m = kmeans_fit(
            &data.x,
            &KMeansConfig { k: 3, ..Default::default() },
            &mut Rng::seed_from_u64(5),
        );
        let again = m.predict(&data.x);
        assert_eq!(m.labels, again);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = blob_data(3);
        let cfg = KMeansConfig { k: 3, ..Default::default() };
        let a = kmeans_fit(&data.x, &cfg, &mut Rng::seed_from_u64(6));
        let b = kmeans_fit(&data.x, &cfg, &mut Rng::seed_from_u64(6));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn single_cluster_centroid_is_mean() {
        let data = blob_data(2);
        let m = kmeans_fit(
            &data.x,
            &KMeansConfig { k: 1, n_init: 1, ..Default::default() },
            &mut Rng::seed_from_u64(7),
        );
        let means = data.x.col_means();
        for (c, m_val) in m.centroids.row(0).iter().enumerate() {
            assert!((m_val - means[c]).abs() < 1e-9);
        }
    }
}
