//! Optimal (provably minimal-misclassification) shallow decision trees
//! over *binary* features — the ODTLearn role in the paper.
//!
//! Exhaustive depth-bounded search with branch-and-bound pruning à la
//! DL8.5 / Quant-BnB: at each node every candidate feature's split is
//! explored recursively, keeping the best subtree; the search threads an
//! upper bound (`best error so far`) through siblings so whole subtrees
//! are pruned once they cannot beat the incumbent, and honours a
//! wall-clock [`Budget`], returning the greedy incumbent with
//! [`SolveStatus::TimedOut`] when exhausted — exactly how Table 1's
//! ODTLearn row reports 3600 s at (n, p) = (500, 100).
//!
//! Continuous inputs are binarized upstream (see [`crate::data::binarize`]);
//! the backbone maps selected binary columns back to original features via
//! `Binarized::feature_of`.

use crate::linalg::Matrix;
use crate::solvers::SolveStatus;
use crate::util::Budget;

/// Exact-tree hyperparameters.
#[derive(Debug, Clone)]
pub struct ExactTreeConfig {
    /// Maximum tree depth (number of split levels).
    pub depth: usize,
    /// Minimum samples per (non-empty) leaf.
    pub min_leaf: usize,
    /// Restrict split search to these binary-column indices.
    pub feature_subset: Option<Vec<usize>>,
}

impl Default for ExactTreeConfig {
    fn default() -> Self {
        Self { depth: 2, min_leaf: 1, feature_subset: None }
    }
}

/// Tree over binary features.
///
/// `PartialEq` is structural (probabilities compared exactly) — the
/// determinism suite uses it to assert parallel and sequential backbone
/// runs produce bit-identical trees.
#[derive(Debug, Clone, PartialEq)]
pub enum BinNode {
    Leaf {
        prob: f64,
        n: usize,
    },
    Split {
        /// Binary column index; rows with value 0 go left, 1 goes right.
        feature: usize,
        left: Box<BinNode>,
        right: Box<BinNode>,
    },
}

/// Result of an exact-tree solve.
#[derive(Debug, Clone)]
pub struct ExactTreeResult {
    pub root: BinNode,
    /// Training misclassification count of the returned tree.
    pub errors: usize,
    /// Lower bound on the optimal misclassification count (equals `errors`
    /// when status is `Optimal`).
    pub lower_bound: usize,
    pub status: SolveStatus,
    /// Number of (node, feature) split evaluations performed.
    pub evaluations: usize,
    pub elapsed_secs: f64,
}

impl ExactTreeResult {
    pub fn predict_proba(&self, x_bin: &Matrix) -> Vec<f64> {
        (0..x_bin.rows()).map(|i| proba_row(&self.root, x_bin.row(i))).collect()
    }

    pub fn predict(&self, x_bin: &Matrix) -> Vec<f64> {
        self.predict_proba(x_bin)
            .into_iter()
            .map(|p| if p >= 0.5 { 1.0 } else { 0.0 })
            .collect()
    }

    /// Binary columns used in at least one split.
    pub fn features_used(&self) -> Vec<usize> {
        let mut out = Vec::new();
        collect(&self.root, &mut out);
        out.sort_unstable();
        out.dedup();
        out
    }
}

fn proba_row(node: &BinNode, row: &[f64]) -> f64 {
    match node {
        BinNode::Leaf { prob, .. } => *prob,
        BinNode::Split { feature, left, right } => {
            if row[*feature] <= 0.5 {
                proba_row(left, row)
            } else {
                proba_row(right, row)
            }
        }
    }
}

fn collect(node: &BinNode, out: &mut Vec<usize>) {
    if let BinNode::Split { feature, left, right } = node {
        out.push(*feature);
        collect(left, out);
        collect(right, out);
    }
}

struct Search<'a> {
    x: &'a Matrix,
    y: &'a [f64],
    features: Vec<usize>,
    min_leaf: usize,
    budget: &'a Budget,
    evaluations: usize,
    timed_out: bool,
}

/// (error count, positives, total) for a leaf on `rows`.
fn leaf_stats(y: &[f64], rows: &[usize]) -> (usize, f64, usize) {
    let pos = rows.iter().filter(|&&i| y[i] >= 0.5).count();
    let neg = rows.len() - pos;
    (pos.min(neg), pos as f64, rows.len())
}

fn make_leaf(y: &[f64], rows: &[usize], parent_prob: f64) -> BinNode {
    if rows.is_empty() {
        return BinNode::Leaf { prob: parent_prob, n: 0 };
    }
    let (_, pos, n) = leaf_stats(y, rows);
    BinNode::Leaf { prob: pos / n as f64, n }
}

impl<'a> Search<'a> {
    /// Optimal subtree on `rows` with `depth` levels left, beating
    /// `ub` (strict) or returning None. Returns (errors, tree).
    fn solve(
        &mut self,
        rows: &[usize],
        depth: usize,
        ub: usize,
        parent_prob: f64,
    ) -> Option<(usize, BinNode)> {
        let (leaf_err, pos, n) = leaf_stats(self.y, rows);
        let prob = if n > 0 { pos / n as f64 } else { parent_prob };
        let mut best: Option<(usize, BinNode)> = if leaf_err < ub {
            Some((leaf_err, make_leaf(self.y, rows, parent_prob)))
        } else {
            None
        };
        // A leaf with zero error is unbeatable; splits cannot help.
        if depth == 0 || leaf_err == 0 || rows.len() < 2 * self.min_leaf {
            return best;
        }
        if self.budget.expired() {
            self.timed_out = true;
            return best;
        }

        let mut ub = ub.min(best.as_ref().map_or(ub, |(e, _)| *e));
        let feats = self.features.clone();
        for f in feats {
            if self.budget.expired() {
                self.timed_out = true;
                break;
            }
            self.evaluations += 1;
            let (left_rows, right_rows): (Vec<usize>, Vec<usize>) =
                rows.iter().partition(|&&i| self.x.get(i, f) <= 0.5);
            // Degenerate split: no information.
            if left_rows.is_empty() && right_rows.is_empty() {
                continue;
            }
            if (!left_rows.is_empty() && left_rows.len() < self.min_leaf)
                || (!right_rows.is_empty() && right_rows.len() < self.min_leaf)
            {
                continue;
            }
            // Left subtree must beat ub on its own.
            let Some((le, lt)) = self.solve(&left_rows, depth - 1, ub, prob) else {
                continue;
            };
            if le >= ub {
                continue;
            }
            // Right subtree gets the remaining error budget.
            let Some((re, rt)) = self.solve(&right_rows, depth - 1, ub - le, prob) else {
                continue;
            };
            let total = le + re;
            if total < ub {
                ub = total;
                best = Some((
                    total,
                    BinNode::Split { feature: f, left: Box::new(lt), right: Box::new(rt) },
                ));
                if total == 0 {
                    break; // perfect subtree
                }
            }
        }
        best
    }
}

/// Solve for the optimal depth-bounded tree on binary features.
pub fn exact_tree_solve(
    x_bin: &Matrix,
    y: &[f64],
    cfg: &ExactTreeConfig,
    budget: &Budget,
) -> ExactTreeResult {
    assert_eq!(x_bin.rows(), y.len());
    assert!(x_bin.rows() > 0, "empty training set");
    let watch = crate::util::Stopwatch::start();
    let features: Vec<usize> = match &cfg.feature_subset {
        Some(s) => s.clone(),
        None => (0..x_bin.cols()).collect(),
    };
    let rows: Vec<usize> = (0..x_bin.rows()).collect();
    let (root_err, pos, n) = leaf_stats(y, &rows);
    let root_prob = pos / n as f64;

    let mut search = Search {
        x: x_bin,
        y,
        features,
        min_leaf: cfg.min_leaf,
        budget,
        evaluations: 0,
        timed_out: false,
    };
    // ub = root_err + 1 so the root leaf itself is admissible.
    let (errors, root) = search
        .solve(&rows, cfg.depth, root_err + 1, root_prob)
        .expect("root leaf is always admissible");

    let status = if search.timed_out { SolveStatus::TimedOut } else { SolveStatus::Optimal };
    let lower_bound = if search.timed_out { 0 } else { errors };
    ExactTreeResult {
        root,
        errors,
        lower_bound,
        status,
        evaluations: search.evaluations,
        elapsed_secs: watch.elapsed_secs(),
    }
}

/// Brute-force reference for tests: enumerate all depth-≤1 or depth-≤2
/// trees explicitly (no pruning). Exponential; tiny inputs only.
pub fn brute_force_depth2_errors(x_bin: &Matrix, y: &[f64]) -> usize {
    let rows: Vec<usize> = (0..x_bin.rows()).collect();
    let leaf_err = |rows: &[usize]| leaf_stats(y, rows).0;
    let mut best = leaf_err(&rows);
    let p = x_bin.cols();
    let split = |rows: &[usize], f: usize| -> (Vec<usize>, Vec<usize>) {
        rows.iter().partition(|&&i| x_bin.get(i, f) <= 0.5)
    };
    for f0 in 0..p {
        let (l, r) = split(&rows, f0);
        // depth-1 tree with f0
        best = best.min(leaf_err(&l) + leaf_err(&r));
        // depth-2: best feature in each child independently
        let best_child = |child: &[usize]| -> usize {
            let mut b = leaf_err(child);
            for f1 in 0..p {
                let (cl, cr) = split(child, f1);
                b = b.min(leaf_err(&cl) + leaf_err(&cr));
            }
            b
        };
        best = best.min(best_child(&l) + best_child(&r));
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Binary XOR dataset: y = x0 ⊕ x1, plus a noise column.
    fn xor_bin(n_copies: usize) -> (Matrix, Vec<f64>) {
        let mut rows = Vec::new();
        let mut y = Vec::new();
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..n_copies {
            for &(a, b) in &[(0.0, 0.0), (0.0, 1.0), (1.0, 0.0), (1.0, 1.0)] {
                rows.push(vec![a, b, if rng.bernoulli(0.5) { 1.0 } else { 0.0 }]);
                y.push(if (a as u8) ^ (b as u8) == 1 { 1.0 } else { 0.0 });
            }
        }
        (Matrix::from_rows(&rows), y)
    }

    #[test]
    fn solves_xor_exactly_at_depth_two() {
        let (x, y) = xor_bin(10);
        let res =
            exact_tree_solve(&x, &y, &ExactTreeConfig::default(), &Budget::unlimited());
        assert_eq!(res.errors, 0);
        assert_eq!(res.status, SolveStatus::Optimal);
        let acc = crate::metrics::accuracy(&y, &res.predict_proba(&x));
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn depth_one_xor_has_positive_error() {
        let (x, y) = xor_bin(10);
        // Restrict to the two XOR columns (the third is random noise that
        // can by chance do better than chance).
        let cfg = ExactTreeConfig {
            depth: 1,
            min_leaf: 1,
            feature_subset: Some(vec![0, 1]),
        };
        let res = exact_tree_solve(&x, &y, &cfg, &Budget::unlimited());
        assert_eq!(res.errors, 20); // best depth-1 split leaves half wrong
        assert_eq!(res.status, SolveStatus::Optimal);
    }

    #[test]
    fn matches_brute_force_on_random_data() {
        let mut rng = Rng::seed_from_u64(6);
        for trial in 0..5 {
            let n = 40;
            let p = 6;
            let mut x = Matrix::zeros(n, p);
            for i in 0..n {
                for j in 0..p {
                    x.set(i, j, if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
                }
            }
            let y: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
            let res =
                exact_tree_solve(&x, &y, &ExactTreeConfig::default(), &Budget::unlimited());
            let bf = brute_force_depth2_errors(&x, &y);
            assert_eq!(res.errors, bf, "trial {trial}");
        }
    }

    #[test]
    fn timeout_returns_incumbent_with_status() {
        let mut rng = Rng::seed_from_u64(7);
        let n = 200;
        let p = 40;
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
            }
        }
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let cfg = ExactTreeConfig { depth: 3, ..Default::default() };
        let res = exact_tree_solve(&x, &y, &cfg, &Budget::seconds(0.01));
        assert_eq!(res.status, SolveStatus::TimedOut);
        // Incumbent is still a valid tree with consistent error count.
        let pred = res.predict(&x);
        let err = pred.iter().zip(&y).filter(|(p, y)| p != y).count();
        assert_eq!(err, res.errors);
    }

    #[test]
    fn feature_subset_respected() {
        let (x, y) = xor_bin(5);
        let cfg = ExactTreeConfig {
            depth: 2,
            min_leaf: 1,
            feature_subset: Some(vec![0, 2]), // excludes x1 → XOR unsolvable
        };
        let res = exact_tree_solve(&x, &y, &cfg, &Budget::unlimited());
        for f in res.features_used() {
            assert!(f == 0 || f == 2);
        }
        assert!(res.errors > 0);
    }

    #[test]
    fn errors_match_prediction_errors() {
        let (x, y) = xor_bin(7);
        let res =
            exact_tree_solve(&x, &y, &ExactTreeConfig::default(), &Budget::unlimited());
        let pred = res.predict(&x);
        let err = pred.iter().zip(&y).filter(|(p, y)| p != y).count();
        assert_eq!(err, res.errors);
    }

    #[test]
    fn min_leaf_blocks_tiny_splits() {
        let (x, y) = xor_bin(2); // 8 samples
        let cfg = ExactTreeConfig { depth: 2, min_leaf: 5, feature_subset: None };
        let res = exact_tree_solve(&x, &y, &cfg, &Budget::unlimited());
        // With min_leaf 5 of 8 samples, no split is feasible → root leaf.
        assert!(matches!(res.root, BinNode::Leaf { .. }));
    }
}
