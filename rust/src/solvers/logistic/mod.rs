//! Sparse logistic regression solvers — the second supervised method the
//! paper ships ("including sparse linear **and logistic** regression").
//!
//! - [`logistic_fit`] — dense logistic regression on a feature subset via
//!   damped Newton (IRLS) with a gradient-descent fallback;
//! - [`logistic_l0_fit`] — L0-constrained heuristic: logistic IHT
//!   (projected gradient on the k-sparse ball) + Newton polish on the
//!   selected support (the `fit_subproblem` of the logistic backbone);
//! - [`logistic_best_subset`] — exact best-subset solve by enumeration
//!   over C(|B|, k) supports under a wall-clock budget (the reduced-
//!   problem solver; |B| is small — that is the whole point of the
//!   backbone).

use crate::linalg::{dot, solve_spd, Matrix};
use crate::solvers::SolveStatus;
use crate::util::Budget;

#[inline]
fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// A (possibly sparse) fitted logistic model in the full feature space.
#[derive(Debug, Clone)]
pub struct LogisticModel {
    /// Dense coefficients (nonzero only on `support`).
    pub beta: Vec<f64>,
    pub intercept: f64,
    /// Sorted support indices.
    pub support: Vec<usize>,
    /// Training negative log-likelihood (natural log).
    pub nll: f64,
    pub status: SolveStatus,
}

impl LogisticModel {
    /// P(y = 1 | x) per row.
    pub fn predict_proba(&self, x: &Matrix) -> Vec<f64> {
        (0..x.rows())
            .map(|i| sigmoid(dot(x.row(i), &self.beta) + self.intercept))
            .collect()
    }

    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        self.predict_proba(x)
            .into_iter()
            .map(|p| if p >= 0.5 { 1.0 } else { 0.0 })
            .collect()
    }
}

/// Negative log-likelihood of labels `y ∈ {0,1}` under scores `z`.
fn nll_from_scores(y: &[f64], z: &[f64]) -> f64 {
    y.iter()
        .zip(z)
        .map(|(&yi, &zi)| {
            // Numerically stable: log(1 + e^z) − y·z.
            let log1pe = if zi > 30.0 { zi } else { (1.0 + zi.exp()).ln() };
            log1pe - yi * zi
        })
        .sum()
}

/// Dense logistic fit on the columns `subset` of `x` via damped Newton
/// (IRLS). Returns (beta_on_subset, intercept, nll). `ridge` stabilizes
/// the Hessian (and bounds coefficients on separable data). One-shot
/// scratch; see [`logistic_fit_with`] for the allocation-reusing entry
/// point.
pub fn logistic_fit(
    x: &Matrix,
    y: &[f64],
    subset: &[usize],
    ridge: f64,
    max_newton: usize,
) -> (Vec<f64>, f64, f64) {
    logistic_fit_with(x, y, subset, ridge, max_newton, &mut LogisticWorkspace::default())
}

/// [`logistic_fit`] borrowing caller-owned scratch. All IRLS state — the
/// subset design matrix, score/candidate buffers of the line search, the
/// (p+1)² Hessian and gradient — lives in the workspace, so the Newton
/// loop and repeated calls (best-subset enumeration, the IHT polish)
/// allocate only the returned coefficient vector. Bit-identical to
/// [`logistic_fit`] for any workspace state.
pub fn logistic_fit_with(
    x: &Matrix,
    y: &[f64],
    subset: &[usize],
    ridge: f64,
    max_newton: usize,
    ws: &mut LogisticWorkspace,
) -> (Vec<f64>, f64, f64) {
    x.select_columns_into(subset, &mut ws.xsub);
    let (n, p) = (ws.xsub.rows(), ws.xsub.cols());
    let pp = p + 1;
    let mut beta = vec![0.0; p];
    let mut b0 = {
        // Log-odds of the base rate as a warm intercept.
        let pos = y.iter().sum::<f64>() / n as f64;
        let pc = pos.clamp(1e-6, 1.0 - 1e-6);
        (pc / (1.0 - pc)).ln()
    };
    ws.z.clear();
    for i in 0..n {
        let zi = dot(ws.xsub.row(i), &beta) + b0;
        ws.z.push(zi);
    }
    let mut nll = nll_from_scores(y, &ws.z) + 0.5 * ridge * dot(&beta, &beta);

    // Newton-step count accumulates locally; posted to the metrics
    // registry once per solve on every exit path below.
    let mut irls_steps = 0u64;
    for _ in 0..max_newton {
        irls_steps += 1;
        // Gradient and Hessian of the (p+1)-dim problem (intercept last),
        // accumulated into reusable workspace buffers; the intercept
        // cross-terms are fused into the per-row triangle update.
        ws.gradbuf.clear();
        ws.gradbuf.resize(pp, 0.0);
        if ws.hess.rows() != pp || ws.hess.cols() != pp {
            ws.hess = Matrix::zeros(pp, pp);
        } else {
            ws.hess.data_mut().iter_mut().for_each(|v| *v = 0.0);
        }
        let hd = ws.hess.data_mut();
        for i in 0..n {
            let mu = sigmoid(ws.z[i]);
            let e = mu - y[i];
            let w = (mu * (1.0 - mu)).max(1e-9);
            let row = ws.xsub.row(i);
            // Gradient accumulate and each rank-1 triangle row are
            // elementwise axpy updates — backend-dispatched, bit-identical
            // across backends.
            crate::linalg::axpy(e, row, &mut ws.gradbuf[..p]);
            for a in 0..p {
                let wra = w * row[a];
                crate::linalg::axpy(wra, &row[a..], &mut hd[a * pp + a..a * pp + p]);
                hd[a * pp + p] += wra; // intercept cross-term
            }
            ws.gradbuf[p] += e;
            hd[p * pp + p] += w;
        }
        for a in 0..p {
            ws.gradbuf[a] += ridge * beta[a];
            hd[a * pp + a] += ridge;
        }
        // Mirror the upper triangle.
        for a in 0..pp {
            for b in 0..a {
                hd[a * pp + b] = hd[b * pp + a];
            }
        }
        let Ok(step) = solve_spd(&ws.hess, &ws.gradbuf) else { break };
        // Damped line search on the NLL (candidate buffers reused).
        let mut t = 1.0;
        let mut improved = false;
        for _ in 0..12 {
            ws.cand_beta.clear();
            ws.cand_beta.extend(beta.iter().zip(&step[..p]).map(|(b, s)| b - t * s));
            let cand_b0 = b0 - t * step[p];
            ws.cand_z.clear();
            for i in 0..n {
                let zi = dot(ws.xsub.row(i), &ws.cand_beta) + cand_b0;
                ws.cand_z.push(zi);
            }
            let cand_nll =
                nll_from_scores(y, &ws.cand_z) + 0.5 * ridge * dot(&ws.cand_beta, &ws.cand_beta);
            if cand_nll < nll - 1e-12 {
                beta.clear();
                beta.extend_from_slice(&ws.cand_beta);
                b0 = cand_b0;
                std::mem::swap(&mut ws.z, &mut ws.cand_z);
                let delta = nll - cand_nll;
                nll = cand_nll;
                improved = true;
                if delta < 1e-10 * (1.0 + nll.abs()) {
                    crate::obs::add_solver_iterations("irls", irls_steps);
                    return (beta, b0, nll);
                }
                break;
            }
            t *= 0.5;
        }
        if !improved {
            break; // converged (or stuck) — Newton step no longer helps
        }
    }
    crate::obs::add_solver_iterations("irls", irls_steps);
    (beta, b0, nll)
}

/// Reusable scratch for [`logistic_l0_fit_with`] and
/// [`logistic_fit_with`]: the IHT iterate, its gradient, the projection
/// index buffer, the IRLS score/Hessian/line-search buffers, and a
/// reusable design-matrix buffer for callers that restrict columns per
/// fit. Buffers are resized on entry, so one `Default` workspace serves
/// any problem shape; contents never affect results.
#[derive(Debug, Clone, Default)]
pub struct LogisticWorkspace {
    /// Caller-owned column-restricted design matrix (`select_columns_into`).
    pub xs: Matrix,
    beta: Vec<f64>,
    grad: Vec<f64>,
    idx: Vec<usize>,
    /// IRLS subset design (distinct from `xs`, which callers may have
    /// lent out while this workspace is in use).
    xsub: Matrix,
    z: Vec<f64>,
    cand_z: Vec<f64>,
    cand_beta: Vec<f64>,
    gradbuf: Vec<f64>,
    hess: Matrix,
}

/// L0-constrained logistic heuristic: IHT + Newton polish (one-shot
/// scratch; see [`logistic_l0_fit_with`]).
pub fn logistic_l0_fit(
    x: &Matrix,
    y: &[f64],
    k: usize,
    ridge: f64,
    iht_iters: usize,
) -> LogisticModel {
    logistic_l0_fit_with(x, y, k, ridge, iht_iters, &mut LogisticWorkspace::default())
}

/// L0-constrained logistic heuristic borrowing caller-owned scratch — the
/// backbone's `fit_subproblem` entry point for sparse logistic regression.
/// Bit-identical to [`logistic_l0_fit`] for any workspace state.
pub fn logistic_l0_fit_with(
    x: &Matrix,
    y: &[f64],
    k: usize,
    ridge: f64,
    iht_iters: usize,
    ws: &mut LogisticWorkspace,
) -> LogisticModel {
    assert_eq!(x.rows(), y.len());
    let (n, p) = (x.rows(), x.cols());
    let k = k.min(p);
    if k == 0 || p == 0 {
        let (_, b0, nll) = logistic_fit_with(x, y, &[], ridge, 25, ws);
        return LogisticModel {
            beta: vec![0.0; p],
            intercept: b0,
            support: vec![],
            nll,
            status: SolveStatus::Optimal,
        };
    }
    // IHT with a conservative step (logistic Lipschitz ≤ ‖X‖²/4).
    ws.beta.clear();
    ws.beta.resize(p, 0.0);
    let beta = &mut ws.beta;
    let mut b0 = 0.0;
    let lr = 4.0 / n as f64;
    for _ in 0..iht_iters {
        ws.grad.clear();
        ws.grad.resize(p, 0.0);
        let mut grad0 = 0.0;
        for i in 0..n {
            let e = sigmoid(dot(x.row(i), &beta[..]) + b0) - y[i];
            grad0 += e;
            crate::linalg::axpy(e, x.row(i), &mut ws.grad);
        }
        for (bj, gj) in beta.iter_mut().zip(&ws.grad) {
            *bj -= lr * (gj + ridge * *bj);
        }
        b0 -= lr * grad0;
        // Project to k-sparse: O(p) expected-time selection under a total
        // order (magnitude desc, then index asc — the order the previous
        // stable sort induced), so the zeroed set is identical.
        if k < p {
            ws.idx.clear();
            ws.idx.extend(0..p);
            ws.idx.select_nth_unstable_by(k, |a, b| {
                beta[*b].abs().partial_cmp(&beta[*a].abs()).unwrap().then(a.cmp(b))
            });
            for &j in &ws.idx[k..] {
                beta[j] = 0.0;
            }
        }
    }
    let mut support: Vec<usize> =
        (0..p).filter(|&j| beta[j] != 0.0).collect();
    support.sort_unstable();
    // Newton polish on the support (reusing this workspace's IRLS buffers).
    let (beta_s, intercept, nll) = logistic_fit_with(x, y, &support, ridge, 25, ws);
    let mut dense = vec![0.0; p];
    for (jj, &j) in support.iter().enumerate() {
        dense[j] = beta_s[jj];
    }
    LogisticModel { beta: dense, intercept, support, nll, status: SolveStatus::Optimal }
}

/// Exact best-subset logistic regression over `pool` (≤ k features) by
/// enumeration, each candidate Newton-fit; honours `budget` and reports
/// `TimedOut` with the incumbent if enumeration is cut short.
pub fn logistic_best_subset(
    x: &Matrix,
    y: &[f64],
    pool: &[usize],
    k: usize,
    ridge: f64,
    budget: &Budget,
) -> LogisticModel {
    let p = x.cols();
    let k = k.min(pool.len());
    let mut best: Option<(f64, Vec<usize>, Vec<f64>, f64)> = None;
    let mut timed_out = false;
    // One workspace across the whole enumeration: every candidate fit
    // reuses the same design/Hessian/line-search buffers.
    let mut ws = LogisticWorkspace::default();

    // Iterative lexicographic subset enumeration (no recursion).
    let mut idx: Vec<usize> = (0..k).collect();
    if k > 0 {
        loop {
            if budget.expired() {
                timed_out = true;
                break;
            }
            let subset: Vec<usize> = idx.iter().map(|&i| pool[i]).collect();
            let (beta_s, b0, nll) = logistic_fit_with(x, y, &subset, ridge, 25, &mut ws);
            if best.as_ref().map_or(true, |(n, ..)| nll < *n) {
                best = Some((nll, subset, beta_s, b0));
            }
            // Advance the combination.
            let mut pos = k;
            loop {
                if pos == 0 {
                    break;
                }
                pos -= 1;
                if idx[pos] != pos + pool.len() - k {
                    idx[pos] += 1;
                    for q in pos + 1..k {
                        idx[q] = idx[q - 1] + 1;
                    }
                    break;
                }
                if pos == 0 {
                    idx.clear();
                    break;
                }
            }
            if idx.is_empty() || idx.len() < k {
                break;
            }
            if idx[0] > pool.len() - k {
                break;
            }
        }
    }
    let (nll, support, beta_s, intercept) = match best {
        Some(b) => b,
        None => {
            let (_, b0, nll) = logistic_fit_with(x, y, &[], ridge, 25, &mut ws);
            (nll, vec![], vec![], b0)
        }
    };
    let mut beta = vec![0.0; p];
    for (jj, &j) in support.iter().enumerate() {
        beta[j] = beta_s[jj];
    }
    LogisticModel {
        beta,
        intercept,
        support,
        nll,
        status: if timed_out { SolveStatus::TimedOut } else { SolveStatus::Optimal },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Planted sparse logistic data: y ~ Bernoulli(σ(Xβ)).
    fn planted(n: usize, p: usize, support: &[usize], scale: f64, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Rng::seed_from_u64(seed);
        let mut x = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                x.set(i, j, rng.normal());
            }
        }
        let mut beta = vec![0.0; p];
        for (t, &j) in support.iter().enumerate() {
            beta[j] = if t % 2 == 0 { scale } else { -scale };
        }
        let y: Vec<f64> = (0..n)
            .map(|i| if rng.bernoulli(sigmoid(dot(x.row(i), &beta))) { 1.0 } else { 0.0 })
            .collect();
        (x, y)
    }

    #[test]
    fn newton_fit_separates_planted_data() {
        let (x, y) = planted(300, 4, &[0, 2], 3.0, 1);
        let (beta, _b0, _nll) = logistic_fit(&x, &y, &[0, 1, 2, 3], 1e-3, 30);
        assert!(beta[0] > 1.0, "beta={beta:?}");
        assert!(beta[2] < -1.0);
        assert!(beta[1].abs() < 0.5 && beta[3].abs() < 0.5);
    }

    #[test]
    fn l0_fit_recovers_support() {
        let (x, y) = planted(400, 30, &[3, 11, 20], 3.0, 2);
        let m = logistic_l0_fit(&x, &y, 3, 1e-3, 150);
        assert_eq!(m.support, vec![3, 11, 20]);
        let auc = crate::metrics::auc(&y, &m.predict_proba(&x));
        assert!(auc > 0.85, "auc={auc}");
    }

    #[test]
    fn l0_fit_respects_sparsity() {
        let (x, y) = planted(100, 20, &[1, 5], 2.0, 3);
        for k in [1, 2, 4] {
            let m = logistic_l0_fit(&x, &y, k, 1e-3, 80);
            assert!(m.support.len() <= k);
        }
    }

    #[test]
    fn best_subset_at_least_as_good_as_heuristic() {
        let (x, y) = planted(150, 12, &[2, 7], 2.5, 4);
        let heur = logistic_l0_fit(&x, &y, 2, 1e-3, 120);
        let exact = logistic_best_subset(
            &x,
            &y,
            &(0..12).collect::<Vec<_>>(),
            2,
            1e-3,
            &Budget::seconds(60.0),
        );
        assert_eq!(exact.status, SolveStatus::Optimal);
        assert!(
            exact.nll <= heur.nll + 1e-6,
            "exact {} worse than heuristic {}",
            exact.nll,
            heur.nll
        );
        assert_eq!(exact.support, vec![2, 7]);
    }

    #[test]
    fn best_subset_timeout_returns_incumbent() {
        let (x, y) = planted(80, 16, &[0, 8], 2.0, 5);
        let m = logistic_best_subset(
            &x,
            &y,
            &(0..16).collect::<Vec<_>>(),
            3,
            1e-3,
            &Budget::seconds(0.0),
        );
        assert_eq!(m.status, SolveStatus::TimedOut);
        assert!(m.nll.is_finite());
    }

    #[test]
    fn intercept_absorbs_class_imbalance() {
        // 90/10 imbalance, no informative features → β ≈ 0, b0 ≈ logit(0.9).
        let mut rng = Rng::seed_from_u64(6);
        let n = 500;
        let mut x = Matrix::zeros(n, 3);
        for i in 0..n {
            for j in 0..3 {
                x.set(i, j, rng.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|_| if rng.bernoulli(0.9) { 1.0 } else { 0.0 }).collect();
        let (beta, b0, _) = logistic_fit(&x, &y, &[0, 1, 2], 1e-2, 30);
        assert!(beta.iter().all(|b| b.abs() < 0.3), "beta={beta:?}");
        let base = y.iter().sum::<f64>() / n as f64;
        let expect = (base / (1.0 - base)).ln();
        assert!((b0 - expect).abs() < 0.4, "b0={b0} vs {expect}");
    }
}
