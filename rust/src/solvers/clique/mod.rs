//! Exact clustering via clique partitioning (Grötschel & Wakabayashi,
//! 1989) — the "Exact" method of Table 1's clustering block and the
//! backbone's reduced-problem solver.
//!
//! Pair formulation: binary `x_{ij}` (i < j) indicates that points i and j
//! share a cluster; the objective minimizes `Σ d_{ij} x_{ij}` with
//! `d_{ij} = ‖x_i − x_j‖²` (the paper's `f(ζ; X)` after summing the
//! per-cluster ζ's into a single co-clustering indicator). Constraints:
//!
//! - **transitivity** triangles `x_{ij} + x_{jk} − x_{ik} ≤ 1` (all three
//!   rotations) — generated lazily, the GW cutting-plane scheme;
//! - **min cluster size** `b`: degree rows `Σ_j x_{ij} ≥ b − 1`;
//! - **at most k clusters**: pigeonhole cuts `Σ_{(i,j)⊆S} x_{ij} ≥ 1` for
//!   any (k+1)-subset `S` of pairwise-separated points — also lazy;
//! - **backbone restriction**: pairs outside the allowed set are fixed to
//!   0 (the paper's `z_{it} + z_{jt} ≤ 1 ∀(i,j) ∉ B` after aggregation).
//!
//! Upper bounds `x ≤ 1` are *dropped* from the LP (a valid relaxation)
//! and enforced lazily, which keeps the dense tableau narrow enough that
//! honest work happens before the budget expires even at Table 1's
//! (n = 200) scale — where, like the paper's Exact row, the solver times
//! out and returns its incumbent.

use crate::linalg::{sqdist, Matrix};
use crate::solvers::kmeans::{kmeans_fit, KMeansConfig};
use crate::solvers::lp::{Constraint, LinearProgram, Sense};
use crate::solvers::mip::{mip_solve, Callbacks, Mip, MipConfig};
use crate::solvers::SolveStatus;
use crate::util::Budget;
use anyhow::Result;

/// Exact-clustering configuration.
#[derive(Debug, Clone)]
pub struct CliqueConfig {
    /// Maximum number of clusters (the paper's target k).
    pub k: usize,
    /// Minimum cluster size b.
    pub min_cluster_size: usize,
    /// Restrict co-clustering to these pairs (the backbone set B); `None`
    /// allows all pairs.
    pub allowed_pairs: Option<Vec<(usize, usize)>>,
    /// Max lazy cuts added per separation round.
    pub max_cuts_per_round: usize,
}

impl Default for CliqueConfig {
    fn default() -> Self {
        Self { k: 5, min_cluster_size: 1, allowed_pairs: None, max_cuts_per_round: 200 }
    }
}

/// Result of an exact clustering solve.
#[derive(Debug, Clone)]
pub struct CliqueResult {
    /// Cluster label per point.
    pub labels: Vec<usize>,
    /// Within-cluster pair cost Σ d_ij over co-clustered pairs.
    pub objective: f64,
    pub lower_bound: f64,
    pub gap: f64,
    pub status: SolveStatus,
    pub nodes_explored: usize,
    pub cuts_added: usize,
    pub elapsed_secs: f64,
}

/// Pair index helper: linear index of pair (i, j), i < j, among C(n, 2).
#[inline]
pub fn pair_index(n: usize, i: usize, j: usize) -> usize {
    debug_assert!(i < j && j < n);
    i * n - i * (i + 1) / 2 + (j - i - 1)
}

/// Pairwise squared-distance objective weights.
fn pair_costs(x: &Matrix) -> Vec<f64> {
    let n = x.rows();
    let mut d = vec![0.0; n * (n - 1) / 2];
    for i in 0..n {
        for j in (i + 1)..n {
            d[pair_index(n, i, j)] = sqdist(x.row(i), x.row(j));
        }
    }
    d
}

/// Labels → pair vector (1.0 where co-clustered).
pub fn labels_to_pairs(n: usize, labels: &[usize]) -> Vec<f64> {
    let mut x = vec![0.0; n * (n - 1) / 2];
    for i in 0..n {
        for j in (i + 1)..n {
            if labels[i] == labels[j] {
                x[pair_index(n, i, j)] = 1.0;
            }
        }
    }
    x
}

/// Pair vector (integral, transitive) → labels via connected components.
pub fn pairs_to_labels(n: usize, x: &[f64]) -> Vec<usize> {
    let mut labels = vec![usize::MAX; n];
    let mut next = 0;
    for i in 0..n {
        if labels[i] != usize::MAX {
            continue;
        }
        // BFS over co-clustering edges.
        let mut queue = vec![i];
        labels[i] = next;
        while let Some(u) = queue.pop() {
            for v in 0..n {
                if v == u || labels[v] != usize::MAX {
                    continue;
                }
                let (a, b) = if u < v { (u, v) } else { (v, u) };
                if x[pair_index(n, a, b)] > 0.5 {
                    labels[v] = next;
                    queue.push(v);
                }
            }
        }
        next += 1;
    }
    labels
}

/// Objective of a labeling under the pair costs.
pub fn labels_objective(x: &Matrix, labels: &[usize]) -> f64 {
    let n = x.rows();
    let mut obj = 0.0;
    for i in 0..n {
        for j in (i + 1)..n {
            if labels[i] == labels[j] {
                obj += sqdist(x.row(i), x.row(j));
            }
        }
    }
    obj
}

/// Make a labeling feasible for (k, b): at most k clusters, each of size
/// ≥ b. Merges undersized clusters into their nearest (centroid) neighbour
/// and splits nothing (k-means with k clusters already respects ≤ k).
fn repair_labels(x: &Matrix, labels: &[usize], k: usize, b: usize) -> Vec<usize> {
    let n = x.rows();
    let mut labels = labels.to_vec();
    loop {
        // Compact label space.
        let mut map = std::collections::BTreeMap::new();
        for &l in &labels {
            let next = map.len();
            map.entry(l).or_insert(next);
        }
        for l in labels.iter_mut() {
            *l = map[l];
        }
        let kk = map.len();
        let mut sizes = vec![0usize; kk];
        for &l in &labels {
            sizes[l] += 1;
        }
        // Centroids.
        let p = x.cols();
        let mut cent = Matrix::zeros(kk, p);
        for i in 0..n {
            let row = x.row(i);
            let c = cent.row_mut(labels[i]);
            for (cv, &v) in c.iter_mut().zip(row) {
                *cv += v;
            }
        }
        for c in 0..kk {
            let inv = 1.0 / sizes[c].max(1) as f64;
            for v in cent.row_mut(c) {
                *v *= inv;
            }
        }
        // Find a violating cluster: undersized, or too many clusters.
        let offender = if kk > k {
            // Merge the smallest cluster.
            (0..kk).min_by_key(|&c| sizes[c])
        } else {
            (0..kk).find(|&c| sizes[c] < b)
        };
        let Some(off) = offender else {
            return labels;
        };
        if kk == 1 {
            return labels; // nothing to merge into
        }
        // Merge offender into nearest other centroid.
        let target = (0..kk)
            .filter(|&c| c != off)
            .min_by(|&a, &bb| {
                sqdist(cent.row(a), cent.row(off))
                    .partial_cmp(&sqdist(cent.row(bb), cent.row(off)))
                    .unwrap()
            })
            .unwrap();
        for l in labels.iter_mut() {
            if *l == off {
                *l = target;
            }
        }
    }
}

/// Solve the exact clique-partitioning clustering problem.
pub fn clique_solve(
    x: &Matrix,
    cfg: &CliqueConfig,
    budget: &Budget,
) -> Result<CliqueResult> {
    let n = x.rows();
    assert!(n >= 2, "need at least two points");
    assert!(cfg.k >= 1);
    let n_pairs = n * (n - 1) / 2;
    let costs = pair_costs(x);

    // --- Base LP ----------------------------------------------------------
    let mut lp = LinearProgram::new(n_pairs);
    lp.objective = costs.clone();
    // Bounds: [0, ∞) — x ≤ 1 enforced lazily; forbidden pairs fixed to 0.
    lp.bounds = vec![(0.0, f64::INFINITY); n_pairs];
    if let Some(allowed) = &cfg.allowed_pairs {
        let mut ok = vec![false; n_pairs];
        for &(i, j) in allowed {
            let (a, b) = if i < j { (i, j) } else { (j, i) };
            ok[pair_index(n, a, b)] = true;
        }
        for (idx, &is_ok) in ok.iter().enumerate() {
            if !is_ok {
                lp.bounds[idx] = (0.0, 0.0);
            }
        }
    }
    // Min-size degree rows: Σ_j x_ij ≥ b − 1.
    if cfg.min_cluster_size > 1 {
        for i in 0..n {
            let coeffs: Vec<(usize, f64)> = (0..n)
                .filter(|&j| j != i)
                .map(|j| {
                    let (a, b) = if i < j { (i, j) } else { (j, i) };
                    (pair_index(n, a, b), 1.0)
                })
                .collect();
            lp.add_constraint(coeffs, Sense::Ge, (cfg.min_cluster_size - 1) as f64);
        }
    }
    // Pigeonhole base row guaranteeing ≤ k clusters in aggregate: with n
    // points in ≤ k clusters, the number of co-clustered pairs is at least
    // k·C(n/k, 2) in the balanced case — but that is not a valid
    // inequality in general; the valid ≥-row is Σ x_ij ≥ n − k (spanning
    // forest argument: a partition into ≤ k parts has ≥ n − k co-clustered
    // pairs because each part of size s contributes C(s,2) ≥ s − 1).
    lp.add_constraint(
        (0..n_pairs).map(|idx| (idx, 1.0)).collect(),
        Sense::Ge,
        (n as isize - cfg.k as isize).max(0) as f64,
    );

    let mip = Mip { lp, binaries: (0..n_pairs).collect() };

    // --- Lazy separation ---------------------------------------------------
    let max_cuts = cfg.max_cuts_per_round;
    let k = cfg.k;
    let cut_fn = move |xv: &[f64]| -> Vec<Constraint> {
        let mut cuts = Vec::new();
        // 1. Upper bounds x ≤ 1.
        for (idx, &v) in xv.iter().enumerate() {
            if v > 1.0 + 1e-6 {
                cuts.push(Constraint { coeffs: vec![(idx, 1.0)], sense: Sense::Le, rhs: 1.0 });
                if cuts.len() >= max_cuts {
                    return cuts;
                }
            }
        }
        // 2. Triangle (transitivity) violations, most-violated first.
        let mut tri: Vec<(f64, usize, usize, usize)> = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let xij = xv[pair_index(n, i, j)];
                for l in (j + 1)..n {
                    let xjl = xv[pair_index(n, j, l)];
                    let xil = xv[pair_index(n, i, l)];
                    // Three rotations.
                    let v1 = xij + xjl - xil; // (i,j) & (j,l) ⇒ (i,l)
                    let v2 = xij + xil - xjl;
                    let v3 = xjl + xil - xij;
                    if v1 > 1.0 + 1e-6 {
                        tri.push((v1, pair_index(n, i, j), pair_index(n, j, l), pair_index(n, i, l)));
                    }
                    if v2 > 1.0 + 1e-6 {
                        tri.push((v2, pair_index(n, i, j), pair_index(n, i, l), pair_index(n, j, l)));
                    }
                    if v3 > 1.0 + 1e-6 {
                        tri.push((v3, pair_index(n, j, l), pair_index(n, i, l), pair_index(n, i, j)));
                    }
                }
            }
        }
        tri.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (_, p1, p2, p3) in tri.into_iter().take(max_cuts.saturating_sub(cuts.len())) {
            cuts.push(Constraint {
                coeffs: vec![(p1, 1.0), (p2, 1.0), (p3, -1.0)],
                sense: Sense::Le,
                rhs: 1.0,
            });
        }
        if !cuts.is_empty() {
            return cuts;
        }
        // 3. Pigeonhole: greedily build an anti-clique (pairwise x < ε) of
        // size k+1; its pair sum must be ≥ 1.
        let mut anti: Vec<usize> = Vec::new();
        for cand in 0..n {
            if anti.iter().all(|&a| {
                let (lo, hi) = if a < cand { (a, cand) } else { (cand, a) };
                xv[pair_index(n, lo, hi)] < 1e-6
            }) {
                anti.push(cand);
                if anti.len() == k + 1 {
                    break;
                }
            }
        }
        if anti.len() == k + 1 {
            let mut coeffs = Vec::new();
            for a in 0..anti.len() {
                for b in (a + 1)..anti.len() {
                    let (lo, hi) =
                        if anti[a] < anti[b] { (anti[a], anti[b]) } else { (anti[b], anti[a]) };
                    coeffs.push((pair_index(n, lo, hi), 1.0));
                }
            }
            cuts.push(Constraint { coeffs, sense: Sense::Ge, rhs: 1.0 });
        }
        cuts
    };

    // --- Rounding heuristic -------------------------------------------------
    let xm = x.clone();
    let kk = cfg.k;
    let bb = cfg.min_cluster_size;
    let heur_fn = move |xv: &[f64]| -> Option<Vec<f64>> {
        // Threshold graph at 0.5 → components → repair to (k, b).
        let labels = pairs_to_labels(n, xv);
        let repaired = repair_labels(&xm, &labels, kk, bb);
        Some(labels_to_pairs(n, &repaired))
    };

    let callbacks = Callbacks { cuts: Some(&cut_fn), heuristic: Some(&heur_fn) };
    let mip_cfg = MipConfig { gap_tol: 1e-6, max_nodes: 0, max_cut_rounds: 50, int_tol: 1e-6 };

    // Seed incumbent via k-means (repaired): guarantees a solution at
    // timeout even if no node completes. Only usable when it respects the
    // backbone's allowed-pair restriction — k-means knows nothing about B.
    let mut rng = crate::rng::Rng::seed_from_u64(0x5EED);
    let km = kmeans_fit(x, &KMeansConfig { k: cfg.k, n_init: 5, ..Default::default() }, &mut rng);
    let seed_labels = repair_labels(x, &km.labels, cfg.k, cfg.min_cluster_size);
    let seed_feasible = match &cfg.allowed_pairs {
        None => true,
        Some(allowed) => {
            let ok: std::collections::BTreeSet<(usize, usize)> = allowed
                .iter()
                .map(|&(i, j)| if i < j { (i, j) } else { (j, i) })
                .collect();
            (0..n).all(|i| {
                ((i + 1)..n).all(|j| seed_labels[i] != seed_labels[j] || ok.contains(&(i, j)))
            })
        }
    };
    let seed_obj = if seed_feasible {
        labels_objective(x, &seed_labels)
    } else {
        f64::INFINITY
    };

    let res = mip_solve(&mip, &mip_cfg, budget, &callbacks)?;

    let (labels, objective, status) = if res.status.has_solution() && !res.x.is_empty() {
        let labels = pairs_to_labels(n, &res.x);
        let obj = res.objective;
        if seed_obj < obj - 1e-9 {
            (seed_labels, seed_obj, res.status)
        } else {
            (labels, obj, res.status)
        }
    } else if res.status == SolveStatus::Infeasible {
        return Ok(CliqueResult {
            labels: vec![],
            objective: f64::INFINITY,
            lower_bound: f64::INFINITY,
            gap: 0.0,
            status: SolveStatus::Infeasible,
            nodes_explored: res.nodes_explored,
            cuts_added: res.cuts_added,
            elapsed_secs: res.elapsed_secs,
        });
    } else if seed_feasible {
        (seed_labels, seed_obj, SolveStatus::TimedOut)
    } else {
        // No incumbent and the k-means seed violates the allowed-pair
        // restriction: fall back to singletons (trivially respects B;
        // cluster-count feasibility is best-effort at timeout).
        let singles: Vec<usize> = (0..n).collect();
        let obj = labels_objective(x, &singles);
        (singles, obj, SolveStatus::TimedOut)
    };

    let lower = res.lower_bound.min(objective);
    let gap = if objective.abs() > 1e-12 {
        ((objective - lower) / objective.abs()).max(0.0)
    } else {
        0.0
    };
    Ok(CliqueResult {
        labels,
        objective,
        lower_bound: lower,
        gap,
        status,
        nodes_explored: res.nodes_explored,
        cuts_added: res.cuts_added,
        elapsed_secs: res.elapsed_secs,
    })
}

/// Brute-force optimal partition for tests: enumerate all partitions of n
/// points into ≤ k clusters with min size b (n ≤ 10).
pub fn brute_force_clustering(x: &Matrix, k: usize, b: usize) -> (Vec<usize>, f64) {
    let n = x.rows();
    assert!(n <= 10, "brute force is Bell-number exponential");
    let mut best: Option<(Vec<usize>, f64)> = None;
    // Enumerate assignments in restricted-growth form (canonical set
    // partitions) to avoid label permutations.
    fn rec(
        i: usize,
        n: usize,
        max_used: usize,
        labels: &mut Vec<usize>,
        k: usize,
        b: usize,
        x: &Matrix,
        best: &mut Option<(Vec<usize>, f64)>,
    ) {
        if i == n {
            let kk = max_used + 1;
            let mut sizes = vec![0usize; kk];
            for &l in labels.iter() {
                sizes[l] += 1;
            }
            if sizes.iter().any(|&s| s < b) {
                return;
            }
            let obj = labels_objective(x, labels);
            if best.as_ref().map_or(true, |(_, o)| obj < *o) {
                *best = Some((labels.clone(), obj));
            }
            return;
        }
        let limit = (max_used + 1).min(k - 1);
        for c in 0..=limit {
            labels.push(c);
            rec(i + 1, n, max_used.max(c), labels, k, b, x, best);
            labels.pop();
        }
    }
    let mut labels = Vec::with_capacity(n);
    labels.push(0);
    rec(1, n, 0, &mut labels, k, b, x, &mut best);
    best.expect("at least the all-one-cluster partition is feasible when b <= n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs::{generate, BlobsConfig};
    use crate::rng::Rng;

    fn tiny_blobs(n: usize, k: usize, seed: u64) -> crate::data::blobs::BlobsData {
        generate(
            &BlobsConfig {
                n,
                p: 2,
                true_clusters: k,
                cluster_std: 0.3,
                center_box: 8.0,
                min_center_dist: 5.0,
            },
            &mut Rng::seed_from_u64(seed),
        )
    }

    #[test]
    fn pair_index_bijection() {
        let n = 7;
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = pair_index(n, i, j);
                assert!(idx < n * (n - 1) / 2);
                assert!(seen.insert(idx), "duplicate index for ({i},{j})");
            }
        }
        assert_eq!(seen.len(), n * (n - 1) / 2);
    }

    #[test]
    fn labels_pairs_roundtrip() {
        let labels = vec![0, 1, 0, 2, 1, 0];
        let x = labels_to_pairs(6, &labels);
        let back = pairs_to_labels(6, &x);
        assert_eq!(crate::metrics::adjusted_rand_index(&labels, &back), 1.0);
    }

    #[test]
    fn matches_brute_force_tiny() {
        for seed in [1, 2, 3] {
            let data = tiny_blobs(7, 2, seed);
            let cfg = CliqueConfig { k: 2, min_cluster_size: 1, ..Default::default() };
            let res = clique_solve(&data.x, &cfg, &Budget::seconds(60.0)).unwrap();
            let (bf_labels, bf_obj) = brute_force_clustering(&data.x, 2, 1);
            assert_eq!(res.status, SolveStatus::Optimal, "seed {seed}");
            assert!(
                (res.objective - bf_obj).abs() < 1e-6,
                "seed {seed}: {} vs {bf_obj}",
                res.objective
            );
            assert_eq!(
                crate::metrics::adjusted_rand_index(&res.labels, &bf_labels),
                1.0,
                "seed {seed}"
            );
        }
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let data = tiny_blobs(12, 3, 5);
        let cfg = CliqueConfig { k: 3, min_cluster_size: 2, ..Default::default() };
        let res = clique_solve(&data.x, &cfg, &Budget::seconds(120.0)).unwrap();
        assert!(res.status.has_solution());
        let ari = crate::metrics::adjusted_rand_index(&res.labels, &data.labels_true);
        assert!(ari > 0.9, "ari={ari}, status={:?}", res.status);
    }

    #[test]
    fn min_cluster_size_respected() {
        let data = tiny_blobs(9, 3, 7);
        let cfg = CliqueConfig { k: 3, min_cluster_size: 3, ..Default::default() };
        let res = clique_solve(&data.x, &cfg, &Budget::seconds(120.0)).unwrap();
        assert!(res.status.has_solution());
        let kk = res.labels.iter().max().unwrap() + 1;
        let mut sizes = vec![0usize; kk];
        for &l in &res.labels {
            sizes[l] += 1;
        }
        for (c, &s) in sizes.iter().enumerate() {
            assert!(s == 0 || s >= 3, "cluster {c} has size {s} < 3");
        }
    }

    #[test]
    fn cluster_count_capped_at_k() {
        let data = tiny_blobs(8, 4, 9);
        let cfg = CliqueConfig { k: 2, min_cluster_size: 1, ..Default::default() };
        let res = clique_solve(&data.x, &cfg, &Budget::seconds(120.0)).unwrap();
        assert!(res.status.has_solution());
        let kk = res
            .labels
            .iter()
            .collect::<std::collections::BTreeSet<_>>()
            .len();
        assert!(kk <= 2, "got {kk} clusters with k=2");
    }

    #[test]
    fn timeout_returns_feasible_incumbent() {
        let data = tiny_blobs(30, 3, 11);
        let cfg = CliqueConfig { k: 3, min_cluster_size: 2, ..Default::default() };
        let res = clique_solve(&data.x, &cfg, &Budget::seconds(0.0)).unwrap();
        assert_eq!(res.status, SolveStatus::TimedOut);
        assert_eq!(res.labels.len(), 30);
        let kk = res.labels.iter().max().unwrap() + 1;
        assert!(kk <= 3);
        assert!(res.objective.is_finite());
    }

    #[test]
    fn forbidden_pairs_never_coclustered() {
        let data = tiny_blobs(6, 2, 13);
        // Allow only pairs within {0,1,2} and within {3,4,5}.
        let mut allowed = Vec::new();
        for group in [[0usize, 1, 2], [3, 4, 5]] {
            for a in 0..3 {
                for b in (a + 1)..3 {
                    allowed.push((group[a], group[b]));
                }
            }
        }
        let cfg = CliqueConfig {
            k: 2,
            min_cluster_size: 1,
            allowed_pairs: Some(allowed.clone()),
            ..Default::default()
        };
        let res = clique_solve(&data.x, &cfg, &Budget::seconds(60.0)).unwrap();
        assert!(res.status.has_solution());
        for i in 0..6 {
            for j in (i + 1)..6 {
                if res.labels[i] == res.labels[j] {
                    assert!(
                        allowed.contains(&(i, j)) || allowed.contains(&(j, i)),
                        "forbidden pair ({i},{j}) co-clustered"
                    );
                }
            }
        }
    }

    #[test]
    fn repair_labels_enforces_constraints() {
        let data = tiny_blobs(12, 4, 17);
        // Start from singletons: 12 clusters, all undersized for b=3.
        let singletons: Vec<usize> = (0..12).collect();
        let repaired = repair_labels(&data.x, &singletons, 3, 3);
        let kk = repaired.iter().collect::<std::collections::BTreeSet<_>>().len();
        assert!(kk <= 3);
        let mut sizes = std::collections::BTreeMap::new();
        for &l in &repaired {
            *sizes.entry(l).or_insert(0usize) += 1;
        }
        for (&l, &s) in &sizes {
            assert!(s >= 3, "cluster {l} size {s}");
        }
    }
}
