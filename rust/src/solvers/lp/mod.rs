//! Dense two-phase primal simplex for linear programs.
//!
//! Plays Cbc's LP role: the relaxation engine under the binary MILP
//! branch-and-bound ([`crate::solvers::mip`]) used by the exact
//! clique-partitioning clustering solver. The problems it sees are small
//! and dense (hundreds of variables/rows), so a tableau implementation
//! with Dantzig pricing (Bland's rule engaged on stall, guaranteeing
//! termination) is appropriate.
//!
//! Model form: `min cᵀx` subject to per-row `aᵀx {≤,=,≥} b` and variable
//! bounds `l ≤ x ≤ u` (finite lower bounds required; `u = +∞` allowed).
//! Lower bounds are shifted out; finite upper bounds become explicit ≤
//! rows (simple, and fine at these sizes).

use crate::solvers::SolveStatus;
use anyhow::{bail, Result};

/// Constraint sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Eq,
    Ge,
}

/// One linear constraint: sparse coefficients, sense, right-hand side.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub coeffs: Vec<(usize, f64)>,
    pub sense: Sense,
    pub rhs: f64,
}

/// A linear program (minimization).
#[derive(Debug, Clone)]
pub struct LinearProgram {
    pub n_vars: usize,
    /// Objective coefficients (length `n_vars`).
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
    /// Per-variable `(lower, upper)`; upper may be `f64::INFINITY`.
    pub bounds: Vec<(f64, f64)>,
}

impl LinearProgram {
    /// New LP with all variables in `[0, ∞)` and zero objective.
    pub fn new(n_vars: usize) -> Self {
        Self {
            n_vars,
            objective: vec![0.0; n_vars],
            constraints: Vec::new(),
            bounds: vec![(0.0, f64::INFINITY); n_vars],
        }
    }

    pub fn add_constraint(&mut self, coeffs: Vec<(usize, f64)>, sense: Sense, rhs: f64) {
        self.constraints.push(Constraint { coeffs, sense, rhs });
    }
}

/// LP solution.
#[derive(Debug, Clone)]
pub struct LpSolution {
    pub status: SolveStatus,
    /// Primal values in the original variable space (empty unless status
    /// is `Optimal`).
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

const EPS: f64 = 1e-9;

/// Solve the LP. Returns `Optimal`, `Infeasible`, or `Unbounded`.
pub fn solve(lp: &LinearProgram) -> Result<LpSolution> {
    if lp.objective.len() != lp.n_vars || lp.bounds.len() != lp.n_vars {
        bail!("LP dimension mismatch");
    }
    for (l, u) in &lp.bounds {
        if !l.is_finite() {
            bail!("lower bounds must be finite");
        }
        if u < l {
            return Ok(LpSolution {
                status: SolveStatus::Infeasible,
                x: vec![],
                objective: f64::INFINITY,
                iterations: 0,
            });
        }
    }

    // --- Shift lower bounds: x = l + x̃, x̃ ≥ 0. -------------------------
    let shift: Vec<f64> = lp.bounds.iter().map(|b| b.0).collect();
    let mut rows: Vec<(Vec<(usize, f64)>, Sense, f64)> = Vec::new();
    for c in &lp.constraints {
        let mut rhs = c.rhs;
        for &(j, a) in &c.coeffs {
            rhs -= a * shift[j];
        }
        rows.push((c.coeffs.clone(), c.sense, rhs));
    }
    // Finite upper bounds → x̃_j ≤ u − l rows.
    for (j, (l, u)) in lp.bounds.iter().enumerate() {
        if u.is_finite() {
            rows.push((vec![(j, 1.0)], Sense::Le, u - l));
        }
    }

    // --- Build standard-form tableau with slacks + artificials. ----------
    let m = rows.len();
    let n = lp.n_vars;
    // Count columns: n structural + one slack/surplus per Le/Ge + one
    // artificial per Eq/Ge (and per Le with negative rhs after flip —
    // handled by flipping rows to rhs ≥ 0 first).
    // Normalize rhs ≥ 0.
    let mut norm_rows: Vec<(Vec<(usize, f64)>, Sense, f64)> = Vec::with_capacity(m);
    for (coeffs, sense, rhs) in rows {
        if rhs < 0.0 {
            let flipped: Vec<(usize, f64)> =
                coeffs.iter().map(|&(j, a)| (j, -a)).collect();
            let s = match sense {
                Sense::Le => Sense::Ge,
                Sense::Ge => Sense::Le,
                Sense::Eq => Sense::Eq,
            };
            norm_rows.push((flipped, s, -rhs));
        } else {
            norm_rows.push((coeffs, sense, rhs));
        }
    }

    let n_slack = norm_rows
        .iter()
        .filter(|(_, s, _)| matches!(s, Sense::Le | Sense::Ge))
        .count();
    let n_art = norm_rows
        .iter()
        .filter(|(_, s, _)| matches!(s, Sense::Eq | Sense::Ge))
        .count();
    let total = n + n_slack + n_art;

    // Tableau: m rows × (total + 1) columns (last = rhs).
    let width = total + 1;
    let mut t = vec![0.0f64; m * width];
    let mut basis = vec![usize::MAX; m];
    let mut slack_idx = n;
    let mut art_idx = n + n_slack;
    let mut artificials: Vec<usize> = Vec::new();

    for (i, (coeffs, sense, rhs)) in norm_rows.iter().enumerate() {
        let row = &mut t[i * width..(i + 1) * width];
        for &(j, a) in coeffs {
            row[j] += a;
        }
        row[total] = *rhs;
        match sense {
            Sense::Le => {
                row[slack_idx] = 1.0;
                basis[i] = slack_idx;
                slack_idx += 1;
            }
            Sense::Ge => {
                row[slack_idx] = -1.0;
                slack_idx += 1;
                row[art_idx] = 1.0;
                basis[i] = art_idx;
                artificials.push(art_idx);
                art_idx += 1;
            }
            Sense::Eq => {
                row[art_idx] = 1.0;
                basis[i] = art_idx;
                artificials.push(art_idx);
                art_idx += 1;
            }
        }
    }

    let mut iterations = 0usize;

    // --- Phase 1: minimize sum of artificials. ----------------------------
    if !artificials.is_empty() {
        let mut cost1 = vec![0.0f64; total];
        for &a in &artificials {
            cost1[a] = 1.0;
        }
        let status = simplex_core(&mut t, &mut basis, &cost1, m, total, &mut iterations)?;
        if status == SolveStatus::Unbounded {
            bail!("phase-1 LP unbounded (internal error)");
        }
        // Infeasible if any artificial remains positive.
        let phase1_obj: f64 = (0..m)
            .filter(|&i| artificials.contains(&basis[i]))
            .map(|i| t[i * width + total])
            .sum();
        if phase1_obj > 1e-7 {
            return Ok(LpSolution {
                status: SolveStatus::Infeasible,
                x: vec![],
                objective: f64::INFINITY,
                iterations,
            });
        }
        // Drive any residual (zero-valued) artificials out of the basis.
        for i in 0..m {
            if artificials.contains(&basis[i]) {
                let row_start = i * width;
                let pivot_col = (0..n + n_slack)
                    .find(|&j| t[row_start + j].abs() > EPS);
                if let Some(j) = pivot_col {
                    pivot(&mut t, &mut basis, m, width, i, j);
                }
                // If no pivot column exists the row is all-zero — redundant
                // constraint; the artificial stays basic at value 0, which
                // is harmless as long as its column is never re-entered
                // (phase 2 cost treats artificials as +∞ via exclusion).
            }
        }
    }

    // --- Phase 2: original objective over structural + slack columns. ----
    let mut cost2 = vec![0.0f64; total];
    cost2[..n].copy_from_slice(&lp.objective);
    // Exclude artificial columns from entering (cost ignored; entering set
    // excludes them inside simplex_core via the `allowed` width).
    let status = simplex_core_restricted(
        &mut t,
        &mut basis,
        &cost2,
        m,
        total,
        n + n_slack,
        &mut iterations,
    )?;
    if status == SolveStatus::Unbounded {
        return Ok(LpSolution {
            status: SolveStatus::Unbounded,
            x: vec![],
            objective: f64::NEG_INFINITY,
            iterations,
        });
    }

    // Extract solution.
    let mut x = shift.clone();
    for i in 0..m {
        if basis[i] < n {
            x[basis[i]] += t[i * width + total];
        }
    }
    let objective: f64 = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
    Ok(LpSolution { status: SolveStatus::Optimal, x, objective, iterations })
}

/// Primal simplex over all columns.
fn simplex_core(
    t: &mut [f64],
    basis: &mut [usize],
    cost: &[f64],
    m: usize,
    total: usize,
    iterations: &mut usize,
) -> Result<SolveStatus> {
    simplex_core_restricted(t, basis, cost, m, total, total, iterations)
}

/// Primal simplex allowing only columns `< allowed` to enter the basis.
///
/// Maintains an explicit reduced-cost row `z` updated incrementally at
/// each pivot (`z ← z − z_e · t_pivot`), so pricing is O(total) per
/// iteration rather than O(m · total) — the difference between seconds
/// and hours inside the clique-partitioning branch-and-bound.
fn simplex_core_restricted(
    t: &mut [f64],
    basis: &mut [usize],
    cost: &[f64],
    m: usize,
    total: usize,
    allowed: usize,
    iterations: &mut usize,
) -> Result<SolveStatus> {
    let width = total + 1;
    let max_iter = 50_000 + 200 * (m + total);

    // Initial reduced costs z_j = c_j − c_Bᵀ (B⁻¹ A)_j.
    let mut z = vec![0.0f64; width];
    z[..total].copy_from_slice(&cost[..total]);
    for i in 0..m {
        let cb = cost[basis[i]];
        if cb != 0.0 {
            let row = &t[i * width..(i + 1) * width];
            for (zj, &tij) in z.iter_mut().zip(row) {
                *zj -= cb * tij;
            }
        }
    }

    let mut in_basis = vec![false; total];
    for &b in basis.iter() {
        in_basis[b] = true;
    }

    let mut stall = 0usize;
    loop {
        *iterations += 1;
        if *iterations > max_iter {
            bail!("simplex iteration limit exceeded ({max_iter})");
        }
        let use_bland = stall > 4 * (m + total);
        let mut entering: Option<usize> = None;
        let mut best_rc = -EPS;
        for (j, &rc) in z.iter().enumerate().take(allowed) {
            if rc < -EPS && !in_basis[j] {
                if use_bland {
                    entering = Some(j);
                    break;
                }
                if rc < best_rc {
                    best_rc = rc;
                    entering = Some(j);
                }
            }
        }
        let Some(e) = entering else {
            return Ok(SolveStatus::Optimal);
        };

        // Ratio test (Bland-style tie-break on basis index).
        let mut leave: Option<usize> = None;
        let mut best_ratio = f64::INFINITY;
        for i in 0..m {
            let a = t[i * width + e];
            if a > EPS {
                let ratio = t[i * width + total] / a;
                if ratio < best_ratio - EPS
                    || (ratio < best_ratio + EPS && leave.map_or(true, |l| basis[i] < basis[l]))
                {
                    best_ratio = ratio;
                    leave = Some(i);
                }
            }
        }
        let Some(l) = leave else {
            return Ok(SolveStatus::Unbounded);
        };
        if best_ratio < EPS {
            stall += 1;
        } else {
            stall = 0;
        }
        in_basis[basis[l]] = false;
        in_basis[e] = true;
        pivot(t, basis, m, width, l, e);
        // Update the cost row against the (now normalized) pivot row.
        let ze = z[e];
        if ze.abs() > 0.0 {
            let prow = &t[l * width..(l + 1) * width];
            for (zj, &pj) in z.iter_mut().zip(prow) {
                *zj -= ze * pj;
            }
            z[e] = 0.0; // exact, avoids drift on the entering column
        }
    }
}

/// Gauss-Jordan pivot on (row, col).
fn pivot(t: &mut [f64], basis: &mut [usize], m: usize, width: usize, row: usize, col: usize) {
    let p = t[row * width + col];
    debug_assert!(p.abs() > EPS, "pivot on ~zero element");
    let inv = 1.0 / p;
    for v in t[row * width..(row + 1) * width].iter_mut() {
        *v *= inv;
    }
    for i in 0..m {
        if i == row {
            continue;
        }
        let factor = t[i * width + col];
        if factor.abs() > EPS {
            // row_i -= factor * row_pivot  (split borrows via split_at_mut)
            let (lo, hi) = t.split_at_mut(std::cmp::max(i, row) * width);
            let (src, dst) = if row < i {
                (&lo[row * width..row * width + width], &mut hi[..width])
            } else {
                (&hi[..width], &mut lo[i * width..i * width + width])
            };
            // When row > i, hi starts at row*width: src = hi, dst in lo.
            for (d, s) in dst.iter_mut().zip(src) {
                *d -= factor * s;
            }
        } else {
            t[i * width + col] = 0.0;
        }
    }
    basis[row] = col;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(sol: &LpSolution, obj: f64, x: &[f64]) {
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective - obj).abs() < 1e-6, "obj {} vs {obj}", sol.objective);
        for (i, (&got, &want)) in sol.x.iter().zip(x).enumerate() {
            assert!((got - want).abs() < 1e-6, "x[{i}] {got} vs {want}");
        }
    }

    #[test]
    fn textbook_maximization() {
        // max 3a + 5b s.t. a ≤ 4, 2b ≤ 12, 3a + 2b ≤ 18 → a=2, b=6, obj 36.
        let mut lp = LinearProgram::new(2);
        lp.objective = vec![-3.0, -5.0]; // minimize the negative
        lp.add_constraint(vec![(0, 1.0)], Sense::Le, 4.0);
        lp.add_constraint(vec![(1, 2.0)], Sense::Le, 12.0);
        lp.add_constraint(vec![(0, 3.0), (1, 2.0)], Sense::Le, 18.0);
        let sol = solve(&lp).unwrap();
        assert_opt(&sol, -36.0, &[2.0, 6.0]);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + y s.t. x + y = 10, x ≥ 3, y ≥ 2 → any feasible has obj 10.
        let mut lp = LinearProgram::new(2);
        lp.objective = vec![1.0, 1.0];
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Sense::Eq, 10.0);
        lp.bounds = vec![(3.0, f64::INFINITY), (2.0, f64::INFINITY)];
        let sol = solve(&lp).unwrap();
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective - 10.0).abs() < 1e-6);
        assert!((sol.x[0] + sol.x[1] - 10.0).abs() < 1e-6);
        assert!(sol.x[0] >= 3.0 - 1e-9 && sol.x[1] >= 2.0 - 1e-9);
    }

    #[test]
    fn detects_infeasible() {
        // x ≤ 1 and x ≥ 2.
        let mut lp = LinearProgram::new(1);
        lp.add_constraint(vec![(0, 1.0)], Sense::Le, 1.0);
        lp.add_constraint(vec![(0, 1.0)], Sense::Ge, 2.0);
        let sol = solve(&lp).unwrap();
        assert_eq!(sol.status, SolveStatus::Infeasible);
    }

    #[test]
    fn detects_unbounded() {
        // min −x, x ≥ 0 unconstrained above.
        let mut lp = LinearProgram::new(1);
        lp.objective = vec![-1.0];
        let sol = solve(&lp).unwrap();
        assert_eq!(sol.status, SolveStatus::Unbounded);
    }

    #[test]
    fn respects_upper_bounds() {
        // min −x − y with x ≤ 2.5, y ≤ 1.5 (via bounds).
        let mut lp = LinearProgram::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.bounds = vec![(0.0, 2.5), (0.0, 1.5)];
        let sol = solve(&lp).unwrap();
        assert_opt(&sol, -4.0, &[2.5, 1.5]);
    }

    #[test]
    fn shifted_lower_bounds() {
        // min x with x ∈ [−5, −1] → x = −5.
        let mut lp = LinearProgram::new(1);
        lp.objective = vec![1.0];
        lp.bounds = vec![(-5.0, -1.0)];
        let sol = solve(&lp).unwrap();
        assert_opt(&sol, -5.0, &[-5.0]);
    }

    #[test]
    fn degenerate_lp_terminates() {
        // Multiple redundant constraints through the same vertex.
        let mut lp = LinearProgram::new(2);
        lp.objective = vec![-1.0, -1.0];
        lp.add_constraint(vec![(0, 1.0), (1, 1.0)], Sense::Le, 1.0);
        lp.add_constraint(vec![(0, 2.0), (1, 2.0)], Sense::Le, 2.0);
        lp.add_constraint(vec![(0, 1.0)], Sense::Le, 1.0);
        lp.add_constraint(vec![(1, 1.0)], Sense::Le, 1.0);
        let sol = solve(&lp).unwrap();
        assert_eq!(sol.status, SolveStatus::Optimal);
        assert!((sol.objective + 1.0).abs() < 1e-6);
    }

    #[test]
    fn matches_vertex_enumeration_on_random_lps() {
        // Small random LPs over the unit box: compare the simplex optimum
        // to brute-force over box corners ∩ feasibility (valid because
        // with only box bounds + ≤ rows, an optimal extreme point of the
        // polytope need not be a box corner — so instead compare lower
        // bound: simplex obj ≤ every feasible corner's obj).
        use crate::rng::Rng;
        let mut rng = Rng::seed_from_u64(9);
        for _ in 0..20 {
            let nv = 4;
            let mut lp = LinearProgram::new(nv);
            for j in 0..nv {
                lp.objective[j] = rng.uniform(-1.0, 1.0);
                lp.bounds[j] = (0.0, 1.0);
            }
            for _ in 0..3 {
                let coeffs: Vec<(usize, f64)> =
                    (0..nv).map(|j| (j, rng.uniform(-1.0, 1.0))).collect();
                lp.add_constraint(coeffs, Sense::Le, rng.uniform(0.5, 2.0));
            }
            let sol = solve(&lp).unwrap();
            assert_eq!(sol.status, SolveStatus::Optimal);
            // Check feasibility of the returned point.
            for c in &lp.constraints {
                let lhs: f64 = c.coeffs.iter().map(|&(j, a)| a * sol.x[j]).sum();
                assert!(lhs <= c.rhs + 1e-6);
            }
            for (j, &(l, u)) in lp.bounds.iter().enumerate() {
                assert!(sol.x[j] >= l - 1e-7 && sol.x[j] <= u + 1e-7);
            }
            // Simplex optimum must not exceed any feasible corner value.
            for mask in 0u32..(1 << nv) {
                let corner: Vec<f64> =
                    (0..nv).map(|j| if mask & (1 << j) != 0 { 1.0 } else { 0.0 }).collect();
                let feasible = lp.constraints.iter().all(|c| {
                    c.coeffs.iter().map(|&(j, a)| a * corner[j]).sum::<f64>() <= c.rhs + 1e-9
                });
                if feasible {
                    let obj: f64 =
                        lp.objective.iter().zip(&corner).map(|(c, v)| c * v).sum();
                    assert!(sol.objective <= obj + 1e-6);
                }
            }
        }
    }
}
