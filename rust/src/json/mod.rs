//! Minimal JSON parser/serializer.
//!
//! The build environment vendors only the `xla` crate's dependency closure
//! (no `serde`), so the artifact manifest (`artifacts/manifest.json`,
//! written by `python/compile/aot.py`) and the experiment config files are
//! handled by this self-contained recursive-descent implementation. It
//! supports the full JSON grammar except `\uXXXX` surrogate pairs beyond
//! the BMP (not needed for manifests/configs).
//!
//! ## Non-finite floats (artifact duty)
//!
//! JSON has no `NaN`/`Infinity` literals, but fitted-model artifacts
//! (`backbone-model/v1`) legitimately carry them (e.g. the optimality
//! `gap` of a heuristic fallback is `NaN`). A float-printing serializer
//! that emits `NaN` bare produces a document **no** parser accepts back —
//! a silent-corruption trap. This module therefore:
//!
//! - serializes a non-finite [`Json::Number`] as the tagged strings
//!   `"NaN"` / `"Infinity"` / `"-Infinity"` (always-valid output);
//! - rejects bare `NaN`/`Infinity`/`-Infinity` tokens at parse time with
//!   the typed, downcastable error [`NonFiniteLiteral`];
//! - offers the explicit codec pair [`Json::from_f64`] /
//!   [`Json::as_f64_tagged`] for round-tripping any `f64` bit-faithfully
//!   (finite values use the shortest decimal form, which `f64` parsing
//!   inverts exactly).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Typed parse error for bare non-finite number literals (`NaN`,
/// `Infinity`, `-Infinity`): they are not valid JSON, and accepting them
/// would mask serializers that corrupt documents. Use the tagged-string
/// encoding ([`Json::from_f64`]) instead.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NonFiniteLiteral {
    /// Byte offset of the offending token.
    pub at: usize,
}

impl std::fmt::Display for NonFiniteLiteral {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "non-finite number literal at byte {}: NaN/Infinity are not valid JSON \
             (use the tagged-string encoding, e.g. \"NaN\")",
            self.at
        )
    }
}

impl std::error::Error for NonFiniteLiteral {}

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(x) => Some(*x),
            _ => None,
        }
    }

    /// Encode an `f64` so that **every** value round-trips: finite values
    /// become a [`Json::Number`] (shortest decimal form, parsed back
    /// bit-identically), non-finite values become the tagged strings
    /// `"NaN"` / `"Infinity"` / `"-Infinity"`. Inverse:
    /// [`Json::as_f64_tagged`].
    pub fn from_f64(x: f64) -> Json {
        if x.is_finite() {
            Json::Number(x)
        } else {
            Json::String(non_finite_tag(x).to_string())
        }
    }

    /// Decode a float written by [`Json::from_f64`]: numbers pass through,
    /// the tagged strings map back to the non-finite values. Any other
    /// shape (including untagged strings) is `None`.
    pub fn as_f64_tagged(&self) -> Option<f64> {
        match self {
            Json::Number(x) => Some(*x),
            Json::String(s) => match s.as_str() {
                "NaN" => Some(f64::NAN),
                "Infinity" => Some(f64::INFINITY),
                "-Infinity" => Some(f64::NEG_INFINITY),
                _ => None,
            },
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Required-field lookup with a contextual error.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing required JSON field `{key}`"))
    }

    /// Serialize to a compact string.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Number(x) => {
                if !x.is_finite() {
                    // Plain `{x}` would emit `NaN`/`inf` — tokens no JSON
                    // parser (including ours) accepts back. Fall back to
                    // the tagged-string encoding so output stays valid.
                    write_escaped(out, non_finite_tag(*x));
                } else if x.fract() == 0.0 && x.abs() < 1e15 && (*x != 0.0 || x.is_sign_positive())
                {
                    // `-0.0` is excluded: `as i64` would drop the sign bit
                    // and break bit-identical artifact round-trips.
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::String(s) => write_escaped(out, s),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Tagged-string spelling of a non-finite `f64` (see the module docs).
fn non_finite_tag(x: f64) -> &'static str {
    if x.is_nan() {
        "NaN"
    } else if x > 0.0 {
        "Infinity"
    } else {
        "-Infinity"
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            // Bare NaN/Infinity: reject with the typed error rather than
            // the generic "unexpected byte" so the cause is diagnosable.
            // Only the exact spellings qualify — `Nope` is garbage, not a
            // float-printing serializer's fingerprint.
            Some(c @ (b'N' | b'I')) => {
                if self.bytes[self.pos..].starts_with(b"NaN")
                    || self.bytes[self.pos..].starts_with(b"Infinity")
                {
                    Err(anyhow::Error::new(NonFiniteLiteral { at: self.pos }))
                } else {
                    bail!("unexpected byte `{}` at {}", c as char, self.pos)
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected byte `{}` at {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.bytes[self.pos..].starts_with(b"Infinity") {
                // `-Infinity`: same typed rejection as the bare spellings.
                return Err(anyhow::Error::new(NonFiniteLiteral { at: start }));
            }
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let x: f64 = text.parse().map_err(|e| anyhow!("bad number `{text}`: {e}"))?;
        Ok(Json::Number(x))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u{code:04x}"))?,
                            );
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Array(items));
                }
                _ => bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Object(map));
                }
                _ => bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Number(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::String("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": false}], "c": "x"}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""αβ""#).unwrap();
        assert_eq!(v.as_str(), Some("αβ"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"nums":[1,2.5,-3],"s":"a\"b","t":true,"z":null}"#;
        let v = Json::parse(doc).unwrap();
        let compact = v.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Number(3.0).as_usize(), Some(3));
        assert_eq!(Json::Number(3.5).as_usize(), None);
        assert_eq!(Json::Number(-1.0).as_usize(), None);
    }

    #[test]
    fn require_reports_missing_field() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.require("a").is_ok());
        let err = v.require("b").unwrap_err().to_string();
        assert!(err.contains("`b`"), "{err}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Array(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Object(Default::default()));
        assert_eq!(Json::Array(vec![]).to_string_compact(), "[]");
    }

    #[test]
    fn control_characters_escape_and_round_trip() {
        let nasty = "a\u{1}b\u{1f}c\"d\\e\nf\tg";
        let text = Json::String(nasty.into()).to_string_compact();
        // Every emitted byte must be a legal JSON string byte (no raw
        // control characters survive into the document).
        assert!(text.bytes().all(|b| b >= 0x20), "raw control byte in {text:?}");
        assert!(text.contains("\\u0001") && text.contains("\\u001f"), "{text}");
        assert_eq!(Json::parse(&text).unwrap(), Json::String(nasty.into()));
    }

    #[test]
    fn non_finite_numbers_serialize_as_tagged_strings() {
        assert_eq!(Json::Number(f64::NAN).to_string_compact(), "\"NaN\"");
        assert_eq!(Json::Number(f64::INFINITY).to_string_compact(), "\"Infinity\"");
        assert_eq!(
            Json::Number(f64::NEG_INFINITY).to_string_compact(),
            "\"-Infinity\""
        );
        // The emitted document is valid JSON and parses back.
        let doc = Json::Object(
            [("gap".to_string(), Json::Number(f64::NAN))].into_iter().collect(),
        );
        let back = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(back.get("gap").unwrap(), &Json::String("NaN".into()));
    }

    #[test]
    fn bare_non_finite_literals_are_typed_parse_errors() {
        for doc in ["NaN", "Infinity", "-Infinity", "[1, NaN]", r#"{"a": -Infinity}"#] {
            let err = Json::parse(doc).unwrap_err();
            assert!(
                err.downcast_ref::<NonFiniteLiteral>().is_some(),
                "`{doc}` did not produce NonFiniteLiteral: {err}"
            );
        }
        // Only the exact spellings get the typed diagnosis; other garbage
        // starting with the same bytes stays a generic parse error.
        for doc in ["Nope", "Inf", "-Item", "[Nautilus]"] {
            let err = Json::parse(doc).unwrap_err();
            assert!(
                err.downcast_ref::<NonFiniteLiteral>().is_none(),
                "`{doc}` was misdiagnosed as a non-finite literal: {err}"
            );
        }
    }

    #[test]
    fn from_f64_round_trips_every_class_of_value() {
        for x in [0.0, -0.0, 1.5, -3.25, 1e-300, 123456789.0, f64::MAX, f64::MIN_POSITIVE] {
            let text = Json::from_f64(x).to_string_compact();
            let back = Json::parse(&text).unwrap().as_f64_tagged().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} → {text} → {back}");
        }
        for x in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::from_f64(x).to_string_compact();
            let back = Json::parse(&text).unwrap().as_f64_tagged().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} → {text} → {back}");
        }
        // Untagged strings are not floats.
        assert_eq!(Json::String("fast".into()).as_f64_tagged(), None);
    }
}
