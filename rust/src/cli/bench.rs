//! `bench` subcommand: the machine-readable perf harness.
//!
//! Runs each learner's end-to-end backbone fit on the standard shapes
//! (`bench_support::run_bench_suite`), once on the inline sequential
//! schedule (`threads = 1`) and once on the all-cores scheduler
//! (`threads = 0`), and writes the timings as JSON — the `BENCH_*.json`
//! perf trajectory every PR appends to and CI uploads as an artifact.
//!
//! ```text
//! backbone-learn bench [--quick] [--reps N] [--budget SECS] [--out FILE]
//!                      [--schema-only]
//! backbone-learn bench --warm [--quick] [--instances N] [--budget SECS]
//!                      [--seed S] [--out FILE]
//! ```
//!
//! Besides the end-to-end rows, the default mode times every
//! backend-dispatched linalg kernel under each distinct resolved compute
//! backend (blocked scalar, and AVX2 where the CPU has it — see
//! `linalg::backend`) and records a hardware fingerprint (CPU model,
//! detected features, core count), so the checked-in trajectory pins
//! like-for-like perf baselines. `--out` refuses to write a document
//! whose `results` array is empty unless `--schema-only` is passed.
//!
//! `--warm` switches to the warm-start benchmark: a repeat family of
//! sparse-regression instances (same shape, different data seeds) is
//! fitted three ways — cold, warm-started from a leave-one-out
//! [`WarmStartStore`] (nearest-neighbor hit, shrunken screening
//! universe), and served from an exact cache hit (no solve at all).
//! Rows carry `mode` and `objective` so CI can assert warm fits are
//! faster at equal-or-better objectives; the default output file is
//! `BENCH_PR6.json`.
//!
//! `--quick` is the CI scale (small shapes, 1 rep by default); without it
//! the suite includes the n=500, p=2000 sparse-regression class the perf
//! acceptance gate tracks. Fits are bit-identical across thread counts
//! (the batch-scheduler contract), so the sequential/parallel ratio is
//! pure scheduling speedup.
//!
//! JSON schema (`backbone-bench/v1`):
//!
//! ```json
//! {
//!   "schema": "backbone-bench/v1",
//!   "quick": true,
//!   "reps": 1,
//!   "budget_secs": 20.0,
//!   "threads_available": 8,
//!   "backend": "simd",
//!   "hardware": { "cpu_model": "...", "features": ["avx2", "fma"],
//!                 "cores": 8, "simd_available": true },
//!   "results": [
//!     { "learner": "sparse_regression", "n": 120, "p": 600, "k": 5,
//!       "m": 5, "threads": 1, "reps": 1, "mean_secs": 0.42,
//!       "min_secs": 0.42, "metric": { "name": "r2", "value": 0.93 } },
//!     { "kind": "kernel", "kernel": "gram", "backend": "simd",
//!       "n": 500, "p": 2000, "reps": 3,
//!       "mean_secs": 0.61, "min_secs": 0.61 }
//!   ]
//! }
//! ```

use super::Args;
use crate::backbone::pipeline::resolved_threads;
use crate::backbone::sparse_regression::SparseRegressionModel;
use crate::backbone::Backbone;
use crate::bench_support::{
    emit_bench_json, hardware_fingerprint, kernel_bench_rows, run_bench_suite,
};
use crate::data::sparse_regression;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::util::Budget;
use crate::warmstart::{featurize, suggested_alpha, InstanceFeatures, WarmStartStore};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

pub fn run(args: &Args) -> Result<i32> {
    if args.flag("warm") {
        return run_warm(args);
    }
    let quick = args.flag("quick");
    let reps = args.get_usize("reps", if quick { 1 } else { 3 })?;
    let budget_secs = args.get_f64("budget", if quick { 20.0 } else { 120.0 })?;
    let out = args.get("out").unwrap_or_else(|| "BENCH_PR8.json".into());

    eprintln!(
        "[bench] {} scale: reps={reps} budget={budget_secs}s backend={} → {out}",
        if quick { "quick" } else { "full" },
        crate::linalg::backend_name(),
    );
    // Per-backend kernel rows first (they flip the global backend and
    // restore it), then the end-to-end suite under the session backend.
    let kernel_rows = kernel_bench_rows(quick, reps);
    {
        let mut by_kernel: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
        for r in &kernel_rows {
            let (Some(kernel), Some(be), Some(secs)) = (
                r.get("kernel").and_then(|v| v.as_str()),
                r.get("backend").and_then(|v| v.as_str()),
                r.get("min_secs").and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            by_kernel.entry(kernel.into()).or_default().insert(be.into(), secs);
        }
        println!("{:<20} {:>14} {:>14} {:>9}", "Kernel", "scalar (s)", "simd (s)", "speedup");
        for (kernel, by_be) in &by_kernel {
            let scalar = by_be.get("scalar").copied();
            let simd = by_be.get("simd").copied();
            println!(
                "{:<20} {:>14} {:>14} {:>9}",
                kernel,
                scalar.map_or_else(|| "—".into(), |s| format!("{s:.3e}")),
                simd.map_or_else(|| "—".into(), |s| format!("{s:.3e}")),
                match (scalar, simd) {
                    (Some(s), Some(v)) if v > 0.0 => format!("{:.2}×", s / v),
                    _ => "—".into(),
                }
            );
        }
    }
    let results = run_bench_suite(quick, reps, budget_secs, &[1, 0])?;

    println!(
        "{:<18} {:>5} {:>5} {:>3} {:>3} {:>7} {:>10} {:>10} {:>12}",
        "Learner", "n", "p", "k", "M", "thr", "mean (s)", "min (s)", "metric"
    );
    for r in &results {
        println!(
            "{:<18} {:>5} {:>5} {:>3} {:>3} {:>7} {:>10.3} {:>10.3} {:>6}={:.3}",
            r.learner,
            r.n,
            r.p,
            r.k,
            r.m,
            if r.threads == 0 { "all".into() } else { r.threads.to_string() },
            r.mean_secs,
            r.min_secs,
            r.metric_name,
            r.metric
        );
    }
    // Sequential → parallel speedup per learner (same shape, same fit —
    // the contract makes results identical, so this is pure scheduling).
    for pair in results.chunks(2) {
        if let [seq, par] = pair {
            if par.mean_secs > 0.0 {
                println!(
                    "  {}: sequential/parallel = {:.2}×",
                    seq.learner,
                    seq.mean_secs / par.mean_secs
                );
            }
        }
    }

    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("schema".into(), Json::String("backbone-bench/v1".into()));
    doc.insert("quick".into(), Json::Bool(quick));
    doc.insert("reps".into(), Json::Number(reps as f64));
    doc.insert("budget_secs".into(), Json::Number(budget_secs));
    doc.insert(
        "threads_available".into(),
        Json::Number(resolved_threads(0) as f64),
    );
    doc.insert("hardware".into(), hardware_fingerprint());
    doc.insert(
        "backend".into(),
        Json::String(crate::linalg::backend_name().into()),
    );
    let mut rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut row: BTreeMap<String, Json> = BTreeMap::new();
            row.insert("learner".into(), Json::String(r.learner.into()));
            row.insert("n".into(), Json::Number(r.n as f64));
            row.insert("p".into(), Json::Number(r.p as f64));
            row.insert("k".into(), Json::Number(r.k as f64));
            row.insert("m".into(), Json::Number(r.m as f64));
            row.insert("threads".into(), Json::Number(r.threads as f64));
            row.insert("reps".into(), Json::Number(r.reps as f64));
            row.insert("mean_secs".into(), Json::Number(r.mean_secs));
            row.insert("min_secs".into(), Json::Number(r.min_secs));
            let mut metric: BTreeMap<String, Json> = BTreeMap::new();
            metric.insert("name".into(), Json::String(r.metric_name.into()));
            metric.insert("value".into(), Json::Number(r.metric));
            row.insert("metric".into(), Json::Object(metric));
            Json::Object(row)
        })
        .collect();
    rows.extend(kernel_rows);
    doc.insert("results".into(), Json::Array(rows));
    emit_bench_json(&out, &Json::Object(doc), args.flag("schema-only"))?;
    eprintln!("wrote {out}");
    Ok(0)
}

/// One instance of the repeat family, with its cached featurization.
struct FamilyInstance {
    x: Matrix,
    y: Vec<f64>,
    features: InstanceFeatures,
}

/// `bench --warm`: cold vs warm-started vs exact-cache-hit fits on a
/// repeat family of same-shape sparse-regression instances.
fn run_warm(args: &Args) -> Result<i32> {
    let quick = args.flag("quick");
    let instances = args.get_usize("instances", 5)?.max(2);
    let budget_secs = args.get_f64("budget", if quick { 20.0 } else { 120.0 })?;
    let seed = args.get_u64("seed", 0)?;
    let out = args.get("out").unwrap_or_else(|| "BENCH_PR6.json".into());
    let (n, p, k, m) = if quick { (100, 400, 5, 5) } else { (200, 1000, 5, 5) };
    let cold_alpha = 0.5;

    eprintln!(
        "[bench --warm] {} repeat-family instances (n={n} p={p} k={k} m={m}) → {out}",
        instances
    );
    let family: Vec<FamilyInstance> = (0..instances)
        .map(|i| {
            let mut rng = Rng::seed_from_u64(seed.wrapping_add(i as u64));
            let data = sparse_regression::generate(
                &sparse_regression::SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
                &mut rng,
            );
            let features = featurize(&data.x, &data.y, k);
            FamilyInstance { x: data.x, y: data.y, features }
        })
        .collect();

    // One timed fit: cold (no warm start) or neighbor-warm (cached beta
    // plus the shrunken screening fraction the cache suggests).
    let solve = |inst: &FamilyInstance,
                 alpha: f64,
                 warm: Option<Vec<f64>>|
     -> Result<(SparseRegressionModel, f64)> {
        let builder = Backbone::sparse_regression()
            .alpha(alpha)
            .beta(0.5)
            .num_subproblems(m)
            .max_nonzeros(k)
            .threads(1)
            .seed(seed);
        let builder = match warm {
            None => builder,
            Some(w) => builder.warm_start(w),
        };
        let mut bb = builder.build()?;
        let clock = Instant::now();
        let model = bb.fit_with_budget(&inst.x, &inst.y, &Budget::seconds(budget_secs))?.clone();
        Ok((model, clock.elapsed().as_secs_f64()))
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut row = |i: usize, mode: &str, secs: f64, objective: f64, distance: Option<f64>| {
        let mut r: BTreeMap<String, Json> = BTreeMap::new();
        r.insert("learner".into(), Json::String("sparse_regression".into()));
        r.insert("instance".into(), Json::Number(i as f64));
        r.insert("n".into(), Json::Number(n as f64));
        r.insert("p".into(), Json::Number(p as f64));
        r.insert("k".into(), Json::Number(k as f64));
        r.insert("m".into(), Json::Number(m as f64));
        r.insert("threads".into(), Json::Number(1.0));
        r.insert("mode".into(), Json::String(mode.into()));
        r.insert("secs".into(), Json::Number(secs));
        r.insert("objective".into(), Json::from_f64(objective));
        if let Some(d) = distance {
            r.insert("distance".into(), Json::Number(d));
        }
        rows.push(Json::Object(r));
    };

    // Pass 1: cold fits — the baseline, and the entries the store learns.
    let mut cold: Vec<(SparseRegressionModel, f64)> = Vec::new();
    for (i, inst) in family.iter().enumerate() {
        let (model, secs) = solve(inst, cold_alpha, None)?;
        println!("instance {i}: cold  {secs:>8.3}s  objective {:.6}", model.objective);
        row(i, "cold", secs, model.objective, None);
        cold.push((model, secs));
    }

    // Pass 2: neighbor-warm fits — for each instance, a leave-one-out
    // store (so the hit is a true neighbor, never the instance itself)
    // suggests a warm start; the timed window covers lookup + solve.
    let mut warm: Vec<(f64, f64)> = Vec::new();
    for (i, inst) in family.iter().enumerate() {
        let mut store = WarmStartStore::new(instances.max(8));
        for (j, other) in family.iter().enumerate() {
            if j == i {
                continue;
            }
            let model = &cold[j].0;
            let coeffs: Vec<f64> = model.support.iter().map(|&c| model.beta[c]).collect();
            store.record(
                &other.features,
                &model.support,
                &coeffs,
                model.intercept,
                model.objective,
                cold_alpha,
            );
        }
        let clock = Instant::now();
        let suggestion = store.suggest(&inst.features);
        let (model, solve_secs, distance) = match suggestion {
            Some(w) if w.beta.len() == p => {
                let alpha = suggested_alpha(p, k);
                let d = w.distance;
                let (model, secs) = solve(inst, alpha, Some(w.beta))?;
                (model, secs, Some(d))
            }
            _ => {
                let (model, secs) = solve(inst, cold_alpha, None)?;
                (model, secs, None)
            }
        };
        let secs = clock.elapsed().as_secs_f64().max(solve_secs);
        println!(
            "instance {i}: warm  {secs:>8.3}s  objective {:.6}  (cold {:.3}s, {:.2}×)",
            model.objective,
            cold[i].1,
            cold[i].1 / secs.max(1e-12)
        );
        row(i, "warm_neighbor", secs, model.objective, distance);
        warm.push((secs, model.objective));
    }

    // Pass 3: exact cache hits — the store has seen these instances, so
    // the lookup *is* the fit (featurize + nearest-neighbor + copy-out).
    let mut store = WarmStartStore::new(instances.max(8));
    for (inst, (model, _)) in family.iter().zip(&cold) {
        let coeffs: Vec<f64> = model.support.iter().map(|&c| model.beta[c]).collect();
        store.record(&inst.features, &model.support, &coeffs, model.intercept, model.objective, cold_alpha);
    }
    let mut exact: Vec<f64> = Vec::new();
    for (i, inst) in family.iter().enumerate() {
        let clock = Instant::now();
        let features = featurize(&inst.x, &inst.y, k);
        let w = store.suggest(&features).context("exact lookup missed its own entry")?;
        let secs = clock.elapsed().as_secs_f64();
        println!(
            "instance {i}: exact {secs:>8.3}s  objective {:.6}  (hit exact={})",
            w.objective, w.exact
        );
        row(i, "warm_exact", secs, w.objective, Some(w.distance));
        exact.push(secs);
    }

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let cold_secs: Vec<f64> = cold.iter().map(|(_, s)| *s).collect();
    let warm_secs: Vec<f64> = warm.iter().map(|(s, _)| *s).collect();
    let (cold_mean, warm_mean, exact_mean) =
        (mean(&cold_secs), mean(&warm_secs), mean(&exact));
    let worsened = warm
        .iter()
        .zip(&cold)
        .filter(|((_, wo), (cm, _))| *wo > cm.objective * (1.0 + 1e-9) + 1e-12)
        .count();
    println!(
        "family mean: cold {cold_mean:.3}s · warm {warm_mean:.3}s ({:.2}×) · \
         exact {exact_mean:.6}s ({:.0}×) · objectives worsened: {worsened}/{instances}",
        cold_mean / warm_mean.max(1e-12),
        cold_mean / exact_mean.max(1e-12),
    );

    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("schema".into(), Json::String("backbone-bench/v1".into()));
    doc.insert("mode".into(), Json::String("warm".into()));
    doc.insert("quick".into(), Json::Bool(quick));
    doc.insert("instances".into(), Json::Number(instances as f64));
    doc.insert("seed".into(), Json::Number(seed as f64));
    doc.insert("budget_secs".into(), Json::Number(budget_secs));
    doc.insert("threads_available".into(), Json::Number(resolved_threads(0) as f64));
    let mut summary: BTreeMap<String, Json> = BTreeMap::new();
    summary.insert("cold_mean_secs".into(), Json::Number(cold_mean));
    summary.insert("warm_mean_secs".into(), Json::Number(warm_mean));
    summary.insert("exact_mean_secs".into(), Json::Number(exact_mean));
    summary.insert("warm_speedup".into(), Json::Number(cold_mean / warm_mean.max(1e-12)));
    summary.insert("exact_speedup".into(), Json::Number(cold_mean / exact_mean.max(1e-12)));
    summary.insert("objectives_worsened".into(), Json::Number(worsened as f64));
    doc.insert("summary".into(), Json::Object(summary));
    doc.insert("results".into(), Json::Array(rows));
    let text = Json::Object(doc).to_string_pretty();
    std::fs::write(&out, &text).with_context(|| format!("writing `{out}`"))?;
    eprintln!("wrote {out}");
    Ok(0)
}
