//! `bench` subcommand: the machine-readable perf harness.
//!
//! Runs each learner's end-to-end backbone fit on the standard shapes
//! (`bench_support::run_bench_suite`), once on the inline sequential
//! schedule (`threads = 1`) and once on the all-cores scheduler
//! (`threads = 0`), and writes the timings as JSON — the `BENCH_*.json`
//! perf trajectory every PR appends to and CI uploads as an artifact.
//!
//! ```text
//! backbone-learn bench [--quick] [--reps N] [--budget SECS] [--out FILE]
//! ```
//!
//! `--quick` is the CI scale (small shapes, 1 rep by default); without it
//! the suite includes the n=500, p=2000 sparse-regression class the perf
//! acceptance gate tracks. Fits are bit-identical across thread counts
//! (the batch-scheduler contract), so the sequential/parallel ratio is
//! pure scheduling speedup.
//!
//! JSON schema (`backbone-bench/v1`):
//!
//! ```json
//! {
//!   "schema": "backbone-bench/v1",
//!   "quick": true,
//!   "reps": 1,
//!   "budget_secs": 20.0,
//!   "threads_available": 8,
//!   "results": [
//!     { "learner": "sparse_regression", "n": 120, "p": 600, "k": 5,
//!       "m": 5, "threads": 1, "reps": 1, "mean_secs": 0.42,
//!       "min_secs": 0.42, "metric": { "name": "r2", "value": 0.93 } }
//!   ]
//! }
//! ```

use super::Args;
use crate::backbone::pipeline::resolved_threads;
use crate::bench_support::run_bench_suite;
use crate::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

pub fn run(args: &Args) -> Result<i32> {
    let quick = args.flag("quick");
    let reps = args.get_usize("reps", if quick { 1 } else { 3 })?;
    let budget_secs = args.get_f64("budget", if quick { 20.0 } else { 120.0 })?;
    let out = args.get("out").unwrap_or_else(|| "BENCH_PR4.json".into());

    eprintln!(
        "[bench] {} scale: reps={reps} budget={budget_secs}s → {out}",
        if quick { "quick" } else { "full" }
    );
    let results = run_bench_suite(quick, reps, budget_secs, &[1, 0])?;

    println!(
        "{:<18} {:>5} {:>5} {:>3} {:>3} {:>7} {:>10} {:>10} {:>12}",
        "Learner", "n", "p", "k", "M", "thr", "mean (s)", "min (s)", "metric"
    );
    for r in &results {
        println!(
            "{:<18} {:>5} {:>5} {:>3} {:>3} {:>7} {:>10.3} {:>10.3} {:>6}={:.3}",
            r.learner,
            r.n,
            r.p,
            r.k,
            r.m,
            if r.threads == 0 { "all".into() } else { r.threads.to_string() },
            r.mean_secs,
            r.min_secs,
            r.metric_name,
            r.metric
        );
    }
    // Sequential → parallel speedup per learner (same shape, same fit —
    // the contract makes results identical, so this is pure scheduling).
    for pair in results.chunks(2) {
        if let [seq, par] = pair {
            if par.mean_secs > 0.0 {
                println!(
                    "  {}: sequential/parallel = {:.2}×",
                    seq.learner,
                    seq.mean_secs / par.mean_secs
                );
            }
        }
    }

    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    doc.insert("schema".into(), Json::String("backbone-bench/v1".into()));
    doc.insert("quick".into(), Json::Bool(quick));
    doc.insert("reps".into(), Json::Number(reps as f64));
    doc.insert("budget_secs".into(), Json::Number(budget_secs));
    doc.insert(
        "threads_available".into(),
        Json::Number(resolved_threads(0) as f64),
    );
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut row: BTreeMap<String, Json> = BTreeMap::new();
            row.insert("learner".into(), Json::String(r.learner.into()));
            row.insert("n".into(), Json::Number(r.n as f64));
            row.insert("p".into(), Json::Number(r.p as f64));
            row.insert("k".into(), Json::Number(r.k as f64));
            row.insert("m".into(), Json::Number(r.m as f64));
            row.insert("threads".into(), Json::Number(r.threads as f64));
            row.insert("reps".into(), Json::Number(r.reps as f64));
            row.insert("mean_secs".into(), Json::Number(r.mean_secs));
            row.insert("min_secs".into(), Json::Number(r.min_secs));
            let mut metric: BTreeMap<String, Json> = BTreeMap::new();
            metric.insert("name".into(), Json::String(r.metric_name.into()));
            metric.insert("value".into(), Json::Number(r.metric));
            row.insert("metric".into(), Json::Object(metric));
            Json::Object(row)
        })
        .collect();
    doc.insert("results".into(), Json::Array(rows));
    let text = Json::Object(doc).to_string_pretty();
    std::fs::write(&out, &text).with_context(|| format!("writing `{out}`"))?;
    eprintln!("wrote {out}");
    Ok(0)
}
