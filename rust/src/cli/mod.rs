//! Command-line launcher.
//!
//! ```text
//! backbone-learn table1 [--block sr|dt|cl|all] [--full] [--threads N] [--config FILE] [--out FILE]
//! backbone-learn fit    --problem sr|dt|cl [--n N --p P --k K --alpha A --beta B --m M --seed S --threads N] [--warm-cache FILE] [--out FILE]
//! backbone-learn save    --learner sr|lr|dt|cl --out model.json [fit args] [--data-out rows.csv]
//! backbone-learn predict --model model.json --data rows.csv [--labels y.csv] [--out preds.json]
//! backbone-learn serve   --model [name=]model.json [--model name=other.json ...] [--port P] [--threads N] [--max-connections N] [--fit] [--warm-cache FILE] [--self-test [--quick]]
//! backbone-learn ablate --sweep alpha-beta|num-subproblems|screen [--block sr|dt|cl] [--threads N]
//! backbone-learn bench  [--quick] [--warm] [--reps N] [--budget SECS] [--out FILE]
//! backbone-learn dump-config --problem sr|dt|cl [--full]
//! backbone-learn artifacts [--dir artifacts]
//! ```
//!
//! `--threads N` runs each backbone iteration's subproblem batch on N OS
//! worker threads (0 = all available cores; 1 = the inline sequential
//! schedule; omitted = library default, sequential unless
//! `BACKBONE_THREADS` is set). Results are bit-identical across thread
//! counts.
//!
//! (The vendored offline crate set has no `clap`; this is a small
//! hand-rolled parser with the same ergonomics for our needs.)

mod ablate;
mod args;
mod bench;
mod fit;
mod model;
mod table1;

pub use args::Args;

use anyhow::{Context, Result};

const USAGE: &str = "\
backbone-learn — BackboneLearn reproduction (Rust + JAX/Pallas AOT)

USAGE:
  backbone-learn table1 [--block sr|dt|cl|all] [--full] [--threads N]
                        [--config FILE] [--out FILE]
  backbone-learn fit    --problem sr|dt|cl [--n N] [--p P] [--k K]
                        [--alpha A] [--beta B] [--m M] [--seed S] [--budget SECS]
                        [--threads N] [--out FILE]   (diagnostics + metrics as JSON)
                        [--trace]                    (record spans through the fit;
                         nested trace tree → diagnostics.trace in --out)
                        [--warm-cache store.json]    (sr only: learn + reuse warm
                         starts across fits; exact repeats skip the solve)
  backbone-learn save    --learner sr|lr|dt|cl --out model.json
                         [--n N] [--p P] [--k K] [--alpha A] [--beta B] [--m M]
                         [--seed S] [--budget SECS] [--threads N]
                         [--data-out rows.csv] [--labels-out y.csv]
                         (fit on generated data → backbone-model/v1 artifact)
  backbone-learn predict --model model.json --data rows.csv
                         [--labels y.csv] [--out preds.json]
                         (artifact + CSV rows → predictions; --labels adds
                          metrics incl. confusion matrix + ROC AUC)
  backbone-learn serve   --model [name=]model.json [--model name=other.json ...]
                         [--host H] [--port P] [--threads N] [--fit]
                         [--warm-cache store.json] [--max-fits N] [--max-inflight N]
                         [--max-connections N] [--read-timeout SECS]
                         [--idle-timeout SECS] [--fit-timeout SECS]
                         [--no-keep-alive]
                         (keep-alive HTTP model server, one handler thread per
                          connection bounded by --max-connections (default 64,
                          saturation → 503 + Retry-After): POST /predict,
                          POST /models/<id>/predict, PUT /models/<id> hot swap,
                          GET /models, GET /healthz, GET /stats, GET /metrics
                          (Prometheus text exposition); --fit adds
                          POST /fit — online fits on --threads solver threads
                          (body `trace: true` returns the fit's trace tree)
                          with a learned warm-start cache; overload → 429 +
                          Retry-After; --fit-timeout / per-request deadline_ms
                          cancel overrunning solves → 503 + Retry-After)
  backbone-learn serve   --model model.json --self-test [--quick] [--requests N]
                         [--connections C] [--batch B] [--target-rps R]
                         [--duration SECS] [--slo-p99-ms MS] [--no-keep-alive]
                         [--no-swap] [--no-compare] [--out report.json]
                         [--chaos [--chaos-seed N]]
                         (loopback load test: keep-alive reuse vs close-mode,
                          hot-swap-under-load, optional p99 SLO; non-zero exit
                          unless the report passes. --chaos — requires a
                          `--features fault-inject` build — swaps in the fault
                          drill: seeded worker panics / write failures /
                          connection drops / slow reads, then audits survival,
                          structured errors, checksum-clean artifacts, and
                          exact /stats + /metrics counter reconciliation)
  backbone-learn ablate --sweep alpha-beta|num-subproblems|screen [--block sr|dt|cl]
                        [--threads N]
  backbone-learn bench  [--quick] [--reps N] [--budget SECS] [--out FILE]
                        [--schema-only]  (end-to-end + per-backend kernel perf
                         harness with a hardware fingerprint; timings as JSON.
                         --out refuses an empty results array unless
                         --schema-only is passed)
  backbone-learn bench  --warm [--quick] [--instances N] [--budget SECS]
                        [--out FILE]  (cold vs warm-start fits on a repeat
                         family → BENCH_PR6.json)
  backbone-learn dump-config --problem sr|dt|cl [--full]
  backbone-learn artifacts [--dir DIR]

Run with quick (CI-scale) sizes by default; pass --full for Table-1 scale.
--threads N solves each subproblem batch on N OS threads (0 = all cores,
1 = inline sequential) with bit-identical results.
--backend scalar|simd|auto (any subcommand; also BACKBONE_BACKEND env var
or the config-file `backend` key) picks the linalg compute backend:
blocked scalar kernels or runtime-detected AVX2. Backends are
bit-identical — the choice only changes wall-clock time.
BACKBONE_LOG=error|warn|info|debug|off filters the structured JSON log
lines on stderr (default warn; serve logs each request at info).
";

/// CLI entry point (called from `main.rs`).
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Dispatch on the subcommand; returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(2);
    };
    let args = Args::parse(&argv[1..])?;
    // Global --backend: pin the linalg compute backend before any kernel
    // runs. Subcommands without the flag inherit BACKBONE_BACKEND/auto
    // (table1 additionally applies the config file's `backend` key).
    if let Some(b) = args.get("backend") {
        let choice = crate::linalg::BackendChoice::parse(&b)
            .with_context(|| format!("--backend must be scalar|simd|auto, got `{b}`"))?;
        crate::linalg::set_backend(choice);
    }
    match cmd.as_str() {
        "table1" => table1::run(&args),
        "fit" => fit::run(&args),
        "save" => model::save(&args),
        "predict" => model::predict(&args),
        "serve" => model::serve(&args),
        "ablate" => ablate::run(&args),
        "bench" => bench::run(&args),
        "dump-config" => {
            let problem = crate::config::Problem::parse(
                &args.get("problem").unwrap_or_else(|| "sr".into()),
            )?;
            let cfg = if args.flag("full") {
                crate::config::ExperimentConfig::paper_defaults(problem)
            } else {
                crate::config::ExperimentConfig::quick_defaults(problem)
            };
            print!("{}", cfg.to_json().to_string_pretty());
            Ok(0)
        }
        "artifacts" => {
            let dir = args.get("dir").unwrap_or_else(|| "artifacts".into());
            match crate::runtime::describe_artifacts(&dir) {
                Ok(desc) => {
                    print!("{desc}");
                    Ok(0)
                }
                Err(e) => {
                    println!("no usable artifacts in `{dir}`: {e}");
                    println!("run `make artifacts` to build them");
                    Ok(0)
                }
            }
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n{USAGE}");
            Ok(2)
        }
    }
}
