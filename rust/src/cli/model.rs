//! `save` / `predict` / `serve` subcommands — the persistence + serving
//! half of the CLI.
//!
//! ```text
//! backbone-learn save    --learner sr|lr|dt|cl --out model.json
//!                        [--n N --p P --k K --alpha A --beta B --m M
//!                         --seed S --budget SECS --threads N]
//!                        [--data-out rows.csv] [--labels-out y.csv]
//! backbone-learn predict --model model.json --data rows.csv
//!                        [--labels y.csv] [--out preds.json]
//! backbone-learn serve   --model [name=]model.json [--model name=other.json ...]
//!                        [--port P] [--host H] [--threads N]
//!                        [--fit] [--warm-cache store.json] [--max-fits N]
//!                        [--max-inflight N] [--read-timeout SECS]
//!                        [--idle-timeout SECS] [--fit-timeout SECS]
//!                        [--no-keep-alive]
//! backbone-learn serve   --model model.json --self-test [--quick]
//!                        [--requests N] [--connections C] [--batch B]
//!                        [--threads N] [--target-rps R] [--duration SECS]
//!                        [--slo-p99-ms MS] [--no-keep-alive] [--no-swap]
//!                        [--no-compare] [--chaos] [--chaos-seed N]
//!                        [--out report.json]
//! ```
//!
//! `save` fits a learner on generated data (same generators as `fit`)
//! and freezes the fitted state as a `backbone-model/v1` artifact;
//! `predict` runs a saved artifact over CSV rows (reporting regression /
//! classification / clustering metrics when `--labels` is given,
//! including the confusion matrix and ROC AUC for classifiers); `serve`
//! exposes one or more named artifacts over keep-alive HTTP (path-routed
//! `/models/<id>/predict`, hot swap via `PUT /models/<id>`), or — with
//! `--self-test` — drives its own loopback load test (keep-alive reuse,
//! close-mode comparison, hot-swap-under-load, optional p99 SLO) and
//! exits non-zero unless the report passes.

use super::Args;
use crate::backbone::Backbone;
use crate::data::{blobs, classification, csv, sparse_regression};
use crate::json::Json;
use crate::linalg::Matrix;
use crate::metrics::{
    adjusted_rand_index, confusion_matrix, mse, r2_score, roc_auc, silhouette_score,
};
use crate::persist::{LearnerKind, LoadedModel, ModelArtifact};
use crate::rng::Rng;
use crate::serve::selftest::{run_self_test, SelfTestConfig};
use crate::serve::{parse_model_spec, ServeConfig, Server};
use crate::util::Budget;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parse the CLI learner id (`--learner`, falling back to `--problem`
/// for symmetry with `fit`).
fn parse_learner(args: &Args) -> Result<LearnerKind> {
    let id = args
        .get("learner")
        .or_else(|| args.get("problem"))
        .context("--learner is required (sr|lr|dt|cl)")?;
    Ok(match id.as_str() {
        "sr" | "sparse-regression" | "sparse_regression" => LearnerKind::SparseRegression,
        "lr" | "sparse-logistic" | "sparse_logistic" | "logistic" => {
            LearnerKind::SparseLogistic
        }
        "dt" | "decision-tree" | "decision_tree" | "decision-trees" => {
            LearnerKind::DecisionTree
        }
        "cl" | "clustering" => LearnerKind::Clustering,
        other => bail!("unknown learner `{other}` (expected sr|lr|dt|cl)"),
    })
}

// ---------------------------------------------------------------------------
// save
// ---------------------------------------------------------------------------

pub fn save(args: &Args) -> Result<i32> {
    let learner = parse_learner(args)?;
    let out = args.get("out").context("--out is required (artifact path)")?;
    let seed = args.get_u64("seed", 0)?;
    let alpha = args.get_fraction("alpha", 0.5)?;
    let beta = args.get_fraction("beta", 0.5)?;
    let m = args.get_usize("m", 5)?;
    let threads = args.get_usize("threads", 1)?;
    let budget = Budget::seconds(args.get_f64("budget", 60.0)?);
    let mut rng = Rng::seed_from_u64(seed);

    // (X rows, labels) written alongside the artifact on request — the
    // natural companion inputs for `cli predict`.
    let companion: (Matrix, Vec<f64>);

    let artifact = match learner {
        LearnerKind::SparseRegression => {
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 500)?;
            let k = args.get_usize("k", 5)?;
            let data = sparse_regression::generate(
                &sparse_regression::SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
                &mut rng,
            );
            let mut bb = Backbone::sparse_regression()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .max_nonzeros(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_sparse_regression(&bb)?
        }
        LearnerKind::SparseLogistic => {
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 100)?;
            let k = args.get_usize("k", 3)?;
            let data = classification::generate(
                &classification::ClassificationConfig {
                    n,
                    p,
                    k,
                    n_redundant: 0,
                    n_clusters: 2,
                    class_sep: 1.5,
                    flip_y: 0.05,
                },
                &mut rng,
            );
            let mut bb = Backbone::sparse_logistic()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .max_nonzeros(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_sparse_logistic(&bb)?
        }
        LearnerKind::DecisionTree => {
            let n = args.get_usize("n", 300)?;
            let p = args.get_usize("p", 40)?;
            let k = args.get_usize("k", 5)?;
            let data = classification::generate(
                &classification::ClassificationConfig {
                    n,
                    p,
                    k,
                    n_redundant: (p / 10).min(k),
                    n_clusters: 4,
                    class_sep: 1.5,
                    flip_y: 0.05,
                },
                &mut rng,
            );
            let mut bb = Backbone::decision_tree()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .depth(args.get_usize("depth", 2)?)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_decision_tree(&bb)?
        }
        LearnerKind::Clustering => {
            let n = args.get_usize("n", 16)?;
            let p = args.get_usize("p", 2)?;
            let k = args.get_usize("k", 4)?;
            let true_k = (k.saturating_sub(2)).max(2);
            let data = blobs::generate(
                &blobs::BlobsConfig {
                    n,
                    p,
                    true_clusters: true_k,
                    cluster_std: 1.0,
                    center_box: 10.0,
                    min_center_dist: 4.0,
                },
                &mut rng,
            );
            let mut bb = Backbone::clustering()
                .beta(beta)
                .num_subproblems(m)
                .n_clusters(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &budget)?;
            let truth: Vec<f64> = data.labels_true.iter().map(|&l| l as f64).collect();
            companion = (data.x, truth);
            ModelArtifact::from_clustering(&bb)?
        }
    };

    artifact.save(&out)?;
    let digest = artifact.provenance.diagnostics.as_ref();
    println!(
        "saved {} artifact → {out} (backbone size {}, {} iterations)",
        artifact.learner().name(),
        digest.map_or(0, |d| d.backbone_size),
        digest.map_or(0, |d| d.iterations),
    );
    if let Some(path) = args.get("data-out") {
        crate::util::atomic_write(&path, &csv::format_matrix(&companion.0))
            .with_context(|| format!("writing `{path}`"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("labels-out") {
        crate::util::atomic_write(&path, &csv::format_vector(&companion.1))
            .with_context(|| format!("writing `{path}`"))?;
        eprintln!("wrote {path}");
    }
    Ok(0)
}

// ---------------------------------------------------------------------------
// predict
// ---------------------------------------------------------------------------

pub fn predict(args: &Args) -> Result<i32> {
    let model_path = args.get("model").context("--model is required")?;
    let data_path = args.get("data").context("--data is required (CSV rows)")?;
    let artifact = ModelArtifact::load(&model_path)?;
    let x = csv::read_matrix(&data_path)?;
    let kind = artifact.learner();

    // One inference pass; predictions are the thresholded view of it.
    let scores = artifact.model.predict_scores(&x)?;
    let predictions = artifact.model.predictions_from_scores(&scores);

    let mut metrics: BTreeMap<String, Json> = BTreeMap::new();
    if let Some(labels_path) = args.get("labels") {
        let y = csv::read_vector(&labels_path)?;
        if y.len() != predictions.len() {
            bail!(
                "--labels has {} entries but --data has {} rows",
                y.len(),
                predictions.len()
            );
        }
        match kind {
            LearnerKind::SparseRegression => {
                metrics.insert("r2".into(), Json::from_f64(r2_score(&y, &predictions)));
                metrics.insert("mse".into(), Json::from_f64(mse(&y, &predictions)));
            }
            LearnerKind::SparseLogistic | LearnerKind::DecisionTree => {
                let cm = confusion_matrix(&y, &scores);
                metrics.insert("accuracy".into(), Json::from_f64(cm.accuracy()));
                metrics.insert("roc_auc".into(), Json::from_f64(roc_auc(&y, &scores)));
                metrics.insert("precision".into(), Json::from_f64(cm.precision()));
                metrics.insert("recall".into(), Json::from_f64(cm.recall()));
                metrics.insert("f1".into(), Json::from_f64(cm.f1()));
                let mut counts = BTreeMap::new();
                counts.insert("true_pos".to_string(), Json::Number(cm.true_pos as f64));
                counts.insert("false_pos".to_string(), Json::Number(cm.false_pos as f64));
                counts.insert("true_neg".to_string(), Json::Number(cm.true_neg as f64));
                counts.insert("false_neg".to_string(), Json::Number(cm.false_neg as f64));
                metrics.insert("confusion_matrix".into(), Json::Object(counts));
            }
            LearnerKind::Clustering => {
                let pred_labels: Vec<usize> =
                    predictions.iter().map(|&p| p as usize).collect();
                let true_labels: Vec<usize> = y.iter().map(|&l| l as usize).collect();
                metrics.insert(
                    "ari".into(),
                    Json::from_f64(adjusted_rand_index(&pred_labels, &true_labels)),
                );
                metrics.insert(
                    "silhouette".into(),
                    Json::from_f64(silhouette_score(&x, &pred_labels)),
                );
            }
        }
        for (name, value) in &metrics {
            eprintln!("{name:<16} {}", value.to_string_compact());
        }
    }

    if let Some(out) = args.get("out") {
        let mut doc: BTreeMap<String, Json> = BTreeMap::new();
        doc.insert("schema".into(), Json::String("backbone-predictions/v1".into()));
        doc.insert("learner".into(), Json::String(kind.name().into()));
        doc.insert("model".into(), Json::String(model_path.clone()));
        doc.insert("rows".into(), Json::Number(predictions.len() as f64));
        doc.insert(
            "predictions".into(),
            Json::Array(predictions.iter().map(|&p| Json::from_f64(p)).collect()),
        );
        if kind.is_classifier() {
            doc.insert(
                "scores".into(),
                Json::Array(scores.iter().map(|&s| Json::from_f64(s)).collect()),
            );
        }
        if !metrics.is_empty() {
            doc.insert("metrics".into(), Json::Object(metrics));
        }
        crate::util::atomic_write(&out, &Json::Object(doc).to_string_pretty())
            .with_context(|| format!("writing `{out}`"))?;
        eprintln!("wrote {out}");
    } else {
        for p in &predictions {
            println!("{p}");
        }
    }
    Ok(0)
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

pub fn serve(args: &Args) -> Result<i32> {
    // Repeatable `--model [name=]path`: a bare path names itself
    // `default` (only allowed first); the first registration is the
    // default model for unqualified `/predict`.
    let specs = args.get_all("model");
    if specs.is_empty() {
        bail!("--model is required ([name=]path, repeatable)");
    }
    let mut models: Vec<(String, LoadedModel, &'static str, String)> = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let (name, path) = parse_model_spec(spec, i)?;
        let artifact = ModelArtifact::load(&path)?;
        models.push((name, artifact.model, artifact.learner().name(), path));
    }
    let threads = args.get_usize("threads", 2)?;

    if args.flag("self-test") {
        let base =
            if args.flag("quick") { SelfTestConfig::quick() } else { SelfTestConfig::full() };
        // `--connections` is the PR-7 name; `--concurrency` stays as an
        // alias for pre-PR-7 scripts.
        let connections_default = args.get_usize("concurrency", base.connections)?;
        let cfg = SelfTestConfig {
            requests: args.get_usize("requests", base.requests)?,
            connections: args.get_usize("connections", connections_default)?,
            batch_rows: args.get_usize("batch", base.batch_rows)?,
            threads: match args.get("threads") {
                Some(_) => threads,
                None => base.threads,
            },
            keep_alive: !args.flag("no-keep-alive"),
            compare_close: !args.flag("no-compare"),
            swap_under_load: !args.flag("no-swap"),
            target_rps: args.get_opt_f64("target-rps")?,
            duration_secs: args.get_opt_f64("duration")?,
            slo_p99_ms: args.get_opt_f64("slo-p99-ms")?,
            chaos: args.flag("chaos"),
            chaos_seed: args.get_u64("chaos-seed", 42)?,
        };
        for (key, value) in [
            ("target-rps", cfg.target_rps),
            ("duration", cfg.duration_secs),
            ("slo-p99-ms", cfg.slo_p99_ms),
        ] {
            if let Some(v) = value {
                if !v.is_finite() || v <= 0.0 {
                    bail!("--{key} must be a positive number, got {v}");
                }
            }
        }
        let (_, model, _, _) = models.swap_remove(0);
        let report = run_self_test(model, &cfg)?;
        let ka = &report.keep_alive;
        println!(
            "self-test [{}]: {} requests over {} connection(s), {} failed, \
             {} server thread(s), batch {} rows",
            report.learner,
            ka.requests,
            report.connections,
            report.total_failed(),
            report.threads,
            report.batch_rows
        );
        println!(
            "  keep-alive: {:.0} req/s · {:.0} rows/s · {} socket(s) · \
             p50 {:.2} ms · p99 {:.2} ms",
            ka.req_per_sec, ka.rows_per_sec, ka.connections_opened, ka.p50_ms, ka.p99_ms
        );
        if let Some(close) = &report.close_mode {
            match report.keepalive_speedup {
                Some(speedup) => println!(
                    "  close-mode: {:.0} req/s over {} socket(s) → keep-alive speedup {:.2}x",
                    close.req_per_sec, close.connections_opened, speedup
                ),
                None => println!(
                    "  close-mode: {:.0} req/s over {} socket(s)",
                    close.req_per_sec, close.connections_opened
                ),
            }
        }
        if let Some(swap) = &report.swap {
            println!(
                "  hot swap: status {} · {} old / {} new · {} boundary violation(s)",
                swap.status, swap.served_old, swap.served_new, swap.boundary_violations
            );
        }
        if let Some(slo) = report.slo_p99_ms {
            println!(
                "  slo: p99 {:.2} ms vs {:.2} ms budget → {}",
                ka.p99_ms,
                slo,
                if report.slo_pass() == Some(true) { "pass" } else { "FAIL" }
            );
        }
        if let Some(chaos) = &report.chaos {
            println!(
                "  chaos (seed {}): injected {} panic(s) / {} write failure(s) / \
                 {} drop(s) / {} stall(s) · {} retries · fits {} ok / {} panicked / \
                 {} timed out → {}",
                chaos.seed,
                chaos.injected_worker_panics,
                chaos.injected_write_failures,
                chaos.injected_conn_drops,
                chaos.injected_slow_reads,
                chaos.retries,
                chaos.fit_ok,
                chaos.fit_panics,
                chaos.fit_timeouts,
                if chaos.ok() { "survived" } else { "FAIL" }
            );
            for miss in &chaos.mismatches {
                eprintln!("  chaos mismatch: {miss}");
            }
        }
        if let Some(out) = args.get("out") {
            crate::util::atomic_write(&out, &report.to_json().to_string_pretty())
                .with_context(|| format!("writing `{out}`"))?;
            eprintln!("wrote {out}");
        }
        // CI contract: non-zero exit unless the whole report passes
        // (zero failures, clean swap boundary, SLO when requested).
        return Ok(if report.passed() { 0 } else { 1 });
    }

    let host = args.get("host").unwrap_or_else(|| "127.0.0.1".into());
    let port = args.get_usize("port", 8787)?;
    let addr = format!("{host}:{port}");
    let enable_fit = args.flag("fit");
    let defaults = ServeConfig::default();
    let duration_arg = |key: &str, default: std::time::Duration| -> Result<std::time::Duration> {
        let secs = args.get_f64(key, default.as_secs_f64())?;
        if !secs.is_finite() || secs <= 0.0 {
            bail!("--{key} must be a positive number of seconds, got {secs}");
        }
        Ok(std::time::Duration::from_secs_f64(secs))
    };
    // Optional server-side fit deadline: every `POST /fit` solve runs
    // under min(--fit-timeout, the request's own `deadline_ms`).
    let fit_timeout = match args.get_opt_f64("fit-timeout")? {
        Some(secs) => {
            if !secs.is_finite() || secs <= 0.0 {
                bail!("--fit-timeout must be a positive number of seconds, got {secs}");
            }
            Some(std::time::Duration::from_secs_f64(secs))
        }
        None => None,
    };
    let cfg = ServeConfig::builder()
        .threads(threads)
        .enable_fit(enable_fit)
        .keep_alive(!args.flag("no-keep-alive"))
        .read_timeout(duration_arg("read-timeout", defaults.read_timeout())?)
        .idle_timeout(duration_arg("idle-timeout", defaults.idle_timeout())?)
        .max_connections(args.get_usize("max-connections", defaults.max_connections())?)
        .max_concurrent_fits(args.get_usize("max-fits", defaults.max_concurrent_fits())?)
        .max_inflight_predicts(
            args.get_usize("max-inflight", defaults.max_inflight_predicts())?,
        )
        .registry_capacity(args.get_usize("registry-cap", defaults.registry_capacity())?)
        .warm_cache_path(args.get("warm-cache"))
        .fit_timeout(fit_timeout)
        .build()?;
    let named: Vec<(String, LoadedModel)> =
        models.iter().map(|(name, model, _, _)| (name.clone(), model.clone())).collect();
    let server = Server::bind_registry(&addr, named, &cfg)
        .with_context(|| format!("binding `{addr}`"))?;
    let bound = server.local_addr()?;
    println!(
        "serving {} model(s) on http://{bound} (keep-alive {}, up to {} connections, \
         {} fit thread(s))",
        models.len(),
        if cfg.keep_alive() { "on" } else { "off" },
        cfg.max_connections(),
        crate::backbone::resolved_threads(threads),
    );
    for (name, _, learner, path) in &models {
        println!("  model {name}: {learner} from {path}");
    }
    println!("  POST /predict              {{\"rows\": [[...], ...]}} → default model");
    println!("  POST /models/<id>/predict  same payload, routed by model id");
    println!("  PUT  /models/<id>          artifact JSON or {{\"path\": ...}} → hot swap");
    println!("  GET  /models               registry listing (id, version, source)");
    if enable_fit {
        println!("  POST /fit                  {{\"x\": [[...]], \"y\": [...], \"k\": K}} → model id");
    }
    println!("  GET  /healthz              liveness + default model identity");
    println!("  GET  /stats                backbone-serve-stats/v1 counters + latency");
    if let Some(err) = server.warm_store_error() {
        eprintln!("warning: warm-start store unusable ({err}); /fit starts cold");
    }
    server.run();
    Ok(0)
}
