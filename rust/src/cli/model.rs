//! `save` / `predict` / `serve` subcommands — the persistence + serving
//! half of the CLI.
//!
//! ```text
//! backbone-learn save    --learner sr|lr|dt|cl --out model.json
//!                        [--n N --p P --k K --alpha A --beta B --m M
//!                         --seed S --budget SECS --threads N]
//!                        [--data-out rows.csv] [--labels-out y.csv]
//! backbone-learn predict --model model.json --data rows.csv
//!                        [--labels y.csv] [--out preds.json]
//! backbone-learn serve   --model model.json [--port P] [--host H]
//!                        [--threads N] [--fit] [--warm-cache store.json]
//!                        [--max-fits N]
//! backbone-learn serve   --model model.json --self-test [--quick]
//!                        [--requests N] [--concurrency C] [--batch B]
//!                        [--threads N] [--out report.json]
//! ```
//!
//! `save` fits a learner on generated data (same generators as `fit`)
//! and freezes the fitted state as a `backbone-model/v1` artifact;
//! `predict` runs a saved artifact over CSV rows (reporting regression /
//! classification / clustering metrics when `--labels` is given,
//! including the confusion matrix and ROC AUC for classifiers); `serve`
//! exposes the artifact over HTTP, or — with `--self-test` — drives its
//! own loopback load generator and exits non-zero if any request failed.

use super::Args;
use crate::backbone::Backbone;
use crate::data::{blobs, classification, csv, sparse_regression};
use crate::json::Json;
use crate::linalg::Matrix;
use crate::metrics::{
    adjusted_rand_index, confusion_matrix, mse, r2_score, roc_auc, silhouette_score,
};
use crate::persist::{LearnerKind, LoadedModel, ModelArtifact};
use crate::rng::Rng;
use crate::serve::selftest::{run_self_test, SelfTestConfig};
use crate::serve::{ServeConfig, Server};
use crate::util::Budget;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parse the CLI learner id (`--learner`, falling back to `--problem`
/// for symmetry with `fit`).
fn parse_learner(args: &Args) -> Result<LearnerKind> {
    let id = args
        .get("learner")
        .or_else(|| args.get("problem"))
        .context("--learner is required (sr|lr|dt|cl)")?;
    Ok(match id.as_str() {
        "sr" | "sparse-regression" | "sparse_regression" => LearnerKind::SparseRegression,
        "lr" | "sparse-logistic" | "sparse_logistic" | "logistic" => {
            LearnerKind::SparseLogistic
        }
        "dt" | "decision-tree" | "decision_tree" | "decision-trees" => {
            LearnerKind::DecisionTree
        }
        "cl" | "clustering" => LearnerKind::Clustering,
        other => bail!("unknown learner `{other}` (expected sr|lr|dt|cl)"),
    })
}

// ---------------------------------------------------------------------------
// save
// ---------------------------------------------------------------------------

pub fn save(args: &Args) -> Result<i32> {
    let learner = parse_learner(args)?;
    let out = args.get("out").context("--out is required (artifact path)")?;
    let seed = args.get_u64("seed", 0)?;
    let alpha = args.get_fraction("alpha", 0.5)?;
    let beta = args.get_fraction("beta", 0.5)?;
    let m = args.get_usize("m", 5)?;
    let threads = args.get_usize("threads", 1)?;
    let budget = Budget::seconds(args.get_f64("budget", 60.0)?);
    let mut rng = Rng::seed_from_u64(seed);

    // (X rows, labels) written alongside the artifact on request — the
    // natural companion inputs for `cli predict`.
    let companion: (Matrix, Vec<f64>);

    let artifact = match learner {
        LearnerKind::SparseRegression => {
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 500)?;
            let k = args.get_usize("k", 5)?;
            let data = sparse_regression::generate(
                &sparse_regression::SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
                &mut rng,
            );
            let mut bb = Backbone::sparse_regression()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .max_nonzeros(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_sparse_regression(&bb)?
        }
        LearnerKind::SparseLogistic => {
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 100)?;
            let k = args.get_usize("k", 3)?;
            let data = classification::generate(
                &classification::ClassificationConfig {
                    n,
                    p,
                    k,
                    n_redundant: 0,
                    n_clusters: 2,
                    class_sep: 1.5,
                    flip_y: 0.05,
                },
                &mut rng,
            );
            let mut bb = Backbone::sparse_logistic()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .max_nonzeros(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_sparse_logistic(&bb)?
        }
        LearnerKind::DecisionTree => {
            let n = args.get_usize("n", 300)?;
            let p = args.get_usize("p", 40)?;
            let k = args.get_usize("k", 5)?;
            let data = classification::generate(
                &classification::ClassificationConfig {
                    n,
                    p,
                    k,
                    n_redundant: (p / 10).min(k),
                    n_clusters: 4,
                    class_sep: 1.5,
                    flip_y: 0.05,
                },
                &mut rng,
            );
            let mut bb = Backbone::decision_tree()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .depth(args.get_usize("depth", 2)?)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            companion = (data.x, data.y);
            ModelArtifact::from_decision_tree(&bb)?
        }
        LearnerKind::Clustering => {
            let n = args.get_usize("n", 16)?;
            let p = args.get_usize("p", 2)?;
            let k = args.get_usize("k", 4)?;
            let true_k = (k.saturating_sub(2)).max(2);
            let data = blobs::generate(
                &blobs::BlobsConfig {
                    n,
                    p,
                    true_clusters: true_k,
                    cluster_std: 1.0,
                    center_box: 10.0,
                    min_center_dist: 4.0,
                },
                &mut rng,
            );
            let mut bb = Backbone::clustering()
                .beta(beta)
                .num_subproblems(m)
                .n_clusters(k)
                .threads(threads)
                .seed(seed)
                .build()?;
            bb.fit_with_budget(&data.x, &budget)?;
            let truth: Vec<f64> = data.labels_true.iter().map(|&l| l as f64).collect();
            companion = (data.x, truth);
            ModelArtifact::from_clustering(&bb)?
        }
    };

    artifact.save(&out)?;
    let digest = artifact.provenance.diagnostics.as_ref();
    println!(
        "saved {} artifact → {out} (backbone size {}, {} iterations)",
        artifact.learner().name(),
        digest.map_or(0, |d| d.backbone_size),
        digest.map_or(0, |d| d.iterations),
    );
    if let Some(path) = args.get("data-out") {
        std::fs::write(&path, csv::format_matrix(&companion.0))
            .with_context(|| format!("writing `{path}`"))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.get("labels-out") {
        std::fs::write(&path, csv::format_vector(&companion.1))
            .with_context(|| format!("writing `{path}`"))?;
        eprintln!("wrote {path}");
    }
    Ok(0)
}

// ---------------------------------------------------------------------------
// predict
// ---------------------------------------------------------------------------

pub fn predict(args: &Args) -> Result<i32> {
    let model_path = args.get("model").context("--model is required")?;
    let data_path = args.get("data").context("--data is required (CSV rows)")?;
    let artifact = ModelArtifact::load(&model_path)?;
    let x = csv::read_matrix(&data_path)?;
    let kind = artifact.learner();

    // One inference pass; predictions are the thresholded view of it.
    let scores = artifact.model.predict_scores(&x)?;
    let predictions = artifact.model.predictions_from_scores(&scores);

    let mut metrics: BTreeMap<String, Json> = BTreeMap::new();
    if let Some(labels_path) = args.get("labels") {
        let y = csv::read_vector(&labels_path)?;
        if y.len() != predictions.len() {
            bail!(
                "--labels has {} entries but --data has {} rows",
                y.len(),
                predictions.len()
            );
        }
        match kind {
            LearnerKind::SparseRegression => {
                metrics.insert("r2".into(), Json::from_f64(r2_score(&y, &predictions)));
                metrics.insert("mse".into(), Json::from_f64(mse(&y, &predictions)));
            }
            LearnerKind::SparseLogistic | LearnerKind::DecisionTree => {
                let cm = confusion_matrix(&y, &scores);
                metrics.insert("accuracy".into(), Json::from_f64(cm.accuracy()));
                metrics.insert("roc_auc".into(), Json::from_f64(roc_auc(&y, &scores)));
                metrics.insert("precision".into(), Json::from_f64(cm.precision()));
                metrics.insert("recall".into(), Json::from_f64(cm.recall()));
                metrics.insert("f1".into(), Json::from_f64(cm.f1()));
                let mut counts = BTreeMap::new();
                counts.insert("true_pos".to_string(), Json::Number(cm.true_pos as f64));
                counts.insert("false_pos".to_string(), Json::Number(cm.false_pos as f64));
                counts.insert("true_neg".to_string(), Json::Number(cm.true_neg as f64));
                counts.insert("false_neg".to_string(), Json::Number(cm.false_neg as f64));
                metrics.insert("confusion_matrix".into(), Json::Object(counts));
            }
            LearnerKind::Clustering => {
                let pred_labels: Vec<usize> =
                    predictions.iter().map(|&p| p as usize).collect();
                let true_labels: Vec<usize> = y.iter().map(|&l| l as usize).collect();
                metrics.insert(
                    "ari".into(),
                    Json::from_f64(adjusted_rand_index(&pred_labels, &true_labels)),
                );
                metrics.insert(
                    "silhouette".into(),
                    Json::from_f64(silhouette_score(&x, &pred_labels)),
                );
            }
        }
        for (name, value) in &metrics {
            eprintln!("{name:<16} {}", value.to_string_compact());
        }
    }

    if let Some(out) = args.get("out") {
        let mut doc: BTreeMap<String, Json> = BTreeMap::new();
        doc.insert("schema".into(), Json::String("backbone-predictions/v1".into()));
        doc.insert("learner".into(), Json::String(kind.name().into()));
        doc.insert("model".into(), Json::String(model_path.clone()));
        doc.insert("rows".into(), Json::Number(predictions.len() as f64));
        doc.insert(
            "predictions".into(),
            Json::Array(predictions.iter().map(|&p| Json::from_f64(p)).collect()),
        );
        if kind.is_classifier() {
            doc.insert(
                "scores".into(),
                Json::Array(scores.iter().map(|&s| Json::from_f64(s)).collect()),
            );
        }
        if !metrics.is_empty() {
            doc.insert("metrics".into(), Json::Object(metrics));
        }
        std::fs::write(&out, Json::Object(doc).to_string_pretty())
            .with_context(|| format!("writing `{out}`"))?;
        eprintln!("wrote {out}");
    } else {
        for p in &predictions {
            println!("{p}");
        }
    }
    Ok(0)
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

pub fn serve(args: &Args) -> Result<i32> {
    let model_path = args.get("model").context("--model is required")?;
    let artifact = ModelArtifact::load(&model_path)?;
    let model: LoadedModel = artifact.model.clone();
    let threads = args.get_usize("threads", 2)?;

    if args.flag("self-test") {
        let base = if args.flag("quick") { SelfTestConfig::quick() } else { SelfTestConfig::full() };
        let cfg = SelfTestConfig {
            requests: args.get_usize("requests", base.requests)?,
            concurrency: args.get_usize("concurrency", base.concurrency)?,
            batch_rows: args.get_usize("batch", base.batch_rows)?,
            threads: match args.get("threads") {
                Some(_) => threads,
                None => base.threads,
            },
        };
        let report = run_self_test(model, &cfg)?;
        println!(
            "self-test [{}]: {} requests ({} failed), {} threads, batch {} rows",
            report.learner, report.requests, report.failed, report.threads, report.batch_rows
        );
        println!(
            "  {:.0} req/s · {:.0} rows/s · latency mean {:.2} ms · p50 {:.2} ms · p99 {:.2} ms",
            report.req_per_sec, report.rows_per_sec, report.mean_ms, report.p50_ms, report.p99_ms
        );
        if let Some(out) = args.get("out") {
            std::fs::write(&out, report.to_json().to_string_pretty())
                .with_context(|| format!("writing `{out}`"))?;
            eprintln!("wrote {out}");
        }
        // CI contract: non-zero exit if any request failed. (A zero
        // request count can't happen — run_self_test clamps to ≥ 1.)
        return Ok(if report.failed > 0 { 1 } else { 0 });
    }

    let host = args.get("host").unwrap_or_else(|| "127.0.0.1".into());
    let port = args.get_usize("port", 8787)?;
    let addr = format!("{host}:{port}");
    let enable_fit = args.flag("fit");
    let cfg = ServeConfig {
        threads,
        enable_fit,
        max_concurrent_fits: args.get_usize("max-fits", 1)?,
        warm_cache_path: args.get("warm-cache"),
        ..ServeConfig::default()
    };
    let server = Server::bind(&addr, model, &cfg)
        .with_context(|| format!("binding `{addr}`"))?;
    let bound = server.local_addr()?;
    println!(
        "serving {} model from {model_path} on http://{bound} ({} threads)",
        artifact.learner().name(),
        crate::backbone::resolved_threads(threads)
    );
    println!("  POST /predict   {{\"rows\": [[...], ...]}} → predictions");
    if enable_fit {
        println!("  POST /fit       {{\"x\": [[...]], \"y\": [...], \"k\": K}} → model id + support");
    }
    println!("  GET  /healthz   liveness + model identity");
    println!("  GET  /stats     per-route request counters + latency profile");
    if let Some(err) = server.warm_store_error() {
        eprintln!("warning: warm-start store unusable ({err}); /fit starts cold");
    }
    server.run();
    Ok(0)
}
