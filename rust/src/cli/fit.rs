//! `fit` subcommand: single backbone fit with diagnostics, on generated
//! data (the quickest way to watch the two-phase algorithm work).
//!
//! With `--out FILE`, the run's [`BackboneDiagnostics`] and headline
//! metrics are written as JSON so benchmark tooling can consume
//! per-iteration stats without parsing the log output.
//!
//! With `--warm-cache FILE` (sparse regression only), fits consult a
//! persistent [`WarmStartStore`]: an exact feature match serves the
//! remembered solution without solving, a near neighbor warm-starts the
//! solve with a shrunken screening universe, and every real fit is
//! recorded back into the store. The `--out` document then carries a
//! `warm_start` object plus `fit_secs` so CI can compare cold vs warm.

use super::Args;
use crate::backbone::sparse_regression::SparseRegressionModel;
use crate::backbone::{Backbone, BackboneDiagnostics};
use crate::config::Problem;
use crate::data::{blobs, classification, sparse_regression};
use crate::json::Json;
use crate::metrics::{adjusted_rand_index, auc, r2_score, silhouette_score, support_recovery};
use crate::rng::Rng;
use crate::solvers::SolveStatus;
use crate::util::Budget;
use crate::warmstart::{featurize, suggested_alpha, WarmStartStore, DEFAULT_STORE_CAPACITY};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::time::Instant;

pub fn run(args: &Args) -> Result<i32> {
    let problem =
        Problem::parse(&args.get("problem").context("--problem is required")?)?;
    let seed = args.get_u64("seed", 0)?;
    let alpha = args.get_fraction("alpha", 0.5)?;
    let beta = args.get_fraction("beta", 0.5)?;
    let m = args.get_usize("m", 5)?;
    // Subproblem-batch workers: an explicit `--threads N` overrides any
    // BACKBONE_THREADS default. 1 = the inline sequential schedule,
    // 0 = all available cores, n = exactly n workers. Bit-identical
    // results across values. Absent → the library default applies.
    let threads: Option<usize> = match args.get("threads") {
        Some(_) => Some(args.get_usize("threads", 1)?),
        None => None,
    };
    let budget = Budget::seconds(args.get_f64("budget", 60.0)?);
    // `--trace` records spans through the fit; the nested trace tree
    // lands in the `--out` document under `diagnostics.trace`.
    let trace = args.flag("trace");
    let out = args.get("out");
    let mut rng = Rng::seed_from_u64(seed);

    // Accumulated for `--out`: headline metric name → value.
    let mut metrics: BTreeMap<String, Json> = BTreeMap::new();
    let diagnostics: BackboneDiagnostics;
    // Filled by the sparse-regression branch when `--warm-cache` is in play.
    let mut warm_json: Option<Json> = None;
    let mut fit_secs: Option<f64> = None;

    match problem {
        Problem::SparseRegression => {
            let n = args.get_usize("n", 200)?;
            let p = args.get_usize("p", 1000)?;
            let k = args.get_usize("k", 5)?;
            let data = sparse_regression::generate(
                &sparse_regression::SparseRegressionConfig { n, p, k, rho: 0.1, snr: 5.0 },
                &mut rng,
            );
            // Warm-start cache: consult the store before fitting, record
            // after. A corrupt or missing store degrades to a cold fit.
            let warm_cache = args.get("warm-cache");
            let (mut store, store_error) = match &warm_cache {
                Some(path) => {
                    let (s, e) = WarmStartStore::load_or_empty(path, DEFAULT_STORE_CAPACITY);
                    (Some(s), e)
                }
                None => (None, None),
            };
            if let Some(err) = &store_error {
                eprintln!("warning: warm-start store unusable ({err}); fitting cold");
            }
            let features = store.as_ref().map(|_| featurize(&data.x, &data.y, k));
            let suggestion = match (store.as_mut(), features.as_ref()) {
                (Some(s), Some(f)) => s.suggest(f),
                _ => None,
            };
            let clock = Instant::now();
            let model: SparseRegressionModel;
            let hit: &str;
            let mut distance: Option<f64> = None;
            if let Some(w) = suggestion.as_ref().filter(|w| w.exact && w.beta.len() == p) {
                // Exact feature match: serve the remembered solution — the
                // warm start IS the fit, no solve needed.
                println!("warm start: exact cache hit (no solve)");
                model = SparseRegressionModel {
                    beta: w.beta.clone(),
                    intercept: w.intercept,
                    support: w.support.clone(),
                    objective: w.objective,
                    gap: f64::NAN,
                    status: SolveStatus::Optimal,
                };
                diagnostics = BackboneDiagnostics::default();
                hit = "exact";
                distance = Some(0.0);
            } else {
                // Neighbor hit warm-starts the solve and shrinks the
                // screening universe; otherwise fit cold as before.
                let (fit_alpha, warm_beta) = match &suggestion {
                    Some(w) if w.beta.len() == p => {
                        let a = suggested_alpha(p, k);
                        println!(
                            "warm start: neighbor at distance {:.3e} → α={a:.4}",
                            w.distance
                        );
                        hit = "neighbor";
                        distance = Some(w.distance);
                        (a, Some(w.beta.clone()))
                    }
                    _ => {
                        hit = "none";
                        (alpha, None)
                    }
                };
                let builder = Backbone::sparse_regression()
                    .alpha(fit_alpha)
                    .beta(beta)
                    .num_subproblems(m)
                    .max_nonzeros(k)
                    .seed(seed)
                    .trace(trace);
                let builder = match threads {
                    None => builder,
                    Some(n) => builder.threads(n),
                };
                let builder = match warm_beta {
                    None => builder,
                    Some(wb) => builder.warm_start(wb),
                };
                let mut bb = builder.build()?;
                model = bb.fit_with_budget(&data.x, &data.y, &budget)?.clone();
                diagnostics = bb.last_diagnostics.clone().unwrap();
                if let (Some(s), Some(f), Some(path)) =
                    (store.as_mut(), features.as_ref(), warm_cache.as_ref())
                {
                    let coeffs: Vec<f64> =
                        model.support.iter().map(|&j| model.beta[j]).collect();
                    s.record(f, &model.support, &coeffs, model.intercept, model.objective, fit_alpha);
                    match s.save(path) {
                        Ok(()) => eprintln!("warm-start store: {} entries → {path}", s.len()),
                        Err(e) => eprintln!("warning: could not save warm-start store: {e}"),
                    }
                }
            }
            let elapsed = clock.elapsed().as_secs_f64();
            let r2 = r2_score(&data.y, &model.predict(&data.x));
            let rec = support_recovery(&model.support, &data.support_true);
            print_diag(&Some(diagnostics.clone()));
            println!("support   : {:?}", model.support);
            println!("true supp : {:?}", data.support_true);
            println!("R²        : {r2:.4}");
            println!("support F1: {:.3}", rec.f1);
            println!("exact gap : {:.4} ({:?})", model.gap, model.status);
            println!("objective : {:.6} in {elapsed:.3}s", model.objective);
            metrics.insert("r2".into(), Json::Number(r2));
            metrics.insert("support_f1".into(), Json::Number(rec.f1));
            metrics.insert("gap".into(), Json::Number(model.gap));
            metrics.insert("objective".into(), Json::Number(model.objective));
            fit_secs = Some(elapsed);
            if let Some(store) = &store {
                let mut w = BTreeMap::new();
                w.insert("enabled".into(), Json::Bool(true));
                w.insert("hit".into(), Json::String(hit.into()));
                if let Some(d) = distance {
                    w.insert("distance".into(), Json::Number(d));
                }
                w.insert("store_entries".into(), Json::Number(store.len() as f64));
                if let Some(err) = &store_error {
                    w.insert("store_error".into(), Json::String(err.to_string()));
                }
                warm_json = Some(Json::Object(w));
            }
        }
        Problem::DecisionTrees => {
            let n = args.get_usize("n", 300)?;
            let p = args.get_usize("p", 40)?;
            let k = args.get_usize("k", 5)?;
            let data = classification::generate(
                &classification::ClassificationConfig {
                    n,
                    p,
                    k,
                    n_redundant: (p / 10).min(k),
                    n_clusters: 4,
                    class_sep: 1.5,
                    flip_y: 0.05,
                },
                &mut rng,
            );
            let depth = args.get_usize("depth", 2)?;
            let builder = Backbone::decision_tree()
                .alpha(alpha)
                .beta(beta)
                .num_subproblems(m)
                .depth(depth)
                .seed(seed)
                .trace(trace);
            let builder = match threads {
                None => builder,
                Some(n) => builder.threads(n),
            };
            let mut bb = builder.build()?;
            bb.fit_with_budget(&data.x, &data.y, &budget)?;
            let a = auc(&data.y, &bb.predict_proba(&data.x));
            print_diag(&bb.last_diagnostics);
            let model = bb.model().unwrap();
            println!("features  : {:?}", model.features_used());
            println!("informative: {:?}", data.informative);
            println!("AUC       : {a:.4}");
            println!("errors    : {} ({:?})", model.errors, model.status);
            metrics.insert("auc".into(), Json::Number(a));
            metrics.insert("errors".into(), Json::Number(model.errors as f64));
            diagnostics = bb.last_diagnostics.clone().unwrap();
        }
        Problem::Clustering => {
            let n = args.get_usize("n", 16)?;
            let p = args.get_usize("p", 2)?;
            let k = args.get_usize("k", 4)?;
            let true_k = (k.saturating_sub(2)).max(2);
            let data = blobs::generate(
                &blobs::BlobsConfig {
                    n,
                    p,
                    true_clusters: true_k,
                    cluster_std: 1.0,
                    center_box: 10.0,
                    min_center_dist: 4.0,
                },
                &mut rng,
            );
            let builder = Backbone::clustering()
                .beta(beta)
                .num_subproblems(m)
                .n_clusters(k)
                .seed(seed)
                .trace(trace);
            let builder = match threads {
                None => builder,
                Some(n) => builder.threads(n),
            };
            let mut bb = builder.build()?;
            let model = bb.fit_with_budget(&data.x, &budget)?.clone();
            print_diag(&bb.last_diagnostics);
            let sil = silhouette_score(&data.x, &model.labels);
            let ari = adjusted_rand_index(&model.labels, &data.labels_true);
            println!("silhouette: {sil:.4}");
            println!("ARI vs truth: {ari:.4}");
            println!("objective : {:.3} gap {:.4} ({:?})", model.objective, model.gap, model.status);
            metrics.insert("silhouette".into(), Json::Number(sil));
            metrics.insert("ari".into(), Json::Number(ari));
            diagnostics = bb.last_diagnostics.clone().unwrap();
        }
    }

    if let Some(path) = out {
        let mut doc = BTreeMap::new();
        doc.insert("problem".into(), Json::String(problem.name().into()));
        doc.insert("seed".into(), Json::Number(seed as f64));
        // Requested worker count when --threads was given explicitly; the
        // resolved count actually used is in diagnostics.threads_used.
        if let Some(n) = threads {
            doc.insert("threads".into(), Json::Number(n as f64));
        }
        doc.insert("diagnostics".into(), diagnostics.to_json());
        doc.insert("metrics".into(), Json::Object(metrics));
        if let Some(secs) = fit_secs {
            doc.insert("fit_secs".into(), Json::Number(secs));
        }
        if let Some(w) = warm_json {
            doc.insert("warm_start".into(), w);
        }
        let text = Json::Object(doc).to_string_pretty();
        crate::util::atomic_write(&path, &text).with_context(|| format!("writing `{path}`"))?;
        eprintln!("wrote {path}");
    }
    Ok(0)
}

fn print_diag(diag: &Option<BackboneDiagnostics>) {
    let Some(d) = diag else { return };
    println!("screened universe: {}", d.screened_universe);
    for it in &d.iterations {
        println!(
            "  iter {}: |U|={} M={} |P_m|={} → |B|={} ({:.2}s)",
            it.iteration,
            it.universe_size,
            it.num_subproblems,
            it.subproblem_size,
            it.backbone_size,
            it.elapsed_secs
        );
    }
    println!(
        "backbone: {} (converged={}, truncated={}, budget_exhausted={}, skipped={}) \
         threads {} phase1 {:.2}s phase2 {:.2}s",
        d.backbone_size,
        d.converged,
        d.truncated,
        d.budget_exhausted,
        d.subproblems_skipped,
        d.threads_used,
        d.phase1_secs,
        d.phase2_secs
    );
}
