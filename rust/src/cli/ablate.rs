//! `ablate` subcommand: the hyperparameter-sensitivity sweeps behind the
//! paper's prose claims (§3): sparse regression prefers large (α, β);
//! decision trees prefer small subproblems; clustering is insensitive.

use super::Args;
use crate::bench_support::{render_table, run_block};
use crate::config::{BackboneCell, ExperimentConfig, Problem};
use anyhow::{Context, Result};

pub fn run(args: &Args) -> Result<i32> {
    let sweep = args.get("sweep").unwrap_or_else(|| "alpha-beta".into());
    let block = args.get("block").unwrap_or_else(|| "sr".into());
    let problem = Problem::parse(&block)?;
    let mut cfg = if args.flag("full") {
        ExperimentConfig::paper_defaults(problem)
    } else {
        ExperimentConfig::quick_defaults(problem)
    };
    cfg.n = args.get_usize("n", cfg.n)?;
    cfg.p = args.get_usize("p", cfg.p)?;
    cfg.k = args.get_usize("k", cfg.k)?;
    cfg.repetitions = args.get_usize("reps", cfg.repetitions)?;
    cfg.budget_secs = args.get_f64("budget", cfg.budget_secs)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    // 0 = all available cores; 1 (default) = sequential schedule.
    cfg.threads = args.get_usize("threads", cfg.threads)?;

    cfg.grid = match sweep.as_str() {
        "alpha-beta" => {
            // α × β product grid at fixed M.
            let mut grid = Vec::new();
            for &alpha in &[0.1, 0.3, 0.5, 0.9] {
                for &beta in &[0.3, 0.5, 0.9] {
                    grid.push(BackboneCell { m: 5, alpha, beta });
                }
            }
            grid
        }
        "num-subproblems" => [1usize, 2, 5, 10, 20]
            .iter()
            .map(|&m| BackboneCell { m, alpha: 0.5, beta: 0.5 })
            .collect(),
        "screen" => [1.0, 0.5, 0.25, 0.1]
            .iter()
            .map(|&alpha| BackboneCell { m: 5, alpha, beta: 0.5 })
            .collect(),
        other => anyhow::bail!("unknown sweep `{other}`"),
    };
    if problem == Problem::Clustering {
        // Clustering has no screen; sweep β/M only.
        for cell in cfg.grid.iter_mut() {
            cell.alpha = 1.0;
        }
        cfg.grid.dedup_by(|a, b| a.m == b.m && a.beta == b.beta);
    }
    for (i, cell) in cfg.grid.iter().enumerate() {
        cell.validate().with_context(|| format!("sweep cell {i}"))?;
    }

    eprintln!(
        "ablation `{sweep}` on {}: n={} p={} k={} reps={} ({} cells)",
        problem.name(),
        cfg.n,
        cfg.p,
        cfg.k,
        cfg.repetitions,
        cfg.grid.len()
    );
    let rows = run_block(&cfg)?;
    let title = format!("ablation `{}` — {}", sweep, problem.name());
    print!("{}", render_table(&title, &rows));
    Ok(0)
}
