//! Tiny `--key value` / `--flag` argument parser.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed arguments: `--key value` pairs and bare `--flag`s. Repeating a
/// key keeps every occurrence in order ([`Args::get_all`]); the
/// single-value getters see the last one (last-wins overrides).
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    /// Every `--key value` in command-line order (repeats preserved).
    pairs: Vec<(String, String)>,
    flags: Vec<String>,
}

/// Option keys that are boolean flags (never consume a value).
const FLAG_KEYS: &[&str] = &[
    "chaos",
    "fit",
    "full",
    "help",
    "quiet",
    "native-only",
    "no-compare",
    "no-keep-alive",
    "no-swap",
    "quick",
    "schema-only",
    "self-test",
    "trace",
    "warm",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument `{tok}`");
            };
            if let Some((k, v)) = key.split_once('=') {
                out.values.insert(k.to_string(), v.to_string());
                out.pairs.push((k.to_string(), v.to_string()));
            } else if FLAG_KEYS.contains(&key) {
                out.flags.push(key.to_string());
            } else {
                let v = argv
                    .get(i + 1)
                    .with_context(|| format!("missing value for --{key}"))?;
                out.values.insert(key.to_string(), v.clone());
                out.pairs.push((key.to_string(), v.clone()));
                i += 1;
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.values.get(key).cloned()
    }

    /// Every value given for `key`, in command-line order. Repeatable
    /// options (`serve --model a=x.json --model b=y.json`) read this.
    pub fn get_all(&self, key: &str) -> Vec<String> {
        self.pairs.iter().filter(|(k, _)| k == key).map(|(_, v)| v.clone()).collect()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a number")),
            None => Ok(default),
        }
    }

    /// Float option with no default — `None` when absent (for knobs whose
    /// presence changes behaviour, like `serve --self-test --target-rps`).
    pub fn get_opt_f64(&self, key: &str) -> Result<Option<f64>> {
        self.values
            .get(key)
            .map(|v| v.parse().with_context(|| format!("--{key} must be a number")))
            .transpose()
    }

    /// Float option constrained to the half-open interval `(lo, hi]` — the
    /// range the backbone fractions (α, β) live in. Reports a CLI-level
    /// error before any estimator is built.
    pub fn get_fraction(&self, key: &str, default: f64) -> Result<f64> {
        let v = self.get_f64(key, default)?;
        if !(v > 0.0 && v <= 1.0) {
            bail!("--{key} must be in (0, 1], got {v}");
        }
        Ok(v)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.values.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_pairs_flags_and_equals() {
        let a = Args::parse(&sv(&["--block", "sr", "--full", "--n=50"])).unwrap();
        assert_eq!(a.get("block").as_deref(), Some("sr"));
        assert!(a.flag("full"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 50);
    }

    #[test]
    fn repeated_keys_keep_order_and_last_wins_for_get() {
        let a = Args::parse(&sv(&["--model", "a=x.json", "--model=b=y.json", "--n", "3"]))
            .unwrap();
        assert_eq!(a.get_all("model"), vec!["a=x.json".to_string(), "b=y.json".into()]);
        assert_eq!(a.get("model").as_deref(), Some("b=y.json"), "get() is last-wins");
        assert!(a.get_all("missing").is_empty());
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse(&sv(&["--alpha", "0.25"])).unwrap();
        assert_eq!(a.get_f64("alpha", 1.0).unwrap(), 0.25);
        assert_eq!(a.get_f64("beta", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_u64("seed", 7).unwrap(), 7);
    }

    #[test]
    fn fraction_getter_enforces_unit_interval() {
        let a = Args::parse(&sv(&["--alpha", "0.25"])).unwrap();
        assert_eq!(a.get_fraction("alpha", 1.0).unwrap(), 0.25);
        assert_eq!(a.get_fraction("beta", 0.5).unwrap(), 0.5);
        let bad = Args::parse(&sv(&["--alpha", "1.5"])).unwrap();
        assert!(bad.get_fraction("alpha", 1.0).is_err());
        let zero = Args::parse(&sv(&["--beta", "0"])).unwrap();
        assert!(zero.get_fraction("beta", 0.5).is_err());
    }

    #[test]
    fn rejects_positional_and_dangling() {
        assert!(Args::parse(&sv(&["oops"])).is_err());
        assert!(Args::parse(&sv(&["--n"])).is_err());
        let bad = Args::parse(&sv(&["--n", "x"])).unwrap();
        assert!(bad.get_usize("n", 0).is_err());
    }
}
