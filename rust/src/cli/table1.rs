//! `table1` subcommand: regenerate Table 1 (one block or all).

use super::Args;
use crate::bench_support::{render_table, run_block};
use crate::config::{ExperimentConfig, Problem};
use anyhow::{Context, Result};

pub fn run(args: &Args) -> Result<i32> {
    let block = args.get("block").unwrap_or_else(|| "all".into());
    let problems: Vec<Problem> = match block.as_str() {
        "all" => vec![Problem::SparseRegression, Problem::DecisionTrees, Problem::Clustering],
        other => vec![Problem::parse(other)?],
    };

    let mut output = String::new();
    for problem in problems {
        let mut cfg = match args.get("config") {
            Some(path) => {
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading config `{path}`"))?;
                ExperimentConfig::from_json(&text)?
            }
            None if args.flag("full") => ExperimentConfig::paper_defaults(problem),
            None => ExperimentConfig::quick_defaults(problem),
        };
        // CLI overrides.
        cfg.n = args.get_usize("n", cfg.n)?;
        cfg.p = args.get_usize("p", cfg.p)?;
        cfg.k = args.get_usize("k", cfg.k)?;
        cfg.repetitions = args.get_usize("reps", cfg.repetitions)?;
        cfg.budget_secs = args.get_f64("budget", cfg.budget_secs)?;
        cfg.seed = args.get_u64("seed", cfg.seed)?;
        // 0 = all available cores; 1 (default) = sequential schedule.
        cfg.threads = args.get_usize("threads", cfg.threads)?;
        // Config-file backend applies unless the global --backend flag
        // already pinned one in `cli::run` (CLI wins over config).
        if args.get("backend").is_none() {
            crate::linalg::set_backend(cfg.backend);
        }
        // Fail fast on bad grids (typed BackboneError) instead of
        // aborting mid-sweep after hours of compute.
        for (i, cell) in cfg.grid.iter().enumerate() {
            cell.validate().with_context(|| format!("grid cell {i}"))?;
        }

        if !args.flag("quiet") {
            eprintln!(
                "running {} block: n={} p={} k={} reps={} budget={}s ...",
                problem.name(),
                cfg.n,
                cfg.p,
                cfg.k,
                cfg.repetitions,
                cfg.budget_secs
            );
        }
        let rows = run_block(&cfg)?;
        let title = format!(
            "{} (n, p, k) = ({}, {}, {})",
            problem.name(),
            cfg.n,
            cfg.p,
            cfg.k
        );
        output.push_str(&render_table(&title, &rows));
        output.push('\n');
    }

    match args.get("out") {
        Some(path) => {
            std::fs::write(&path, &output).with_context(|| format!("writing `{path}`"))?;
            eprintln!("wrote {path}");
        }
        None => print!("{output}"),
    }
    Ok(0)
}
