//! CLI launcher (placeholder; replaced by cli module wiring).
fn main() {
    backbone_learn::cli::main();
}
