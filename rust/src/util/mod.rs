//! Small shared utilities: wall-clock budgets, timing, index sets, and
//! crash-safe artifact I/O (atomic writes + content checksums).

use crate::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A thread-safe wall-clock budget shared by long-running solvers.
///
/// Exact MIO solvers (L0BnB, MILP branch-and-bound, exact trees) honour the
/// paper's one-hour cap through this type: they poll `expired()` at node
/// boundaries and return their incumbent with a `TimedOut` status, exactly
/// like the `ODTLearn`/`Exact` rows of Table 1 that report 3600 s.
///
/// The budget is a fixed deadline (`Instant` + optional `Duration`) plus a
/// latched exhausted flag: once any observer — including a worker on
/// another thread of the parallel subproblem scheduler — sees the deadline
/// pass, every clone of this budget reports `expired()` from then on via a
/// single relaxed atomic load. `&Budget` is `Send + Sync`, so the batch
/// scheduler hands the same budget to all workers and they short-circuit
/// mid-batch exactly as the sequential path does.
#[derive(Debug, Clone)]
pub struct Budget {
    start: Instant,
    limit: Option<Duration>,
    /// Latched once the deadline is observed as passed; `Arc` so clones
    /// (and the threads borrowing them) agree instantly.
    exhausted: Arc<AtomicBool>,
}

impl Budget {
    /// Unlimited budget.
    pub fn unlimited() -> Self {
        Self { start: Instant::now(), limit: None, exhausted: Arc::new(AtomicBool::new(false)) }
    }

    /// Budget of `secs` seconds starting now.
    pub fn seconds(secs: f64) -> Self {
        Self {
            start: Instant::now(),
            limit: Some(Duration::from_secs_f64(secs)),
            exhausted: Arc::new(AtomicBool::new(false)),
        }
    }

    /// True once the budget is exhausted. Monotone: after the first `true`
    /// every subsequent call (on any clone, from any thread) is `true`.
    #[inline]
    pub fn expired(&self) -> bool {
        if self.exhausted.load(Ordering::Relaxed) {
            return true;
        }
        match self.limit {
            Some(l) if self.start.elapsed() >= l => {
                self.exhausted.store(true, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// Elapsed wall-clock seconds since creation.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Remaining seconds (`f64::INFINITY` if unlimited).
    pub fn remaining_secs(&self) -> f64 {
        match self.limit {
            Some(l) => (l.saturating_sub(self.start.elapsed())).as_secs_f64(),
            None => f64::INFINITY,
        }
    }

    /// A child budget capped at `secs` but never exceeding the parent.
    pub fn child(&self, secs: f64) -> Budget {
        Budget::seconds(secs.min(self.remaining_secs()))
    }
}

/// Simple stopwatch for benchmark rows.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Sorted, deduplicated index set (the representation of backbone sets and
/// indicator universes). Thin wrapper over `Vec<usize>` with set algebra.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct IndexSet {
    items: Vec<usize>,
}

impl IndexSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_vec(mut v: Vec<usize>) -> Self {
        v.sort_unstable();
        v.dedup();
        Self { items: v }
    }

    pub fn from_range(n: usize) -> Self {
        Self { items: (0..n).collect() }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, x: usize) -> bool {
        self.items.binary_search(&x).is_ok()
    }

    pub fn insert(&mut self, x: usize) {
        if let Err(pos) = self.items.binary_search(&x) {
            self.items.insert(pos, x);
        }
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.items
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.items.iter().copied()
    }

    pub fn union(&self, other: &IndexSet) -> IndexSet {
        let mut v = self.items.clone();
        v.extend_from_slice(&other.items);
        IndexSet::from_vec(v)
    }

    pub fn union_with(&mut self, xs: &[usize]) {
        self.items.extend_from_slice(xs);
        self.items.sort_unstable();
        self.items.dedup();
    }

    pub fn intersect(&self, other: &IndexSet) -> IndexSet {
        IndexSet {
            items: self.items.iter().copied().filter(|&x| other.contains(x)).collect(),
        }
    }

    pub fn is_subset_of(&self, other: &IndexSet) -> bool {
        self.items.iter().all(|&x| other.contains(x))
    }

    pub fn into_vec(self) -> Vec<usize> {
        self.items
    }
}

impl FromIterator<usize> for IndexSet {
    fn from_iter<T: IntoIterator<Item = usize>>(iter: T) -> Self {
        IndexSet::from_vec(iter.into_iter().collect())
    }
}

/// FNV-1a 64-bit hash — the content checksum of persisted artifacts.
/// Dependency-free and stable across platforms/versions, which is what a
/// wire-format checksum needs (cryptographic strength is not the goal:
/// this detects truncation and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write `contents` to `path` atomically: temp file in the target's
/// directory → flush → `sync_all` → rename over the target. A crash at
/// any point leaves either the old file or the new file, never a
/// truncated hybrid. The temp file is removed on any failure.
///
/// Under the `fault-inject` feature an installed [`crate::fault`] plan
/// can force this call to fail (before anything touches the filesystem),
/// which is how the chaos harness proves callers survive write failures.
pub fn atomic_write(path: &str, contents: &str) -> std::io::Result<()> {
    let watch = Stopwatch::start();
    let result = atomic_write_inner(path, contents);
    crate::obs::record_persist_write(watch.elapsed_secs(), result.is_ok());
    result
}

fn atomic_write_inner(path: &str, contents: &str) -> std::io::Result<()> {
    if crate::fault::fire(crate::fault::FaultPoint::WriteFail) {
        return Err(std::io::Error::other("injected write failure (fault-inject)"));
    }
    let target = Path::new(path);
    let dir: PathBuf = match target.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let name = target
        .file_name()
        .ok_or_else(|| std::io::Error::other(format!("`{path}` has no file name")))?
        .to_string_lossy()
        .into_owned();
    // Unique within the process (pid guards against a concurrent sibling
    // process writing the same target).
    static TEMP_COUNTER: AtomicU64 = AtomicU64::new(0);
    let tmp = dir.join(format!(
        ".{name}.tmp.{}.{}",
        std::process::id(),
        TEMP_COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let write_result = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()
    })();
    if let Err(e) = write_result {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, target) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    Ok(())
}

/// Top-level key carrying the embedded artifact checksum.
pub const CHECKSUM_KEY: &str = "checksum";

/// Checksum of a JSON document in the embedded wire format
/// (`fnv1a64:<16 hex digits>`), computed over the canonical pretty
/// serialization with the `checksum` key itself removed — so embedding
/// the checksum does not change the bytes it covers.
pub fn json_checksum(doc: &Json) -> String {
    let text = match doc {
        Json::Object(m) if m.contains_key(CHECKSUM_KEY) => {
            let mut stripped = m.clone();
            stripped.remove(CHECKSUM_KEY);
            Json::Object(stripped).to_string_pretty()
        }
        _ => doc.to_string_pretty(),
    };
    format!("fnv1a64:{:016x}", fnv1a64(text.as_bytes()))
}

/// Insert (or refresh) the embedded checksum of a JSON object document.
/// Non-object documents are left untouched.
pub fn embed_checksum(doc: &mut Json) {
    let sum = json_checksum(doc);
    if let Json::Object(m) = doc {
        m.insert(CHECKSUM_KEY.into(), Json::String(sum));
    }
}

/// Result of checking a document against its embedded checksum.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChecksumState {
    /// No `checksum` key — a pre-checksum artifact; loads as before.
    Absent,
    /// Embedded checksum matches the content.
    Valid,
    /// Embedded checksum does not match: the file is corrupt (or was
    /// edited without refreshing the checksum).
    Mismatch { stored: String, computed: String },
}

/// Verify a document against its embedded checksum (if any).
pub fn verify_checksum(doc: &Json) -> ChecksumState {
    let Some(stored) = doc.get(CHECKSUM_KEY).and_then(Json::as_str) else {
        return ChecksumState::Absent;
    };
    let computed = json_checksum(doc);
    if stored == computed {
        ChecksumState::Valid
    } else {
        crate::obs::record_checksum_failure();
        ChecksumState::Mismatch { stored: stored.to_string(), computed }
    }
}

/// Format seconds the way Table 1 does (integer seconds, `3600` for a
/// timeout at the one-hour cap).
pub fn format_secs(secs: f64) -> String {
    if secs >= 100.0 {
        format!("{:.0}", secs)
    } else if secs >= 1.0 {
        format!("{:.1}", secs)
    } else {
        format!("{:.3}", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_unlimited_never_expires() {
        let b = Budget::unlimited();
        assert!(!b.expired());
        assert_eq!(b.remaining_secs(), f64::INFINITY);
    }

    #[test]
    fn budget_zero_expires_immediately() {
        let b = Budget::seconds(0.0);
        assert!(b.expired());
    }

    #[test]
    fn budget_is_send_sync_and_latches_across_clones() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Budget>();
        let a = Budget::seconds(0.0);
        let b = a.clone();
        // Observing expiry on one clone latches the shared flag; the other
        // clone sees it without re-reading the clock.
        assert!(a.expired());
        assert!(b.expired());
    }

    #[test]
    fn budget_expired_is_visible_from_other_threads() {
        let budget = Budget::seconds(0.0);
        let seen = std::thread::scope(|s| {
            s.spawn(|| budget.expired()).join().unwrap()
        });
        assert!(seen);
        assert!(budget.expired());
    }

    #[test]
    fn budget_child_capped_by_parent() {
        let parent = Budget::seconds(0.05);
        let child = parent.child(100.0);
        assert!(child.remaining_secs() <= 0.05 + 1e-6);
    }

    #[test]
    fn index_set_algebra() {
        let a = IndexSet::from_vec(vec![3, 1, 2, 3]);
        assert_eq!(a.as_slice(), &[1, 2, 3]);
        let b = IndexSet::from_vec(vec![2, 4]);
        assert_eq!(a.union(&b).as_slice(), &[1, 2, 3, 4]);
        assert_eq!(a.intersect(&b).as_slice(), &[2]);
        assert!(IndexSet::from_vec(vec![1, 3]).is_subset_of(&a));
        assert!(!IndexSet::from_vec(vec![1, 5]).is_subset_of(&a));
    }

    #[test]
    fn index_set_insert_keeps_sorted_unique() {
        let mut s = IndexSet::new();
        for x in [5, 1, 3, 1, 5] {
            s.insert(x);
        }
        assert_eq!(s.as_slice(), &[1, 3, 5]);
        assert!(s.contains(3));
        assert!(!s.contains(2));
    }

    #[test]
    fn format_secs_bands() {
        assert_eq!(format_secs(3600.0), "3600");
        assert_eq!(format_secs(34.26), "34.3");
        assert_eq!(format_secs(0.1234), "0.123");
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn atomic_write_replaces_and_cleans_up() {
        let path = std::env::temp_dir()
            .join(format!("backbone_util_atomic_{}.txt", std::process::id()));
        let path = path.to_string_lossy().into_owned();
        atomic_write(&path, "first").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "first");
        atomic_write(&path, "second").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "second");
        // No temp droppings next to the target.
        let dir = std::path::Path::new(&path).parent().unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains("backbone_util_atomic") && n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn atomic_write_failure_preserves_the_old_file() {
        let dir = std::env::temp_dir()
            .join(format!("backbone_util_nodir_{}", std::process::id()));
        let path = dir.join("x.json").to_string_lossy().into_owned();
        // Parent directory does not exist → the temp-file create fails and
        // nothing is left behind (and an existing target would survive).
        assert!(atomic_write(&path, "data").is_err());
        assert!(!std::path::Path::new(&path).exists());
    }

    #[test]
    fn checksum_embed_verify_roundtrip_and_mismatch() {
        let mut doc = Json::parse(r#"{"a": 1, "b": [1.5, 2.5]}"#).unwrap();
        assert_eq!(verify_checksum(&doc), ChecksumState::Absent);
        embed_checksum(&mut doc);
        assert_eq!(verify_checksum(&doc), ChecksumState::Valid);
        // Embedding twice is idempotent (checksum covers content only).
        let once = doc.to_string_pretty();
        embed_checksum(&mut doc);
        assert_eq!(doc.to_string_pretty(), once);
        // Tamper with the content → mismatch.
        let tampered = once.replace("1.5", "1.6");
        let bad = Json::parse(&tampered).unwrap();
        assert!(matches!(verify_checksum(&bad), ChecksumState::Mismatch { .. }));
    }
}
