//! Small shared utilities: wall-clock budgets, timing, and index sets.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A thread-safe wall-clock budget shared by long-running solvers.
///
/// Exact MIO solvers (L0BnB, MILP branch-and-bound, exact trees) honour the
/// paper's one-hour cap through this type: they poll `expired()` at node
/// boundaries and return their incumbent with a `TimedOut` status, exactly
/// like the `ODTLearn`/`Exact` rows of Table 1 that report 3600 s.
///
/// The budget is a fixed deadline (`Instant` + optional `Duration`) plus a
/// latched exhausted flag: once any observer — including a worker on
/// another thread of the parallel subproblem scheduler — sees the deadline
/// pass, every clone of this budget reports `expired()` from then on via a
/// single relaxed atomic load. `&Budget` is `Send + Sync`, so the batch
/// scheduler hands the same budget to all workers and they short-circuit
/// mid-batch exactly as the sequential path does.
#[derive(Debug, Clone)]
pub struct Budget {
    start: Instant,
    limit: Option<Duration>,
    /// Latched once the deadline is observed as passed; `Arc` so clones
    /// (and the threads borrowing them) agree instantly.
    exhausted: Arc<AtomicBool>,
}

impl Budget {
    /// Unlimited budget.
    pub fn unlimited() -> Self {
        Self { start: Instant::now(), limit: None, exhausted: Arc::new(AtomicBool::new(false)) }
    }

    /// Budget of `secs` seconds starting now.
    pub fn seconds(secs: f64) -> Self {
        Self {
            start: Instant::now(),
            limit: Some(Duration::from_secs_f64(secs)),
            exhausted: Arc::new(AtomicBool::new(false)),
        }
    }

    /// True once the budget is exhausted. Monotone: after the first `true`
    /// every subsequent call (on any clone, from any thread) is `true`.
    #[inline]
    pub fn expired(&self) -> bool {
        if self.exhausted.load(Ordering::Relaxed) {
            return true;
        }
        match self.limit {
            Some(l) if self.start.elapsed() >= l => {
                self.exhausted.store(true, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// Elapsed wall-clock seconds since creation.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Remaining seconds (`f64::INFINITY` if unlimited).
    pub fn remaining_secs(&self) -> f64 {
        match self.limit {
            Some(l) => (l.saturating_sub(self.start.elapsed())).as_secs_f64(),
            None => f64::INFINITY,
        }
    }

    /// A child budget capped at `secs` but never exceeding the parent.
    pub fn child(&self, secs: f64) -> Budget {
        Budget::seconds(secs.min(self.remaining_secs()))
    }
}

/// Simple stopwatch for benchmark rows.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Sorted, deduplicated index set (the representation of backbone sets and
/// indicator universes). Thin wrapper over `Vec<usize>` with set algebra.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct IndexSet {
    items: Vec<usize>,
}

impl IndexSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_vec(mut v: Vec<usize>) -> Self {
        v.sort_unstable();
        v.dedup();
        Self { items: v }
    }

    pub fn from_range(n: usize) -> Self {
        Self { items: (0..n).collect() }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn contains(&self, x: usize) -> bool {
        self.items.binary_search(&x).is_ok()
    }

    pub fn insert(&mut self, x: usize) {
        if let Err(pos) = self.items.binary_search(&x) {
            self.items.insert(pos, x);
        }
    }

    pub fn as_slice(&self) -> &[usize] {
        &self.items
    }

    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.items.iter().copied()
    }

    pub fn union(&self, other: &IndexSet) -> IndexSet {
        let mut v = self.items.clone();
        v.extend_from_slice(&other.items);
        IndexSet::from_vec(v)
    }

    pub fn union_with(&mut self, xs: &[usize]) {
        self.items.extend_from_slice(xs);
        self.items.sort_unstable();
        self.items.dedup();
    }

    pub fn intersect(&self, other: &IndexSet) -> IndexSet {
        IndexSet {
            items: self.items.iter().copied().filter(|&x| other.contains(x)).collect(),
        }
    }

    pub fn is_subset_of(&self, other: &IndexSet) -> bool {
        self.items.iter().all(|&x| other.contains(x))
    }

    pub fn into_vec(self) -> Vec<usize> {
        self.items
    }
}

impl FromIterator<usize> for IndexSet {
    fn from_iter<T: IntoIterator<Item = usize>>(iter: T) -> Self {
        IndexSet::from_vec(iter.into_iter().collect())
    }
}

/// Format seconds the way Table 1 does (integer seconds, `3600` for a
/// timeout at the one-hour cap).
pub fn format_secs(secs: f64) -> String {
    if secs >= 100.0 {
        format!("{:.0}", secs)
    } else if secs >= 1.0 {
        format!("{:.1}", secs)
    } else {
        format!("{:.3}", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_unlimited_never_expires() {
        let b = Budget::unlimited();
        assert!(!b.expired());
        assert_eq!(b.remaining_secs(), f64::INFINITY);
    }

    #[test]
    fn budget_zero_expires_immediately() {
        let b = Budget::seconds(0.0);
        assert!(b.expired());
    }

    #[test]
    fn budget_is_send_sync_and_latches_across_clones() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Budget>();
        let a = Budget::seconds(0.0);
        let b = a.clone();
        // Observing expiry on one clone latches the shared flag; the other
        // clone sees it without re-reading the clock.
        assert!(a.expired());
        assert!(b.expired());
    }

    #[test]
    fn budget_expired_is_visible_from_other_threads() {
        let budget = Budget::seconds(0.0);
        let seen = std::thread::scope(|s| {
            s.spawn(|| budget.expired()).join().unwrap()
        });
        assert!(seen);
        assert!(budget.expired());
    }

    #[test]
    fn budget_child_capped_by_parent() {
        let parent = Budget::seconds(0.05);
        let child = parent.child(100.0);
        assert!(child.remaining_secs() <= 0.05 + 1e-6);
    }

    #[test]
    fn index_set_algebra() {
        let a = IndexSet::from_vec(vec![3, 1, 2, 3]);
        assert_eq!(a.as_slice(), &[1, 2, 3]);
        let b = IndexSet::from_vec(vec![2, 4]);
        assert_eq!(a.union(&b).as_slice(), &[1, 2, 3, 4]);
        assert_eq!(a.intersect(&b).as_slice(), &[2]);
        assert!(IndexSet::from_vec(vec![1, 3]).is_subset_of(&a));
        assert!(!IndexSet::from_vec(vec![1, 5]).is_subset_of(&a));
    }

    #[test]
    fn index_set_insert_keeps_sorted_unique() {
        let mut s = IndexSet::new();
        for x in [5, 1, 3, 1, 5] {
            s.insert(x);
        }
        assert_eq!(s.as_slice(), &[1, 3, 5]);
        assert!(s.contains(3));
        assert!(!s.contains(2));
    }

    #[test]
    fn format_secs_bands() {
        assert_eq!(format_secs(3600.0), "3600");
        assert_eq!(format_secs(34.26), "34.3");
        assert_eq!(format_secs(0.1234), "0.123");
    }
}
