//! Table-1 harness: runs every method of each experiment block, averages
//! over repetitions, and renders rows in the paper's format.
//!
//! Used by the `table1_*` benches, the `backbone-learn table1` CLI
//! subcommand, and the end-to-end example. Method selection mirrors §3:
//!
//! - **Sparse regression** — GLMNet (CD elastic-net path, λ chosen on a
//!   validation split), L0BnB (cardinality path k = 1..k_max, exact, under
//!   budget), BbLearn (backbone + exact reduced solve) over the (α, β, M)
//!   grid. Accuracy = out-of-sample R².
//! - **Decision trees** — CART (depth cross-validated on a validation
//!   split), ODTLearn-style exact tree (binarized, depth-limited, under
//!   budget), BbLearn grid. Accuracy = out-of-sample AUC.
//! - **Clustering** — KMeans, exact clique partitioning (under budget),
//!   BbLearn grid. Accuracy = silhouette score (in-sample, as in the
//!   paper).

use crate::backbone::Backbone;
use crate::config::{BackboneCell, ExperimentConfig, Problem};
use crate::data::{binarize, blobs, classification, sparse_regression, train_test_split};
use crate::json::Json;
use crate::linalg::Matrix;
use crate::metrics::{auc, r2_score, silhouette_score};
use crate::rng::Rng;
use crate::solvers::cart::{cart_fit, CartConfig};
use crate::solvers::cd::{elastic_net_path, ElasticNetConfig};
use crate::solvers::clique::{clique_solve, CliqueConfig};
use crate::solvers::exact_tree::{exact_tree_solve, ExactTreeConfig};
use crate::solvers::kmeans::{kmeans_fit, KMeansConfig};
use crate::solvers::l0bnb::{l0bnb_solve, L0BnbConfig};
use crate::runtime::Backend;
use crate::util::{format_secs, Budget, Stopwatch};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

thread_local! {
    static BACKEND: std::cell::RefCell<Option<Backend>> = const { std::cell::RefCell::new(None) };
}

/// Process-wide backend for BbLearn runs: PJRT if `artifacts/` is usable,
/// native otherwise (override with BACKBONE_NATIVE_ONLY=1).
pub fn default_backend() -> Backend {
    BACKEND.with(|b| {
        let mut b = b.borrow_mut();
        if b.is_none() {
            let native_only = std::env::var("BACKBONE_NATIVE_ONLY").is_ok();
            let backend = if native_only {
                Backend::Native
            } else {
                Backend::pjrt_from_dir("artifacts").unwrap_or(Backend::Native)
            };
            if backend.is_pjrt() {
                eprintln!("[bench] PJRT backend loaded from artifacts/");
            }
            *b = Some(backend);
        }
        b.clone().unwrap()
    })
}

/// One rendered row of Table 1 (averaged over repetitions).
#[derive(Debug, Clone)]
pub struct TableRow {
    pub method: String,
    pub m: Option<usize>,
    pub alpha: Option<f64>,
    pub beta: Option<f64>,
    pub accuracy: f64,
    pub time_secs: f64,
    pub backbone_size: Option<f64>,
}

impl TableRow {
    fn fmt_opt_usize(v: Option<usize>) -> String {
        v.map_or_else(|| "—".into(), |x| x.to_string())
    }

    fn fmt_opt_f64(v: Option<f64>) -> String {
        v.map_or_else(|| "—".into(), |x| format!("{x:.1}"))
    }
}

/// Render rows as a text table in the paper's column order.
pub fn render_table(title: &str, rows: &[TableRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("## {title}\n"));
    out.push_str(&format!(
        "{:<12} {:>4} {:>5} {:>5} {:>9} {:>11} {:>14}\n",
        "Method", "M", "a", "b", "Accuracy", "Time (sec)", "Backbone Size"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<12} {:>4} {:>5} {:>5} {:>9.3} {:>11} {:>14}\n",
            r.method,
            TableRow::fmt_opt_usize(r.m),
            TableRow::fmt_opt_f64(r.alpha),
            TableRow::fmt_opt_f64(r.beta),
            r.accuracy,
            format_secs(r.time_secs),
            r.backbone_size
                .map_or_else(|| "—".into(), |b| format!("{b:.0}")),
        ));
    }
    out
}

fn mean(xs: &[f64]) -> f64 {
    crate::linalg::mean(xs)
}

// ---------------------------------------------------------------------------
// Sparse regression block
// ---------------------------------------------------------------------------

/// Accumulator for one method across repetitions.
#[derive(Default, Clone)]
struct Acc {
    accuracy: Vec<f64>,
    time: Vec<f64>,
    backbone: Vec<f64>,
}

impl Acc {
    fn push(&mut self, accuracy: f64, time: f64, backbone: Option<f64>) {
        self.accuracy.push(accuracy);
        self.time.push(time);
        if let Some(b) = backbone {
            self.backbone.push(b);
        }
    }

    fn row(&self, method: &str, cell: Option<BackboneCell>) -> TableRow {
        TableRow {
            method: method.into(),
            m: cell.map(|c| c.m),
            alpha: cell.map(|c| c.alpha),
            beta: cell.map(|c| c.beta),
            accuracy: mean(&self.accuracy),
            time_secs: mean(&self.time),
            backbone_size: if self.backbone.is_empty() { None } else { Some(mean(&self.backbone)) },
        }
    }
}

/// Run the sparse-regression block; returns rows in Table-1 order.
pub fn run_sparse_regression_block(cfg: &ExperimentConfig) -> Result<Vec<TableRow>> {
    let mut glmnet = Acc::default();
    let mut l0bnb = Acc::default();
    let mut bb: Vec<Acc> = vec![Acc::default(); cfg.grid.len()];

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed.wrapping_add(rep as u64));
        let gen_cfg = sparse_regression::SparseRegressionConfig {
            n: cfg.n,
            p: cfg.p,
            k: cfg.k,
            rho: 0.1,
            snr: 5.0,
        };
        let data = sparse_regression::generate(&gen_cfg, &mut rng);
        // All methods train on the full (n × p) design (keeps the PJRT
        // shape buckets hit and the comparison fair); model selection uses
        // a fresh validation draw and accuracy a fresh test draw, both
        // from this rep's ground-truth β.
        let fresh = |rng: &mut Rng| {
            let mut d2 = sparse_regression::generate(&gen_cfg, rng);
            let signal = d2.x.matvec(&data.beta_true);
            for (yi, s) in d2.y.iter_mut().zip(&signal) {
                *yi = s + data.sigma * rng.normal();
            }
            d2
        };
        let val = fresh(&mut rng);
        let test = fresh(&mut rng);

        // --- GLMNet ---
        let watch = Stopwatch::start();
        let path = elastic_net_path(
            &data.x,
            &data.y,
            &ElasticNetConfig { alpha: 1.0, n_lambda: 50, ..Default::default() },
        );
        let best = path.select_best(&val.x, &val.y);
        let t = watch.elapsed_secs();
        glmnet.push(r2_score(&test.y, &best.predict(&test.x)), t, None);

        // --- L0BnB path (k = 1..k_max) ---
        let watch = Stopwatch::start();
        let budget = Budget::seconds(cfg.budget_secs);
        let mut best_r2_val = f64::NEG_INFINITY;
        let mut best_model = None;
        for kk in 1..=cfg.k {
            let res = l0bnb_solve(
                &data.x,
                &data.y,
                &L0BnbConfig { k: kk, lambda2: 1e-3, gap_tol: 0.01, max_nodes: 0 },
                &budget.child(cfg.budget_secs / cfg.k as f64),
            );
            let val_r2 = r2_score(&val.y, &res.predict(&val.x));
            if val_r2 > best_r2_val {
                best_r2_val = val_r2;
                best_model = Some(res);
            }
            if budget.expired() {
                break;
            }
        }
        let t = watch.elapsed_secs();
        let model = best_model.expect("at least one k solved");
        l0bnb.push(r2_score(&test.y, &model.predict(&test.x)), t, None);

        // --- BbLearn grid ---
        for (ci, cell) in cfg.grid.iter().enumerate() {
            let watch = Stopwatch::start();
            let builder = Backbone::sparse_regression()
                .alpha(cell.alpha)
                .beta(cell.beta)
                .num_subproblems(cell.m)
                .max_nonzeros(cfg.k)
                .backend(default_backend())
                .seed(cfg.seed.wrapping_add(rep as u64).wrapping_mul(31 + ci as u64));
            // cfg.threads is authoritative (overrides any BACKBONE_THREADS
            // default): 1 = inline sequential schedule, 0 = all cores.
            let mut learner = builder.threads(cfg.threads).build()?;
            let model = learner
                .fit_with_budget(&data.x, &data.y, &Budget::seconds(cfg.budget_secs))?
                .clone();
            let t = watch.elapsed_secs();
            let bsize = learner.last_diagnostics.as_ref().unwrap().backbone_size as f64;
            bb[ci].push(r2_score(&test.y, &model.predict(&test.x)), t, Some(bsize));
        }
    }

    let mut rows = vec![glmnet.row("GLMNet", None), l0bnb.row("L0BnB", None)];
    for (ci, cell) in cfg.grid.iter().enumerate() {
        rows.push(bb[ci].row("BbLearn", Some(*cell)));
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Decision-tree block
// ---------------------------------------------------------------------------

/// Run the decision-tree block; returns rows in Table-1 order.
pub fn run_decision_tree_block(cfg: &ExperimentConfig) -> Result<Vec<TableRow>> {
    let mut cart = Acc::default();
    let mut odt = Acc::default();
    let mut bb: Vec<Acc> = vec![Acc::default(); cfg.grid.len()];
    let depth = 2usize;
    let bins = 2usize;

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed.wrapping_add(1000 + rep as u64));
        let gen_cfg = classification::ClassificationConfig {
            n: cfg.n + cfg.n / 2, // extra rows reserved for the test split
            p: cfg.p,
            k: cfg.k,
            n_redundant: (cfg.p / 10).min(cfg.k),
            n_clusters: 4,
            class_sep: 1.5,
            flip_y: 0.05,
        };
        let data = classification::generate(&gen_cfg, &mut rng);
        let split = train_test_split(&data.x, &data.y, 1.0 / 3.0, &mut rng);

        // --- CART (depth cross-validated on a validation split) ---
        let watch = Stopwatch::start();
        let inner = train_test_split(&split.x_train, &split.y_train, 0.25, &mut rng);
        let mut best = (f64::NEG_INFINITY, 2usize);
        for d in [2, 3, 4, 5] {
            let m = cart_fit(
                &inner.x_train,
                &inner.y_train,
                &CartConfig { max_depth: d, ..Default::default() },
            );
            let a = auc(&inner.y_test, &m.predict_proba(&inner.x_test));
            if a > best.0 {
                best = (a, d);
            }
        }
        let model = cart_fit(
            &split.x_train,
            &split.y_train,
            &CartConfig { max_depth: best.1, ..Default::default() },
        );
        let t = watch.elapsed_secs();
        cart.push(auc(&split.y_test, &model.predict_proba(&split.x_test)), t, None);

        // --- ODTLearn-style exact tree on all (binarized) features ---
        let watch = Stopwatch::start();
        let bz = binarize(&split.x_train, bins);
        let res = exact_tree_solve(
            &bz.x_bin,
            &split.y_train,
            &ExactTreeConfig { depth, min_leaf: 1, feature_subset: None },
            &Budget::seconds(cfg.budget_secs),
        );
        // Predict on test via the stored thresholds.
        let proba: Vec<f64> = (0..split.x_test.rows())
            .map(|i| {
                let row = split.x_test.row(i);
                let mut node = &res.root;
                loop {
                    match node {
                        crate::solvers::exact_tree::BinNode::Leaf { prob, .. } => return *prob,
                        crate::solvers::exact_tree::BinNode::Split { feature, left, right } => {
                            let src = bz.feature_of[*feature];
                            let thr = bz.thresholds[*feature];
                            node = if row[src] <= thr { left } else { right };
                        }
                    }
                }
            })
            .collect();
        let t = watch.elapsed_secs();
        odt.push(auc(&split.y_test, &proba), t, None);

        // --- BbLearn grid ---
        for (ci, cell) in cfg.grid.iter().enumerate() {
            let watch = Stopwatch::start();
            let builder = Backbone::decision_tree()
                .alpha(cell.alpha)
                .beta(cell.beta)
                .num_subproblems(cell.m)
                .depth(depth)
                .bins(bins)
                .seed(cfg.seed.wrapping_add(rep as u64).wrapping_mul(17 + ci as u64));
            // cfg.threads is authoritative (overrides any BACKBONE_THREADS
            // default): 1 = inline sequential schedule, 0 = all cores.
            let mut learner = builder.threads(cfg.threads).build()?;
            learner.fit_with_budget(
                &split.x_train,
                &split.y_train,
                &Budget::seconds(cfg.budget_secs),
            )?;
            let t = watch.elapsed_secs();
            let a = auc(&split.y_test, &learner.predict_proba(&split.x_test));
            let bsize = learner.last_diagnostics.as_ref().unwrap().backbone_size as f64;
            bb[ci].push(a, t, Some(bsize));
        }
    }

    let mut rows = vec![cart.row("CART", None), odt.row("ODTLearn", None)];
    for (ci, cell) in cfg.grid.iter().enumerate() {
        rows.push(bb[ci].row("BbLearn", Some(*cell)));
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Clustering block
// ---------------------------------------------------------------------------

/// Run the clustering block; returns rows in Table-1 order.
pub fn run_clustering_block(cfg: &ExperimentConfig) -> Result<Vec<TableRow>> {
    let mut km_acc = Acc::default();
    let mut exact_acc = Acc::default();
    let mut bb: Vec<Acc> = vec![Acc::default(); cfg.grid.len()];

    for rep in 0..cfg.repetitions {
        let mut rng = Rng::seed_from_u64(cfg.seed.wrapping_add(2000 + rep as u64));
        // Ambiguity: target clusters (cfg.k) exceed true clusters.
        let true_clusters = (cfg.k.saturating_sub(2)).max(2);
        let gen_cfg = blobs::BlobsConfig {
            n: cfg.n,
            p: cfg.p,
            true_clusters,
            cluster_std: 1.0,
            center_box: 10.0,
            min_center_dist: 4.0,
        };
        let data = blobs::generate(&gen_cfg, &mut rng);

        // --- KMeans at the target k ---
        let watch = Stopwatch::start();
        let km = kmeans_fit(
            &data.x,
            &KMeansConfig { k: cfg.k, ..Default::default() },
            &mut rng,
        );
        let t = watch.elapsed_secs();
        km_acc.push(silhouette_score(&data.x, &km.labels), t, None);

        // --- Exact clique partitioning ---
        let watch = Stopwatch::start();
        let res = clique_solve(
            &data.x,
            &CliqueConfig { k: cfg.k, min_cluster_size: 1, ..Default::default() },
            &Budget::seconds(cfg.budget_secs),
        )?;
        let t = watch.elapsed_secs();
        exact_acc.push(silhouette_score(&data.x, &res.labels), t, None);

        // --- BbLearn grid ---
        for (ci, cell) in cfg.grid.iter().enumerate() {
            let watch = Stopwatch::start();
            let builder = Backbone::clustering()
                .beta(cell.beta)
                .num_subproblems(cell.m)
                .n_clusters(cfg.k)
                .backend(default_backend())
                .seed(cfg.seed.wrapping_add(rep as u64).wrapping_mul(13 + ci as u64));
            // cfg.threads is authoritative (overrides any BACKBONE_THREADS
            // default): 1 = inline sequential schedule, 0 = all cores.
            let mut learner = builder.threads(cfg.threads).build()?;
            learner.fit_with_budget(&data.x, &Budget::seconds(cfg.budget_secs))?;
            let t = watch.elapsed_secs();
            let sil = silhouette_score(&data.x, learner.labels());
            let bsize = learner.last_diagnostics.as_ref().unwrap().backbone_size as f64;
            bb[ci].push(sil, t, Some(bsize));
        }
    }

    let mut rows = vec![km_acc.row("KMeans", None), exact_acc.row("Exact", None)];
    for (ci, cell) in cfg.grid.iter().enumerate() {
        let mut row = bb[ci].row("BbLearn", Some(*cell));
        row.alpha = None; // Table 1 lists `a = —` for clustering
        rows.push(row);
    }
    Ok(rows)
}

/// Run one block by problem id.
pub fn run_block(cfg: &ExperimentConfig) -> Result<Vec<TableRow>> {
    match cfg.problem {
        Problem::SparseRegression => run_sparse_regression_block(cfg),
        Problem::DecisionTrees => run_decision_tree_block(cfg),
        Problem::Clustering => run_clustering_block(cfg),
    }
}

/// Convenience: silhouette of a labels vector on data (re-exported for
/// benches).
pub fn clustering_accuracy(x: &Matrix, labels: &[usize]) -> f64 {
    silhouette_score(x, labels)
}

/// The canonical percentile now lives in [`crate::obs`]; this re-export
/// keeps existing `bench_support::percentile` callers working while
/// guaranteeing every consumer (bench rows, `/stats` latency window,
/// self-test report) computes p50/p99 the same way.
pub use crate::obs::percentile;

// ---------------------------------------------------------------------------
// Perf suite (`cli bench`): end-to-end fit timings as machine-readable rows
// ---------------------------------------------------------------------------

/// One timed configuration of the perf suite: a learner fitted end to end
/// on a standard shape at a fixed seed, `reps` times.
#[derive(Debug, Clone)]
pub struct BenchFitResult {
    /// Learner id: `sparse_regression` | `sparse_logistic` |
    /// `decision_tree` | `clustering`.
    pub learner: &'static str,
    pub n: usize,
    pub p: usize,
    pub k: usize,
    /// Subproblems per iteration (M).
    pub m: usize,
    /// Requested worker threads (0 = all cores, 1 = inline sequential).
    pub threads: usize,
    pub reps: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    /// Headline quality metric — guards against "fast because wrong".
    pub metric_name: &'static str,
    pub metric: f64,
}

/// One standard shape of the perf suite.
struct BenchShape {
    learner: &'static str,
    n: usize,
    p: usize,
    k: usize,
    m: usize,
}

/// The perf-suite shapes. `quick` is the CI scale (finishes in well under
/// a minute on one core); full scale includes the n=500, p=2000
/// sparse-regression class the perf acceptance gate tracks.
fn bench_shapes(quick: bool) -> Vec<BenchShape> {
    if quick {
        vec![
            BenchShape { learner: "sparse_regression", n: 120, p: 600, k: 5, m: 5 },
            BenchShape { learner: "sparse_logistic", n: 120, p: 200, k: 3, m: 4 },
            BenchShape { learner: "decision_tree", n: 150, p: 20, k: 3, m: 4 },
            BenchShape { learner: "clustering", n: 16, p: 2, k: 3, m: 3 },
        ]
    } else {
        vec![
            BenchShape { learner: "sparse_regression", n: 500, p: 2000, k: 10, m: 8 },
            BenchShape { learner: "sparse_logistic", n: 300, p: 1000, k: 5, m: 6 },
            BenchShape { learner: "decision_tree", n: 300, p: 40, k: 5, m: 5 },
            BenchShape { learner: "clustering", n: 24, p: 2, k: 4, m: 4 },
        ]
    }
}

/// Run every learner's end-to-end fit on the standard shapes, once per
/// entry of `threads_list` (the `cli bench` payload: typically `[1, 0]`,
/// i.e. the inline sequential schedule and the all-cores scheduler —
/// bit-identical results, so the ratio is pure scheduling speedup).
/// Deterministic seeds; `budget_secs` bounds each fit's exact phase.
pub fn run_bench_suite(
    quick: bool,
    reps: usize,
    budget_secs: f64,
    threads_list: &[usize],
) -> Result<Vec<BenchFitResult>> {
    let reps = reps.max(1);
    let mut out = Vec::new();
    for shape in bench_shapes(quick) {
        for &threads in threads_list {
            let mut secs = Vec::with_capacity(reps);
            let mut metric = 0.0;
            let metric_name;
            match shape.learner {
                "sparse_regression" => {
                    let data = sparse_regression::generate(
                        &sparse_regression::SparseRegressionConfig {
                            n: shape.n,
                            p: shape.p,
                            k: shape.k,
                            rho: 0.1,
                            snr: 5.0,
                        },
                        &mut Rng::seed_from_u64(71),
                    );
                    metric_name = "r2";
                    for _ in 0..reps {
                        let mut bb = Backbone::sparse_regression()
                            .alpha(0.5)
                            .beta(0.5)
                            .num_subproblems(shape.m)
                            .max_nonzeros(shape.k)
                            .threads(threads)
                            .seed(7)
                            .build()?;
                        let watch = Stopwatch::start();
                        let model = bb
                            .fit_with_budget(&data.x, &data.y, &Budget::seconds(budget_secs))?
                            .clone();
                        secs.push(watch.elapsed_secs());
                        metric = r2_score(&data.y, &model.predict(&data.x));
                    }
                }
                "sparse_logistic" => {
                    let data = classification::generate(
                        &classification::ClassificationConfig {
                            n: shape.n,
                            p: shape.p,
                            k: shape.k,
                            n_redundant: 0,
                            n_clusters: 2,
                            class_sep: 1.5,
                            flip_y: 0.05,
                        },
                        &mut Rng::seed_from_u64(72),
                    );
                    metric_name = "auc";
                    for _ in 0..reps {
                        let mut bb = Backbone::sparse_logistic()
                            .alpha(0.5)
                            .beta(0.5)
                            .num_subproblems(shape.m)
                            .max_nonzeros(shape.k)
                            .threads(threads)
                            .seed(7)
                            .build()?;
                        let watch = Stopwatch::start();
                        bb.fit_with_budget(&data.x, &data.y, &Budget::seconds(budget_secs))?;
                        secs.push(watch.elapsed_secs());
                        metric = auc(&data.y, &bb.predict_proba(&data.x));
                    }
                }
                "decision_tree" => {
                    let data = classification::generate(
                        &classification::ClassificationConfig {
                            n: shape.n,
                            p: shape.p,
                            k: shape.k,
                            n_redundant: 0,
                            n_clusters: 4,
                            class_sep: 1.5,
                            flip_y: 0.05,
                        },
                        &mut Rng::seed_from_u64(73),
                    );
                    metric_name = "auc";
                    for _ in 0..reps {
                        let mut bb = Backbone::decision_tree()
                            .alpha(0.6)
                            .beta(0.5)
                            .num_subproblems(shape.m)
                            .depth(2)
                            .threads(threads)
                            .seed(7)
                            .build()?;
                        let watch = Stopwatch::start();
                        bb.fit_with_budget(&data.x, &data.y, &Budget::seconds(budget_secs))?;
                        secs.push(watch.elapsed_secs());
                        metric = auc(&data.y, &bb.predict_proba(&data.x));
                    }
                }
                "clustering" => {
                    let data = blobs::generate(
                        &blobs::BlobsConfig {
                            n: shape.n,
                            p: shape.p,
                            true_clusters: (shape.k.saturating_sub(1)).max(2),
                            cluster_std: 1.0,
                            center_box: 10.0,
                            min_center_dist: 4.0,
                        },
                        &mut Rng::seed_from_u64(74),
                    );
                    metric_name = "silhouette";
                    for _ in 0..reps {
                        let mut bb = Backbone::clustering()
                            .beta(0.8)
                            .num_subproblems(shape.m)
                            .n_clusters(shape.k)
                            .threads(threads)
                            .seed(7)
                            .build()?;
                        let watch = Stopwatch::start();
                        bb.fit_with_budget(&data.x, &Budget::seconds(budget_secs))?;
                        secs.push(watch.elapsed_secs());
                        metric = silhouette_score(&data.x, bb.labels());
                    }
                }
                other => anyhow::bail!("unknown bench learner `{other}`"),
            }
            let mean_secs = mean(&secs);
            let min_secs = secs.iter().copied().fold(f64::INFINITY, f64::min);
            out.push(BenchFitResult {
                learner: shape.learner,
                n: shape.n,
                p: shape.p,
                k: shape.k,
                m: shape.m,
                threads,
                reps,
                mean_secs,
                min_secs,
                metric_name,
                metric,
            });
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Hardware fingerprint + per-backend kernel rows + trajectory emission
// ---------------------------------------------------------------------------

/// Hardware fingerprint for the `BENCH_*.json` trajectory: CPU model,
/// runtime-detected vector features, and core count. A perf number is
/// only comparable to another taken on the same fingerprint — the CI
/// trajectory comparator treats rows from different fingerprints as
/// not like-for-like.
pub fn hardware_fingerprint() -> Json {
    let mut m: BTreeMap<String, Json> = BTreeMap::new();
    m.insert("cpu_model".into(), Json::String(crate::linalg::cpu_model()));
    m.insert(
        "features".into(),
        Json::Array(
            crate::linalg::detected_features()
                .iter()
                .map(|f| Json::String((*f).into()))
                .collect(),
        ),
    );
    m.insert(
        "cores".into(),
        Json::Number(std::thread::available_parallelism().map_or(1, |v| v.get()) as f64),
    );
    m.insert(
        "simd_available".into(),
        Json::Bool(crate::linalg::simd_available()),
    );
    Json::Object(m)
}

/// Time every backend-dispatched kernel under each *distinct* resolved
/// backend (scalar always; simd when the CPU has AVX2) and return one
/// JSON row per (kernel, backend). Shapes: n=500, p=2000 at full scale
/// (the perf-gate class), n=100, p=300 under `quick`. Timings are
/// min-of-`reps` per-call seconds (min is the standard noise floor for
/// microbenchmarks). The entry backend is restored before returning.
pub fn kernel_bench_rows(quick: bool, reps: usize) -> Vec<Json> {
    use crate::linalg::{backend, set_backend, BackendChoice, ComputeBackend};
    use std::hint::black_box;
    let reps = reps.max(1);
    let (n, p) = if quick { (100, 300) } else { (500, 2000) };
    let mut rng = Rng::seed_from_u64(42);
    let x = Matrix::from_vec(n, p, (0..n * p).map(|_| rng.normal()).collect());
    let v: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.1).collect();
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let len = n * p;
    let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
    let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
    let idx: Vec<usize> = (0..len).map(|i| (i * 7919) % len).collect();
    let means = x.col_means();

    let entry = backend();
    let mut rows: Vec<Json> = Vec::new();
    let mut seen: Vec<ComputeBackend> = Vec::new();
    for choice in [BackendChoice::Scalar, BackendChoice::Simd] {
        let be = set_backend(choice);
        if seen.contains(&be) {
            // No AVX2: the simd request resolved to scalar again — a
            // second identical row would be noise, not signal.
            continue;
        }
        seen.push(be);
        let time = |iters: usize, f: &mut dyn FnMut()| -> f64 {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let watch = Stopwatch::start();
                for _ in 0..iters {
                    f();
                }
                best = best.min(watch.elapsed_secs() / iters as f64);
            }
            best
        };
        let mut push = |kernel: &str, secs: f64| {
            let mut r: BTreeMap<String, Json> = BTreeMap::new();
            r.insert("kind".into(), Json::String("kernel".into()));
            r.insert("kernel".into(), Json::String(kernel.into()));
            r.insert("backend".into(), Json::String(be.name().into()));
            r.insert("n".into(), Json::Number(n as f64));
            r.insert("p".into(), Json::Number(p as f64));
            r.insert("reps".into(), Json::Number(reps as f64));
            r.insert("mean_secs".into(), Json::Number(secs));
            r.insert("min_secs".into(), Json::Number(secs));
            rows.push(Json::Object(r));
        };

        // Vector kernels stream n·p elements; matrix kernels run the
        // real entry points on the n×p design.
        push("dot", time(50, &mut || {
            black_box(crate::linalg::dot(&a, &b));
        }));
        let mut yacc = b.clone();
        push("axpy", time(50, &mut || {
            crate::linalg::axpy(0.5, &a, &mut yacc);
            black_box(&yacc);
        }));
        push("sqdist", time(50, &mut || {
            black_box(crate::linalg::sqdist(&a, &b));
        }));
        push("gather_sum", time(20, &mut || {
            black_box(crate::linalg::gather_sum(&a, &idx));
        }));
        let (mut num, mut den) = (vec![0.0; p], vec![0.0; p]);
        push("centered_accumulate", time(5, &mut || {
            for i in 0..n {
                crate::linalg::centered_accumulate(
                    x.row(i),
                    &means,
                    w[i],
                    &mut num,
                    &mut den,
                );
            }
            black_box(&num);
        }));
        let mut buf = Vec::new();
        push("matvec", time(20, &mut || {
            x.matvec_into(&v, &mut buf);
            black_box(&buf);
        }));
        let mut buft = Vec::new();
        push("matvec_t", time(20, &mut || {
            x.matvec_t_into(&w, &mut buft);
            black_box(&buft);
        }));
        push("gram", time(1, &mut || {
            black_box(x.gram());
        }));
        let mut resid = Vec::new();
        push("residual_into", time(20, &mut || {
            x.residual_into(&beta, &y, 0.1, &mut resid);
            black_box(&resid);
        }));
    }
    // Restore whatever backend the process entered with.
    set_backend(match entry {
        ComputeBackend::Scalar => BackendChoice::Scalar,
        ComputeBackend::Simd => BackendChoice::Simd,
    });
    rows
}

/// Write a `backbone-bench/v1` document, refusing to emit a trajectory
/// file whose `results` array is empty (an empty trajectory pins nothing
/// and silently poisons cross-PR comparisons) unless the caller
/// explicitly asked for a schema-only document.
pub fn emit_bench_json(path: &str, doc: &Json, schema_only: bool) -> Result<()> {
    let empty = match doc.get("results") {
        Some(r) => r.as_array().map_or(true, |a| a.is_empty()),
        None => true,
    };
    if empty && !schema_only {
        anyhow::bail!(
            "refusing to write `{path}` with an empty `results` array — a trajectory \
             file with no measurements pins no baseline (pass --schema-only to write \
             a schema-only document on purpose)"
        );
    }
    crate::util::atomic_write(path, &doc.to_string_pretty())
        .with_context(|| format!("writing `{path}`"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(problem: Problem) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::quick_defaults(problem);
        cfg.repetitions = 1;
        match problem {
            Problem::SparseRegression => {
                cfg.n = 60;
                cfg.p = 100;
                cfg.k = 3;
                cfg.budget_secs = 10.0;
            }
            Problem::DecisionTrees => {
                cfg.n = 90;
                cfg.p = 12;
                cfg.k = 3;
                cfg.budget_secs = 10.0;
            }
            Problem::Clustering => {
                cfg.n = 12;
                cfg.p = 2;
                cfg.k = 3;
                cfg.budget_secs = 15.0;
            }
        }
        cfg.grid.truncate(1);
        cfg
    }

    #[test]
    fn sparse_regression_block_produces_expected_rows() {
        let rows = run_sparse_regression_block(&tiny(Problem::SparseRegression)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].method, "GLMNet");
        assert_eq!(rows[1].method, "L0BnB");
        assert_eq!(rows[2].method, "BbLearn");
        assert!(rows[2].backbone_size.is_some());
        for r in &rows {
            assert!(r.accuracy.is_finite());
            assert!(r.time_secs >= 0.0);
        }
    }

    #[test]
    fn decision_tree_block_produces_expected_rows() {
        let rows = run_decision_tree_block(&tiny(Problem::DecisionTrees)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].method, "CART");
        assert_eq!(rows[1].method, "ODTLearn");
        for r in &rows {
            assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0, "{r:?}");
        }
    }

    #[test]
    fn clustering_block_produces_expected_rows() {
        let rows = run_clustering_block(&tiny(Problem::Clustering)).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].method, "KMeans");
        assert_eq!(rows[1].method, "Exact");
        assert!(rows[2].alpha.is_none(), "clustering lists a = —");
    }

    #[test]
    fn bench_suite_produces_one_row_per_shape_and_thread_count() {
        // Sequential-only, single rep, tight budget: structure over speed.
        let rows = run_bench_suite(true, 1, 5.0, &[1]).unwrap();
        assert_eq!(rows.len(), 4);
        let learners: Vec<&str> = rows.iter().map(|r| r.learner).collect();
        assert_eq!(
            learners,
            vec!["sparse_regression", "sparse_logistic", "decision_tree", "clustering"]
        );
        for r in &rows {
            assert_eq!(r.threads, 1);
            assert_eq!(r.reps, 1);
            assert!(r.mean_secs >= 0.0 && r.min_secs >= 0.0);
            assert!(r.min_secs <= r.mean_secs + 1e-12);
            assert!(r.metric.is_finite(), "{}: metric {}", r.learner, r.metric);
        }
    }

    #[test]
    fn fingerprint_has_the_comparator_fields() {
        let fp = hardware_fingerprint();
        assert!(fp.get("cpu_model").and_then(|v| v.as_str().map(String::from)).is_some());
        assert!(fp.get("features").and_then(|v| v.as_array().map(|_| ())).is_some());
        assert!(fp.get("cores").is_some());
        assert!(fp.get("simd_available").is_some());
    }

    #[test]
    fn kernel_rows_cover_every_kernel_per_distinct_backend() {
        let rows = kernel_bench_rows(true, 1);
        let backends = if crate::linalg::simd_available() { 2 } else { 1 };
        assert_eq!(rows.len(), 9 * backends, "{rows:?}");
        for r in &rows {
            assert_eq!(r.get("kind").and_then(|v| v.as_str()), Some("kernel"));
            let secs = r.get("min_secs").and_then(|v| v.as_f64()).unwrap();
            assert!(secs.is_finite() && secs >= 0.0);
        }
    }

    #[test]
    fn emit_refuses_empty_results_unless_schema_only() {
        let mut doc: BTreeMap<String, Json> = BTreeMap::new();
        doc.insert("schema".into(), Json::String("backbone-bench/v1".into()));
        doc.insert("results".into(), Json::Array(vec![]));
        let doc = Json::Object(doc);
        let path = std::env::temp_dir().join("backbone_emit_test.json");
        let path = path.to_str().unwrap();
        assert!(emit_bench_json(path, &doc, false).is_err(), "empty must be refused");
        emit_bench_json(path, &doc, true).unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn percentile_interpolates_and_handles_edges() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 4.0);
        assert_eq!(percentile(&xs, 0.5), 2.5);
        assert!((percentile(&xs, 0.25) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn render_table_formats_all_rows() {
        let rows = vec![
            TableRow {
                method: "GLMNet".into(),
                m: None,
                alpha: None,
                beta: None,
                accuracy: 0.871,
                time_secs: 15.0,
                backbone_size: None,
            },
            TableRow {
                method: "BbLearn".into(),
                m: Some(5),
                alpha: Some(0.1),
                beta: Some(0.5),
                accuracy: 0.884,
                time_secs: 483.0,
                backbone_size: Some(48.0),
            },
        ];
        let text = render_table("Sparse Regression (n=500, p=5000, k=10)", &rows);
        assert!(text.contains("GLMNet"));
        assert!(text.contains("0.884"));
        assert!(text.contains("48"));
        assert!(text.contains("—"));
    }
}
