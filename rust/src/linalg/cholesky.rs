//! Cholesky factorization and least-squares solves.
//!
//! The reduced problems the backbone produces are small (`|B| ≤ ~100`
//! features), so normal-equations + Cholesky with a ridge jitter is both
//! fast and accurate enough; solvers that need more stability (the LP
//! simplex) maintain their own factorizations.

use super::{dot, Matrix};
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix. Fails if the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky: matrix must be square");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = a.get(i, i) - s;
                if d <= 0.0 {
                    bail!("cholesky: matrix not positive definite at pivot {i} (d={d})");
                }
                l.set(i, j, d.sqrt());
            } else {
                l.set(i, j, (a.get(i, j) - s) / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Extend a Cholesky factorization by one bordered row/column in O(m²).
///
/// Given the lower factor `l` of an m×m SPD matrix `A` and the bordered
/// matrix `A' = [[A, c], [cᵀ, d]]` (`cross` = c, `diag` = d), returns the
/// (m+1)×(m+1) lower factor of `A'` without refactorizing: the new row is
/// `w = L⁻¹c` (one forward substitution) and the new pivot is
/// `√(d − wᵀw)`. Fails if the bordered matrix is not (numerically)
/// positive definite. This is the incremental primitive behind the L0
/// swap search's O(k²) trial evaluation (`solvers::cd::l0`).
pub fn cholesky_bordered(l: &Matrix, cross: &[f64], diag: f64) -> Result<Matrix> {
    let m = l.rows();
    assert_eq!(m, l.cols(), "cholesky_bordered: factor must be square");
    assert_eq!(m, cross.len(), "cholesky_bordered: border length mismatch");
    let w = solve_lower(l, cross);
    let d = diag - dot(&w, &w);
    if d <= 0.0 {
        bail!("cholesky_bordered: bordered matrix not positive definite (d={d})");
    }
    let mut out = Matrix::zeros(m + 1, m + 1);
    for i in 0..m {
        out.row_mut(i)[..=i].copy_from_slice(&l.row(i)[..=i]);
    }
    out.row_mut(m)[..m].copy_from_slice(&w);
    out.set(m, m, d.sqrt());
    Ok(out)
}

/// Solve `L y = b` (forward substitution) for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let s = dot(&l.row(i)[..i], &y[..i]);
        y[i] = (b[i] - s) / l.get(i, i);
    }
    y
}

/// Solve `Lᵀ x = y` (back substitution) for lower-triangular `L`.
pub fn solve_lower_transpose(l: &Matrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = 0.0;
        for k in (i + 1)..n {
            s += l.get(k, i) * x[k];
        }
        x[i] = (y[i] - s) / l.get(i, i);
    }
    x
}

/// Solve the SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Ok(solve_lower_transpose(&l, &y))
}

/// Ordinary / ridge least squares: minimize `‖y − Xβ‖² + λ‖β‖²` via the
/// normal equations `(XᵀX + λI) β = Xᵀy`. With `λ = 0` a tiny jitter is
/// added automatically if the Gram matrix is singular.
pub fn least_squares(x: &Matrix, y: &[f64], lambda: f64) -> Result<Vec<f64>> {
    assert_eq!(x.rows(), y.len(), "least_squares: dimension mismatch");
    let p = x.cols();
    if p == 0 {
        return Ok(Vec::new());
    }
    let mut g = x.gram();
    let xty = x.matvec_t(y);
    for i in 0..p {
        g.set(i, i, g.get(i, i) + lambda);
    }
    match solve_spd(&g, &xty) {
        Ok(beta) => Ok(beta),
        Err(_) => {
            // Singular gram (collinear columns): retry with jitter scaled
            // to the matrix magnitude.
            let jitter = 1e-8 * (g.frobenius_norm() / p as f64).max(1e-8);
            for i in 0..p {
                g.set(i, i, g.get(i, i) + jitter);
            }
            solve_spd(&g, &xty)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_vec(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn cholesky_of_known_matrix() {
        // A = [[4,2],[2,3]] → L = [[2,0],[1,sqrt(2)]]
        let a = Matrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.get(1, 1) - 2f64.sqrt()).abs() < 1e-12);
        // L Lᵀ = A
        let recon = l.matmul(&l.transpose());
        for i in 0..2 {
            for j in 0..2 {
                assert!((recon.get(i, j) - a.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn bordered_factor_matches_full_factorization() {
        let a = Matrix::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let l2 = cholesky(&a.select_columns(&[0, 1]).select_rows(&[0, 1])).unwrap();
        let l3 = cholesky_bordered(&l2, &[1.0, 2.0], 4.0).unwrap();
        let full = cholesky(&a).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                assert!((l3.get(i, j) - full.get(i, j)).abs() < 1e-12);
            }
        }
        // Indefinite border must be rejected.
        assert!(cholesky_bordered(&l2, &[10.0, 10.0], 1.0).is_err());
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_roundtrip() {
        let a = Matrix::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        approx_vec(&x, &x_true, 1e-10);
    }

    #[test]
    fn least_squares_exact_recovery() {
        // Overdetermined, exactly consistent system.
        let x = Matrix::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![2.0, -1.0],
        ]);
        let beta_true = vec![2.5, -1.5];
        let y = x.matvec(&beta_true);
        let beta = least_squares(&x, &y, 0.0).unwrap();
        approx_vec(&beta, &beta_true, 1e-10);
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let x = Matrix::from_rows(&[vec![1.0], vec![1.0]]);
        let y = vec![2.0, 2.0];
        let b0 = least_squares(&x, &y, 0.0).unwrap()[0];
        let b1 = least_squares(&x, &y, 10.0).unwrap()[0];
        assert!((b0 - 2.0).abs() < 1e-10);
        assert!(b1 < b0 && b1 > 0.0);
    }

    #[test]
    fn least_squares_handles_collinear_columns() {
        // Two identical columns: singular gram; jitter path must succeed.
        let x = Matrix::from_rows(&[vec![1.0, 1.0], vec![2.0, 2.0], vec![3.0, 3.0]]);
        let y = vec![2.0, 4.0, 6.0];
        let beta = least_squares(&x, &y, 0.0).unwrap();
        let pred = x.matvec(&beta);
        approx_vec(&pred, &y, 1e-4);
    }
}
