//! Cholesky factorization and least-squares solves.
//!
//! The reduced problems the backbone produces are small (`|B| ≤ ~100`
//! features), so normal-equations + Cholesky with a ridge jitter is both
//! fast and accurate enough; solvers that need more stability (the LP
//! simplex) maintain their own factorizations.

use super::{dot, Matrix};
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix. Fails if the matrix is not (numerically) positive definite.
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky: matrix must be square");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let s = dot(&l.row(i)[..j], &l.row(j)[..j]);
            if i == j {
                let d = a.get(i, i) - s;
                if d <= 0.0 {
                    bail!("cholesky: matrix not positive definite at pivot {i} (d={d})");
                }
                l.set(i, j, d.sqrt());
            } else {
                l.set(i, j, (a.get(i, j) - s) / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve `L y = b` (forward substitution) for lower-triangular `L`.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let s = dot(&l.row(i)[..i], &y[..i]);
        y[i] = (b[i] - s) / l.get(i, i);
    }
    y
}

/// Solve `Lᵀ x = y` (back substitution) for lower-triangular `L`.
pub fn solve_lower_transpose(l: &Matrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = 0.0;
        for k in (i + 1)..n {
            s += l.get(k, i) * x[k];
        }
        x[i] = (y[i] - s) / l.get(i, i);
    }
    x
}

/// Solve the SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let y = solve_lower(&l, b);
    Ok(solve_lower_transpose(&l, &y))
}

/// Ordinary / ridge least squares: minimize `‖y − Xβ‖² + λ‖β‖²` via the
/// normal equations `(XᵀX + λI) β = Xᵀy`. With `λ = 0` a tiny jitter is
/// added automatically if the Gram matrix is singular.
pub fn least_squares(x: &Matrix, y: &[f64], lambda: f64) -> Result<Vec<f64>> {
    assert_eq!(x.rows(), y.len(), "least_squares: dimension mismatch");
    let p = x.cols();
    if p == 0 {
        return Ok(Vec::new());
    }
    let mut g = x.gram();
    let xty = x.matvec_t(y);
    for i in 0..p {
        g.set(i, i, g.get(i, i) + lambda);
    }
    match solve_spd(&g, &xty) {
        Ok(beta) => Ok(beta),
        Err(_) => {
            // Singular gram (collinear columns): retry with jitter scaled
            // to the matrix magnitude.
            let jitter = 1e-8 * (g.frobenius_norm() / p as f64).max(1e-8);
            for i in 0..p {
                g.set(i, i, g.get(i, i) + jitter);
            }
            solve_spd(&g, &xty)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx_vec(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn cholesky_of_known_matrix() {
        // A = [[4,2],[2,3]] → L = [[2,0],[1,sqrt(2)]]
        let a = Matrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.get(1, 1) - 2f64.sqrt()).abs() < 1e-12);
        // L Lᵀ = A
        let recon = l.matmul(&l.transpose());
        for i in 0..2 {
            for j in 0..2 {
                assert!((recon.get(i, j) - a.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_roundtrip() {
        let a = Matrix::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let x = solve_spd(&a, &b).unwrap();
        approx_vec(&x, &x_true, 1e-10);
    }

    #[test]
    fn least_squares_exact_recovery() {
        // Overdetermined, exactly consistent system.
        let x = Matrix::from_rows(&[
            vec![1.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 1.0],
            vec![2.0, -1.0],
        ]);
        let beta_true = vec![2.5, -1.5];
        let y = x.matvec(&beta_true);
        let beta = least_squares(&x, &y, 0.0).unwrap();
        approx_vec(&beta, &beta_true, 1e-10);
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let x = Matrix::from_rows(&[vec![1.0], vec![1.0]]);
        let y = vec![2.0, 2.0];
        let b0 = least_squares(&x, &y, 0.0).unwrap()[0];
        let b1 = least_squares(&x, &y, 10.0).unwrap()[0];
        assert!((b0 - 2.0).abs() < 1e-10);
        assert!(b1 < b0 && b1 > 0.0);
    }

    #[test]
    fn least_squares_handles_collinear_columns() {
        // Two identical columns: singular gram; jitter path must succeed.
        let x = Matrix::from_rows(&[vec![1.0, 1.0], vec![2.0, 2.0], vec![3.0, 3.0]]);
        let y = vec![2.0, 4.0, 6.0];
        let beta = least_squares(&x, &y, 0.0).unwrap();
        let pred = x.matvec(&beta);
        approx_vec(&pred, &y, 1e-4);
    }
}
