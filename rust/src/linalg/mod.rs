//! Dense linear algebra substrate.
//!
//! All solvers operate on small-to-medium dense problems (the paper's exact
//! methods cap out around `n=500`, `p=5000`), so a straightforward row-major
//! `f64` matrix with cache-blocked 4-accumulator kernels, Cholesky
//! (including the O(k²) bordered update [`cholesky_bordered`]), and
//! least-squares is the right substrate — no sparse structures or external
//! BLAS. Squared row/column norms are memoized per matrix (see
//! [`Matrix::row_sq_norms`]) with invalidation on every mutation.
//!
//! The hot kernels dispatch through a process-wide [`ComputeBackend`]
//! ([`backend()`] / [`set_backend`] / `BACKBONE_BACKEND`): blocked scalar
//! kernels as the portable default, AVX2 kernels (`simd`, the crate's only
//! `unsafe` module) where detected — **bit-identical by construction**, so
//! backend choice only moves timings. The original sequential loops are
//! retained as `*_naive` property-test oracles and never dispatch (see the
//! `ops` module docs for the three-tier contract).

mod backend;
mod cholesky;
mod matrix;
mod ops;
#[cfg(all(target_arch = "x86_64", not(miri)))]
mod simd;

// Dispatch shim: `ComputeBackend::Simd` arms compile against this name on
// every target. Where the intrinsics module is cfg-excluded (non-x86-64,
// Miri) the shim is the blocked scalar kernels — the Simd variant is
// unreachable there (`simd_available()` is false), but the match arms
// still have to compile.
#[cfg(all(target_arch = "x86_64", not(miri)))]
use simd as simd_shim;
#[cfg(not(all(target_arch = "x86_64", not(miri))))]
mod simd_shim {
    pub use super::ops::{
        axpy_blocked as axpy, centered_accumulate_blocked as centered_accumulate,
        dot_blocked as dot, fused4_blocked as fused4, gather_sum_blocked as gather_sum,
        sqdist_blocked as sqdist,
    };
}

pub use backend::*;
pub use cholesky::*;
pub use matrix::*;
pub use ops::*;
