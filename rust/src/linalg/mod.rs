//! Dense linear algebra substrate.
//!
//! All solvers operate on small-to-medium dense problems (the paper's exact
//! methods cap out around `n=500`, `p=5000`), so a straightforward row-major
//! `f64` matrix with cache-blocked matmul, Cholesky, and least-squares is
//! the right substrate — no sparse structures or external BLAS.

mod cholesky;
mod matrix;
mod ops;

pub use cholesky::*;
pub use matrix::*;
pub use ops::*;
