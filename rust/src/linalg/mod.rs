//! Dense linear algebra substrate.
//!
//! All solvers operate on small-to-medium dense problems (the paper's exact
//! methods cap out around `n=500`, `p=5000`), so a straightforward row-major
//! `f64` matrix with cache-blocked 4-accumulator kernels, Cholesky
//! (including the O(k²) bordered update [`cholesky_bordered`]), and
//! least-squares is the right substrate — no sparse structures or external
//! BLAS. The original scalar loops are retained as `*_naive` property-test
//! oracles; squared row/column norms are memoized per matrix (see
//! [`Matrix::row_sq_norms`]) with invalidation on every mutation.

mod cholesky;
mod matrix;
mod ops;

pub use cholesky::*;
pub use matrix::*;
pub use ops::*;
