//! Pluggable compute-backend dispatch for the linalg hot kernels.
//!
//! Every hot kernel entry point in [`super::ops`] (`dot`, `axpy`,
//! `sqdist`, the fused rank-4 update behind `matvec_t`/`matmul`/`gram`,
//! the screener's centered accumulate, the CART gather sum — and through
//! them `matvec`, `residual_into`, and the distance evaluations) routes
//! through a process-wide [`ComputeBackend`]:
//!
//! - [`ComputeBackend::Scalar`] — the PR-4 blocked 4-accumulator kernels
//!   (portable default, pure safe Rust).
//! - [`ComputeBackend::Simd`] — `core::arch` AVX2 kernels
//!   ([`super::simd`], the crate's only `unsafe` module), **bit-identical
//!   to the scalar backend by construction** (same accumulator structure,
//!   same association, multiply+add only — no FMA contraction).
//!
//! The retained `*_naive` loops are the third tier: pure sequential
//! correctness oracles that never dispatch (see `linalg::ops` docs).
//!
//! ## Selection
//!
//! Resolution order (first match wins), memoized in a process-global:
//!
//! 1. An explicit [`set_backend`] call — the CLI's `--backend` flag and
//!    `ExperimentConfig::backend` land here, and tests use it to pin or
//!    flip backends in-process.
//! 2. The `BACKBONE_BACKEND` environment variable: `scalar`, `simd`, or
//!    `auto` (anything else warns once and falls back to `auto`).
//! 3. `auto` (the default): `simd` when runtime detection
//!    (`is_x86_feature_detected!("avx2")`) succeeds, else `scalar`.
//!
//! Requesting `simd` on hardware without AVX2 (or on non-x86 targets, or
//! under Miri) resolves to `scalar` — the request is a ceiling, not a
//! promise, and every backend produces bit-identical results, so the
//! fallback is observable only in timings.
//!
//! The state is an `AtomicU8` rather than a `OnceLock` precisely so
//! [`set_backend`] can re-resolve mid-process (backend-identity tests fit
//! under one backend, switch, and refit). Because backends are
//! bit-identical, a switch while another thread computes is benign: it
//! changes which instructions run, never what they produce.
//!
//! The same seam is where an accelerator backend would slot in: the
//! `pjrt`-gated [`crate::runtime::Engine`] already shadows whole-routine
//! entry points (screen/IHT/Lloyd) the same way — detect at startup,
//! dispatch per call, fall back bit-compatibly (see `runtime::engine`).

use super::{ops, simd_shim as simd};
use std::sync::atomic::{AtomicU8, Ordering};

/// A backend *request*: what the user asked for, before hardware
/// detection. Carried by `ExperimentConfig` and the `--backend` flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// Always the blocked scalar kernels.
    Scalar,
    /// The AVX2 kernels when available, else scalar.
    Simd,
    /// Detect: AVX2 kernels iff the CPU has them (the default).
    #[default]
    Auto,
}

impl BackendChoice {
    /// Parse `scalar`/`simd`/`auto` (the `BACKBONE_BACKEND` and
    /// `--backend` vocabulary). `None` for anything else.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "simd" => Some(Self::Simd),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
            Self::Auto => "auto",
        }
    }
}

/// A *resolved* backend: which kernel implementations actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputeBackend {
    Scalar,
    Simd,
}

impl ComputeBackend {
    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
        }
    }

    /// Dot product.
    #[inline]
    pub fn dot(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Self::Scalar => ops::dot_blocked(a, b),
            Self::Simd => simd::dot(a, b),
        }
    }

    /// `y += alpha * x`.
    #[inline]
    pub fn axpy(self, alpha: f64, x: &[f64], y: &mut [f64]) {
        match self {
            Self::Scalar => ops::axpy_blocked(alpha, x, y),
            Self::Simd => simd::axpy(alpha, x, y),
        }
    }

    /// Squared Euclidean distance.
    #[inline]
    pub fn sqdist(self, a: &[f64], b: &[f64]) -> f64 {
        match self {
            Self::Scalar => ops::sqdist_blocked(a, b),
            Self::Simd => simd::sqdist(a, b),
        }
    }

    /// Fused rank-4 row update `out[j] += Σ c[l]·r_l[j]`.
    #[inline]
    pub fn fused4(
        self,
        c: [f64; 4],
        r0: &[f64],
        r1: &[f64],
        r2: &[f64],
        r3: &[f64],
        out: &mut [f64],
    ) {
        match self {
            Self::Scalar => ops::fused4_blocked(c, r0, r1, r2, r3, out),
            Self::Simd => simd::fused4(c, r0, r1, r2, r3, out),
        }
    }

    /// Screener centered accumulate: `num += (row−means)·w`,
    /// `den += (row−means)²`.
    #[inline]
    pub fn centered_accumulate(
        self,
        row: &[f64],
        means: &[f64],
        w: f64,
        num: &mut [f64],
        den: &mut [f64],
    ) {
        match self {
            Self::Scalar => ops::centered_accumulate_blocked(row, means, w, num, den),
            Self::Simd => simd::centered_accumulate(row, means, w, num, den),
        }
    }

    /// Indexed gather sum `Σ vals[idx[i]]`.
    #[inline]
    pub fn gather_sum(self, vals: &[f64], idx: &[usize]) -> f64 {
        match self {
            Self::Scalar => ops::gather_sum_blocked(vals, idx),
            Self::Simd => simd::gather_sum(vals, idx),
        }
    }
}

/// Process-global resolved backend: 0 = unresolved, 1 = scalar, 2 = simd.
static STATE: AtomicU8 = AtomicU8::new(0);

/// True when the AVX2 kernel module is compiled in *and* the CPU reports
/// AVX2 at runtime. Always false on non-x86-64 targets and under Miri
/// (vendor intrinsics are outside Miri's model, so `linalg::simd` is
/// `cfg`-excluded there and everything runs on the scalar backend).
pub fn simd_available() -> bool {
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    {
        false
    }
}

fn resolve(choice: BackendChoice) -> ComputeBackend {
    match choice {
        BackendChoice::Scalar => ComputeBackend::Scalar,
        BackendChoice::Simd | BackendChoice::Auto => {
            if simd_available() {
                ComputeBackend::Simd
            } else {
                ComputeBackend::Scalar
            }
        }
    }
}

/// Resolve and pin the process-wide backend. Returns what was resolved
/// (e.g. `Scalar` for a `Simd` request on hardware without AVX2).
pub fn set_backend(choice: BackendChoice) -> ComputeBackend {
    let resolved = resolve(choice);
    let code = match resolved {
        ComputeBackend::Scalar => 1,
        ComputeBackend::Simd => 2,
    };
    STATE.store(code, Ordering::Relaxed);
    resolved
}

/// The currently resolved backend; resolves from `BACKBONE_BACKEND` (or
/// `auto`) on first use.
#[inline]
pub fn backend() -> ComputeBackend {
    match STATE.load(Ordering::Relaxed) {
        1 => ComputeBackend::Scalar,
        2 => ComputeBackend::Simd,
        _ => resolve_from_env(),
    }
}

#[cold]
fn resolve_from_env() -> ComputeBackend {
    let choice = match std::env::var("BACKBONE_BACKEND") {
        Ok(v) => BackendChoice::parse(&v).unwrap_or_else(|| {
            eprintln!(
                "warning: BACKBONE_BACKEND=`{v}` is not scalar|simd|auto; using auto"
            );
            BackendChoice::Auto
        }),
        Err(_) => BackendChoice::Auto,
    };
    set_backend(choice)
}

/// Name of the currently resolved backend (`"scalar"` / `"simd"`).
pub fn backend_name() -> &'static str {
    backend().name()
}

/// CPU model string for the bench hardware fingerprint (from
/// `/proc/cpuinfo` on Linux; `"unknown"` elsewhere).
pub fn cpu_model() -> String {
    if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    "unknown".to_string()
}

/// Runtime-detected vector features relevant to the SIMD backend, for
/// the bench hardware fingerprint. FMA is reported when present but the
/// SIMD backend deliberately does not use it (see `linalg::simd` docs).
pub fn detected_features() -> Vec<&'static str> {
    let mut out = Vec::new();
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        if std::arch::is_x86_feature_detected!("sse4.2") {
            out.push("sse4.2");
        }
        if std::arch::is_x86_feature_detected!("avx") {
            out.push("avx");
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            out.push("avx2");
        }
        if std::arch::is_x86_feature_detected!("fma") {
            out.push("fma");
        }
        if std::arch::is_x86_feature_detected!("avx512f") {
            out.push("avx512f");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parse_roundtrip() {
        for c in [BackendChoice::Scalar, BackendChoice::Simd, BackendChoice::Auto] {
            assert_eq!(BackendChoice::parse(c.name()), Some(c));
        }
        assert_eq!(BackendChoice::parse("SIMD"), Some(BackendChoice::Simd));
        assert_eq!(BackendChoice::parse(" auto "), Some(BackendChoice::Auto));
        assert_eq!(BackendChoice::parse("gpu"), None);
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
    }

    #[test]
    fn set_backend_pins_and_reports_resolution() {
        // Remember whatever the process had, restore at the end — other
        // tests in this binary share the global.
        let before = backend();
        assert_eq!(set_backend(BackendChoice::Scalar), ComputeBackend::Scalar);
        assert_eq!(backend(), ComputeBackend::Scalar);
        let simd = set_backend(BackendChoice::Simd);
        if simd_available() {
            assert_eq!(simd, ComputeBackend::Simd);
        } else {
            assert_eq!(simd, ComputeBackend::Scalar, "no AVX2 → scalar fallback");
        }
        assert_eq!(backend(), simd);
        // Auto resolves to simd iff available.
        let auto = set_backend(BackendChoice::Auto);
        assert_eq!(auto == ComputeBackend::Simd, simd_available());
        let code = match before {
            ComputeBackend::Scalar => BackendChoice::Scalar,
            ComputeBackend::Simd => BackendChoice::Simd,
        };
        set_backend(code);
    }

    #[test]
    fn every_dispatched_kernel_is_backend_bit_identical() {
        let a: Vec<f64> = (0..37).map(|i| (i as f64 * 0.31).sin() * 2.0).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64 * 0.77).cos() * 1.5).collect();
        let (s, v) = (ComputeBackend::Scalar, ComputeBackend::Simd);
        assert_eq!(s.dot(&a, &b).to_bits(), v.dot(&a, &b).to_bits());
        assert_eq!(s.sqdist(&a, &b).to_bits(), v.sqdist(&a, &b).to_bits());
        let (mut y1, mut y2) = (b.clone(), b.clone());
        s.axpy(0.9, &a, &mut y1);
        v.axpy(0.9, &a, &mut y2);
        assert_eq!(y1, y2);
        let idx: Vec<usize> = (0..37).map(|i| (i * 5) % 37).collect();
        assert_eq!(s.gather_sum(&a, &idx).to_bits(), v.gather_sum(&a, &idx).to_bits());
    }

    #[test]
    fn fingerprint_helpers_do_not_panic() {
        let _ = cpu_model();
        let feats = detected_features();
        if simd_available() {
            assert!(feats.contains(&"avx2"));
        }
    }
}
