//! Matrix/vector products and vector helpers.
//!
//! The hot kernels (`matmul`, `matvec`, `matvec_t`) are written so LLVM can
//! auto-vectorize the inner loops: contiguous row slices, no bounds checks
//! in the inner loop (iterator zips), and an ikj loop order for matmul.

use super::Matrix;

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Elementwise `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Mean of a slice.
#[inline]
pub fn mean(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    a.iter().sum::<f64>() / a.len() as f64
}

/// Population variance of a slice.
pub fn variance(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let m = mean(a);
    a.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / a.len() as f64
}

impl Matrix {
    /// `self * v` for a column vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(v, &mut out);
        out
    }

    /// `self * v` written into a caller-owned buffer (resized to fit) —
    /// the allocation-free variant the solver workspaces use in their hot
    /// loops.
    pub fn matvec_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.cols(), "matvec: dimension mismatch");
        out.clear();
        out.extend((0..self.rows()).map(|i| dot(self.row(i), v)));
    }

    /// `selfᵀ * v` — computed without materializing the transpose by
    /// accumulating scaled rows (row-major friendly).
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_t_into(v, &mut out);
        out
    }

    /// `selfᵀ * v` written into a caller-owned buffer (resized to fit).
    pub fn matvec_t_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.rows(), "matvec_t: dimension mismatch");
        out.clear();
        out.resize(self.cols(), 0.0);
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                axpy(vi, self.row(i), out);
            }
        }
    }

    /// Matrix product `self * other` with ikj loop order (streams `other`'s
    /// rows, keeps the output row in cache).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols(), other.rows(), "matmul: dimension mismatch");
        let (m, k, n) = (self.rows(), self.cols(), other.cols());
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            // SAFETY-free split: accumulate into a scratch row then copy,
            // so the borrow checker allows reading `other` rows.
            let out_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate().take(k) {
                if a != 0.0 {
                    axpy(a, other.row(kk), out_row);
                }
            }
        }
        out
    }

    /// Gram matrix `selfᵀ * self` exploiting symmetry (only the upper
    /// triangle is computed, then mirrored).
    pub fn gram(&self) -> Matrix {
        let p = self.cols();
        let mut g = Matrix::zeros(p, p);
        for i in 0..self.rows() {
            let row = self.row(i);
            for a in 0..p {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for (b, &rb) in row.iter().enumerate().skip(a) {
                    grow[b] += ra * rb;
                }
            }
        }
        for a in 0..p {
            for b in 0..a {
                let v = g.get(b, a);
                g.set(a, b, v);
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-10
    }

    #[test]
    fn dot_norm_axpy() {
        assert!(approx(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0));
        assert!(approx(norm2(&[3.0, 4.0]), 5.0));
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.matvec_t(&[1.0, 0.0, 1.0]), vec![6.0, 8.0]);
    }

    #[test]
    fn matvec_t_equals_transpose_matvec() {
        let m = Matrix::from_rows(&[vec![1.0, -2.0, 0.5], vec![0.0, 3.0, 1.0]]);
        let v = vec![2.0, -1.0];
        assert_eq!(m.matvec_t(&v), m.transpose().matvec(&v));
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[2.0, 1.0]);
        assert_eq!(c.row(1), &[4.0, 3.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.5, -2.0, 3.0], vec![0.0, 1.0, 2.0]]);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 0.0],
            vec![-1.0, 0.5, 2.0],
            vec![3.0, 1.0, 1.0],
            vec![0.0, 0.0, 4.0],
        ]);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!(approx(g.get(i, j), g2.get(i, j)));
            }
        }
    }

    #[test]
    fn stats_helpers() {
        assert!(approx(mean(&[1.0, 2.0, 3.0]), 2.0));
        assert!(approx(variance(&[1.0, 2.0, 3.0]), 2.0 / 3.0));
        assert!(approx(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0));
    }
}
