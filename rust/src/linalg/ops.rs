//! Matrix/vector products and vector helpers.
//!
//! The hot kernels come in two tiers:
//!
//! - **Blocked 4-accumulator kernels** — the defaults ([`Matrix::matvec`],
//!   [`Matrix::matvec_t`], [`Matrix::matmul`], [`Matrix::gram`],
//!   [`Matrix::residual_into`]). Inner loops are unrolled four-wide with
//!   independent accumulators (breaking the sequential-add dependency
//!   chain so LLVM emits packed FMAs) and stream four rows per pass over
//!   the output, quartering the memory traffic of the row-at-a-time
//!   formulation. `matmul` additionally blocks the output row into
//!   L1-sized column panels.
//! - **Scalar reference kernels** — the original straight loops, retained
//!   as [`Matrix::matvec_naive`] / [`Matrix::matvec_t_naive`] /
//!   [`Matrix::matmul_naive`] / [`Matrix::gram_naive`]. They are the
//!   oracles the property suite (`tests/prop_linalg.rs`) checks the
//!   blocked kernels against (agreement ≤ 1e-9) and are not meant for
//!   production call sites.
//!
//! Accuracy contract: blocked kernels reassociate floating-point sums, so
//! results may differ from the scalar oracles in the last few ulps — never
//! more than the property-test tolerance on well-scaled data. Within one
//! build, every kernel is deterministic: the same inputs always produce
//! bit-identical outputs (no runtime dispatch, no threading).
//!
//! Aliasing contract: all `*_into` entry points take `&mut Vec<f64>`
//! output buffers that are cleared and resized before writing, so stale
//! contents never leak into results; Rust's borrow rules already prevent
//! the output from aliasing any input.

use super::Matrix;

/// Dot product, 4-accumulator unrolled.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % 4;
    let (a4, at) = a.split_at(split);
    let (b4, bt) = b.split_at(split);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        s0 += ca[0] * cb[0];
        s1 += ca[1] * cb[1];
        s2 += ca[2] * cb[2];
        s3 += ca[3] * cb[3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Scalar reference dot product (property-test oracle for [`dot`]).
#[inline]
pub fn dot_naive(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Elementwise `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Mean of a slice.
#[inline]
pub fn mean(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    a.iter().sum::<f64>() / a.len() as f64
}

/// Population variance of a slice.
pub fn variance(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let m = mean(a);
    a.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / a.len() as f64
}

/// Column-panel width of the blocked `matmul`: 1024 f64 = 8 KiB per
/// streamed row, so the four B-row panels plus the output panel sit in L1.
const MATMUL_COL_BLOCK: usize = 1024;

impl Matrix {
    /// `self * v` for a column vector `v` (blocked kernel).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(v, &mut out);
        out
    }

    /// `self * v` written into a caller-owned buffer (resized to fit) —
    /// the allocation-free variant the solver workspaces use in their hot
    /// loops. Each row is reduced with the 4-accumulator [`dot`].
    pub fn matvec_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.cols(), "matvec: dimension mismatch");
        out.clear();
        out.extend((0..self.rows()).map(|i| dot(self.row(i), v)));
    }

    /// Scalar reference `self * v` (property-test oracle for
    /// [`Matrix::matvec`]; sequential left-to-right summation per row).
    pub fn matvec_naive(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols(), "matvec: dimension mismatch");
        (0..self.rows()).map(|i| dot_naive(self.row(i), v)).collect()
    }

    /// `selfᵀ * v` — computed without materializing the transpose
    /// (blocked kernel).
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_t_into(v, &mut out);
        out
    }

    /// `selfᵀ * v` written into a caller-owned buffer (resized to fit).
    /// Rows are consumed four at a time, fusing four scaled-row updates
    /// into one pass over the output — 4× fewer output-buffer sweeps than
    /// the row-at-a-time formulation.
    pub fn matvec_t_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.rows(), "matvec_t: dimension mismatch");
        let p = self.cols();
        out.clear();
        out.resize(p, 0.0);
        let mut i = 0;
        while i + 4 <= self.rows() {
            let (v0, v1, v2, v3) = (v[i], v[i + 1], v[i + 2], v[i + 3]);
            if v0 != 0.0 || v1 != 0.0 || v2 != 0.0 || v3 != 0.0 {
                let r0 = self.row(i);
                let r1 = self.row(i + 1);
                let r2 = self.row(i + 2);
                let r3 = self.row(i + 3);
                for j in 0..p {
                    out[j] += v0 * r0[j] + v1 * r1[j] + v2 * r2[j] + v3 * r3[j];
                }
            }
            i += 4;
        }
        while i < self.rows() {
            if v[i] != 0.0 {
                axpy(v[i], self.row(i), out);
            }
            i += 1;
        }
    }

    /// Scalar reference `selfᵀ * v` (property-test oracle for
    /// [`Matrix::matvec_t`]; one scaled-row accumulation per row).
    pub fn matvec_t_naive(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows(), "matvec_t: dimension mismatch");
        let mut out = vec![0.0; self.cols()];
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                axpy(vi, self.row(i), &mut out);
            }
        }
        out
    }

    /// Matrix product `self * other` (blocked kernel): ikj loop order with
    /// the k dimension unrolled four-wide (one fused pass over the output
    /// row per four A-coefficients) and the output row processed in
    /// L1-sized column panels ([`MATMUL_COL_BLOCK`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols(), other.rows(), "matmul: dimension mismatch");
        let (m, kdim, n) = (self.rows(), self.cols(), other.cols());
        let mut out = Matrix::zeros(m, n);
        let od = out.data_mut();
        for i in 0..m {
            let a_row = self.row(i);
            let orow = &mut od[i * n..(i + 1) * n];
            let mut jb = 0;
            while jb < n {
                let je = (jb + MATMUL_COL_BLOCK).min(n);
                let opanel = &mut orow[jb..je];
                let mut kk = 0;
                while kk + 4 <= kdim {
                    let (a0, a1, a2, a3) =
                        (a_row[kk], a_row[kk + 1], a_row[kk + 2], a_row[kk + 3]);
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        let b0 = &other.row(kk)[jb..je];
                        let b1 = &other.row(kk + 1)[jb..je];
                        let b2 = &other.row(kk + 2)[jb..je];
                        let b3 = &other.row(kk + 3)[jb..je];
                        for (j, o) in opanel.iter_mut().enumerate() {
                            *o += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                        }
                    }
                    kk += 4;
                }
                while kk < kdim {
                    let a = a_row[kk];
                    if a != 0.0 {
                        axpy(a, &other.row(kk)[jb..je], opanel);
                    }
                    kk += 1;
                }
                jb = je;
            }
        }
        out
    }

    /// Scalar reference `self * other` (property-test oracle for
    /// [`Matrix::matmul`]; ikj order, one scaled-row update per k).
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols(), other.rows(), "matmul: dimension mismatch");
        let (m, k) = (self.rows(), self.cols());
        let mut out = Matrix::zeros(m, other.cols());
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate().take(k) {
                if a != 0.0 {
                    axpy(a, other.row(kk), out_row);
                }
            }
        }
        out
    }

    /// Gram matrix `selfᵀ * self` (blocked kernel): rows are consumed four
    /// at a time as fused rank-4 updates of the upper triangle (4× fewer
    /// triangle sweeps than the rank-1 formulation), then mirrored.
    pub fn gram(&self) -> Matrix {
        let p = self.cols();
        let n = self.rows();
        let mut g = Matrix::zeros(p, p);
        let gd = g.data_mut();
        let mut i = 0;
        while i + 4 <= n {
            let r0 = self.row(i);
            let r1 = self.row(i + 1);
            let r2 = self.row(i + 2);
            let r3 = self.row(i + 3);
            for a in 0..p {
                let (x0, x1, x2, x3) = (r0[a], r1[a], r2[a], r3[a]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                let ga = &mut gd[a * p + a..(a + 1) * p];
                let (s0, s1, s2, s3) = (&r0[a..], &r1[a..], &r2[a..], &r3[a..]);
                for (b, gb) in ga.iter_mut().enumerate() {
                    *gb += x0 * s0[b] + x1 * s1[b] + x2 * s2[b] + x3 * s3[b];
                }
            }
            i += 4;
        }
        while i < n {
            let row = self.row(i);
            for a in 0..p {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let ga = &mut gd[a * p + a..(a + 1) * p];
                let sa = &row[a..];
                for (b, gb) in ga.iter_mut().enumerate() {
                    *gb += ra * sa[b];
                }
            }
            i += 1;
        }
        // Mirror through the flat buffer (get/set would re-drop the norm
        // memo per element).
        for a in 0..p {
            for b in 0..a {
                gd[a * p + b] = gd[b * p + a];
            }
        }
        g
    }

    /// Scalar reference Gram matrix (property-test oracle for
    /// [`Matrix::gram`]; rank-1 row updates of the upper triangle).
    pub fn gram_naive(&self) -> Matrix {
        let p = self.cols();
        let mut g = Matrix::zeros(p, p);
        for i in 0..self.rows() {
            let row = self.row(i);
            for a in 0..p {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for (b, &rb) in row.iter().enumerate().skip(a) {
                    grow[b] += ra * rb;
                }
            }
        }
        for a in 0..p {
            for b in 0..a {
                let v = g.get(b, a);
                g.set(a, b, v);
            }
        }
        g
    }

    /// Fused residual `out[i] = y[i] − offset − rowᵢ·beta`, i.e. the
    /// regression residual `y − Xβ − intercept` in a single pass over the
    /// matrix — no intermediate prediction buffer. `out` is cleared and
    /// resized to `rows()`; it must be a distinct buffer from `y` (the
    /// borrow checker enforces this).
    pub fn residual_into(&self, beta: &[f64], y: &[f64], offset: f64, out: &mut Vec<f64>) {
        assert_eq!(beta.len(), self.cols(), "residual_into: beta dimension mismatch");
        assert_eq!(y.len(), self.rows(), "residual_into: y dimension mismatch");
        out.clear();
        out.extend(
            y.iter()
                .enumerate()
                .map(|(i, &yi)| yi - offset - dot(self.row(i), beta)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-10
    }

    #[test]
    fn dot_norm_axpy() {
        assert!(approx(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0));
        assert!(approx(norm2(&[3.0, 4.0]), 5.0));
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
    }

    #[test]
    fn dot_matches_naive_across_lengths() {
        for len in 0..19 {
            let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.7).sin()).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64 * 1.3).cos()).collect();
            assert!(approx(dot(&a, &b), dot_naive(&a, &b)), "len={len}");
        }
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.matvec_t(&[1.0, 0.0, 1.0]), vec![6.0, 8.0]);
    }

    #[test]
    fn matvec_t_equals_transpose_matvec() {
        let m = Matrix::from_rows(&[vec![1.0, -2.0, 0.5], vec![0.0, 3.0, 1.0]]);
        let v = vec![2.0, -1.0];
        let a = m.matvec_t(&v);
        let b = m.transpose().matvec(&v);
        for (x, y) in a.iter().zip(&b) {
            assert!(approx(*x, *y), "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn blocked_kernels_match_naive_on_awkward_shapes() {
        // Shapes straddling the 4-wide unroll boundaries.
        for (r, c) in [(1, 1), (3, 5), (4, 4), (5, 3), (7, 9), (8, 8), (9, 2)] {
            let a = Matrix::from_vec(
                r,
                c,
                (0..r * c).map(|i| ((i * 37 % 19) as f64 - 9.0) * 0.25).collect(),
            );
            let v: Vec<f64> = (0..c).map(|i| (i as f64 - 1.5) * 0.5).collect();
            let w: Vec<f64> = (0..r).map(|i| (i as f64 - 2.0) * 0.75).collect();
            for (x, y) in a.matvec(&v).iter().zip(a.matvec_naive(&v)) {
                assert!(approx(*x, y));
            }
            for (x, y) in a.matvec_t(&w).iter().zip(a.matvec_t_naive(&w)) {
                assert!(approx(*x, y));
            }
            let b = Matrix::from_vec(
                c,
                r,
                (0..r * c).map(|i| ((i * 11 % 13) as f64 - 6.0) * 0.5).collect(),
            );
            let fast = a.matmul(&b);
            let slow = a.matmul_naive(&b);
            let gf = a.gram();
            let gs = a.gram_naive();
            for i in 0..r {
                for j in 0..r {
                    assert!(approx(fast.get(i, j), slow.get(i, j)));
                }
            }
            for i in 0..c {
                for j in 0..c {
                    assert!(approx(gf.get(i, j), gs.get(i, j)));
                }
            }
        }
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[2.0, 1.0]);
        assert_eq!(c.row(1), &[4.0, 3.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.5, -2.0, 3.0], vec![0.0, 1.0, 2.0]]);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 0.0],
            vec![-1.0, 0.5, 2.0],
            vec![3.0, 1.0, 1.0],
            vec![0.0, 0.0, 4.0],
        ]);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!(approx(g.get(i, j), g2.get(i, j)));
            }
        }
    }

    #[test]
    fn residual_into_matches_unfused() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, -1.0], vec![0.5, 0.5]]);
        let beta = vec![2.0, -1.0];
        let y = vec![1.0, 4.0, -2.0];
        let mut out = vec![99.0; 7]; // stale contents must be overwritten
        x.residual_into(&beta, &y, 0.25, &mut out);
        let pred = x.matvec(&beta);
        assert_eq!(out.len(), 3);
        for i in 0..3 {
            assert!(approx(out[i], y[i] - 0.25 - pred[i]));
        }
    }

    #[test]
    fn stats_helpers() {
        assert!(approx(mean(&[1.0, 2.0, 3.0]), 2.0));
        assert!(approx(variance(&[1.0, 2.0, 3.0]), 2.0 / 3.0));
        assert!(approx(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0));
    }
}
