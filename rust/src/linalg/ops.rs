//! Matrix/vector products and vector helpers.
//!
//! The hot kernels come in three tiers:
//!
//! - **Dispatched entry points** — the public kernels every consumer
//!   calls ([`dot`], [`axpy`], [`sqdist`], [`fused4`],
//!   [`centered_accumulate`], [`gather_sum`], and through them
//!   [`Matrix::matvec`], [`Matrix::matvec_t`], [`Matrix::matmul`],
//!   [`Matrix::gram`], [`Matrix::residual_into`]). Each routes through
//!   the process-wide [`super::ComputeBackend`] (see `linalg::backend`):
//!   blocked scalar kernels by default, AVX2 kernels where detected.
//! - **Blocked scalar kernels** — the `*_blocked` functions: inner loops
//!   unrolled four-wide with independent accumulators (breaking the
//!   sequential-add dependency chain), four rows streamed per pass over
//!   the output. `matmul` additionally blocks the output row into
//!   L1-sized column panels. These are the `ComputeBackend::Scalar`
//!   implementation and the portable fallback of `ComputeBackend::Simd`.
//! - **Sequential naive oracles** — the original straight loops,
//!   retained as the `*_naive` functions ([`dot_naive`],
//!   [`sqdist_naive`], [`gather_sum_naive`], [`Matrix::matvec_naive`],
//!   [`Matrix::matvec_t_naive`], [`Matrix::matmul_naive`],
//!   [`Matrix::gram_naive`]). **The naive tier is exclusively a test
//!   oracle**: it never dispatches through the backend (its loops are
//!   written out inline, so no backend bug can hide its own oracle), is
//!   checked against the dispatched kernels to ≤ 1e-9 by
//!   `tests/prop_linalg.rs`, and has no production call sites.
//!
//! Accuracy contract: blocked kernels reassociate floating-point sums,
//! so results may differ from the naive oracles in the last few ulps —
//! never more than the property-test tolerance on well-scaled data.
//! Across *backends* the contract is stronger: the AVX2 kernels mirror
//! the blocked scalar accumulator structure exactly (multiply+add only,
//! no FMA — see `linalg::simd`), so scalar and SIMD outputs are
//! **bit-identical** and backend selection is a pure wall-clock knob.
//! Within one build and one backend, every kernel is deterministic: the
//! same inputs always produce bit-identical outputs.
//!
//! Aliasing contract: all `*_into` entry points take `&mut Vec<f64>`
//! output buffers that are cleared and resized before writing, so stale
//! contents never leak into results; Rust's borrow rules already prevent
//! the output from aliasing any input.

use super::backend::backend;
use super::Matrix;

/// Dot product (backend-dispatched).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    backend().dot(a, b)
}

/// Dot product, 4-accumulator unrolled (the scalar backend).
#[inline]
pub fn dot_blocked(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % 4;
    let (a4, at) = a.split_at(split);
    let (b4, bt) = b.split_at(split);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        s0 += ca[0] * cb[0];
        s1 += ca[1] * cb[1];
        s2 += ca[2] * cb[2];
        s3 += ca[3] * cb[3];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// Sequential reference dot product (test oracle for [`dot`]).
#[inline]
pub fn dot_naive(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Squared Euclidean distance between two vectors (backend-dispatched).
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    backend().sqdist(a, b)
}

/// Squared Euclidean distance, 4-accumulator unrolled (the scalar
/// backend; mirrors [`dot_blocked`]'s accumulation structure).
#[inline]
pub fn sqdist_blocked(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let split = a.len() - a.len() % 4;
    let (a4, at) = a.split_at(split);
    let (b4, bt) = b.split_at(split);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        let d0 = ca[0] - cb[0];
        let d1 = ca[1] - cb[1];
        let d2 = ca[2] - cb[2];
        let d3 = ca[3] - cb[3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for (x, y) in at.iter().zip(bt) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Sequential reference squared distance (test oracle for [`sqdist`]).
#[inline]
pub fn sqdist_naive(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// `y += alpha * x` (backend-dispatched).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    backend().axpy(alpha, x, y)
}

/// `y += alpha * x`, scalar backend. Elementwise, so every backend is
/// trivially bit-identical here; kept as the non-dispatching form the
/// naive oracles and the SIMD fallback share.
#[inline]
pub fn axpy_blocked(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Fused rank-4 row update `out[j] += c[0]·r0[j] + c[1]·r1[j] +
/// c[2]·r2[j] + c[3]·r3[j]` (backend-dispatched) — the shared inner step
/// of [`Matrix::matvec_t`], [`Matrix::matmul`] panels, and
/// [`Matrix::gram`] rank-4 updates.
#[inline]
pub fn fused4(c: [f64; 4], r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], out: &mut [f64]) {
    backend().fused4(c, r0, r1, r2, r3, out)
}

/// Fused rank-4 row update, scalar backend (left-associated sum per
/// element — the association the SIMD backend reproduces exactly).
#[inline]
pub fn fused4_blocked(
    c: [f64; 4],
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    out: &mut [f64],
) {
    let m = out.len();
    let (r0, r1, r2, r3) = (&r0[..m], &r1[..m], &r2[..m], &r3[..m]);
    for (j, o) in out.iter_mut().enumerate() {
        *o += c[0] * r0[j] + c[1] * r1[j] + c[2] * r2[j] + c[3] * r3[j];
    }
}

/// Screener centered accumulate (backend-dispatched): for each column
/// `j`, `num[j] += (row[j] − means[j])·w` and
/// `den[j] += (row[j] − means[j])²` — the per-row step of the
/// correlation screener's single pass over `X`.
#[inline]
pub fn centered_accumulate(row: &[f64], means: &[f64], w: f64, num: &mut [f64], den: &mut [f64]) {
    backend().centered_accumulate(row, means, w, num, den)
}

/// Screener centered accumulate, scalar backend (elementwise — every
/// backend is bit-identical here by construction).
#[inline]
pub fn centered_accumulate_blocked(
    row: &[f64],
    means: &[f64],
    w: f64,
    num: &mut [f64],
    den: &mut [f64],
) {
    debug_assert_eq!(row.len(), means.len());
    debug_assert_eq!(row.len(), num.len());
    debug_assert_eq!(row.len(), den.len());
    for (j, (&v, &m)) in row.iter().zip(means).enumerate() {
        let c = v - m;
        num[j] += c * w;
        den[j] += c * c;
    }
}

/// Indexed gather sum `Σ vals[idx[i]]` (backend-dispatched) — the CART
/// split scan's and tree builder's label-mass reduction over a row set.
#[inline]
pub fn gather_sum(vals: &[f64], idx: &[usize]) -> f64 {
    backend().gather_sum(vals, idx)
}

/// Indexed gather sum, 4-accumulator unrolled (the scalar backend).
#[inline]
pub fn gather_sum_blocked(vals: &[f64], idx: &[usize]) -> f64 {
    let split = idx.len() - idx.len() % 4;
    let (i4, it) = idx.split_at(split);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in i4.chunks_exact(4) {
        s0 += vals[c[0]];
        s1 += vals[c[1]];
        s2 += vals[c[2]];
        s3 += vals[c[3]];
    }
    let mut s = (s0 + s2) + (s1 + s3);
    for &i in it {
        s += vals[i];
    }
    s
}

/// Sequential reference gather sum (test oracle for [`gather_sum`]).
#[inline]
pub fn gather_sum_naive(vals: &[f64], idx: &[usize]) -> f64 {
    idx.iter().map(|&i| vals[i]).sum()
}

/// Elementwise `a - b`.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Mean of a slice.
#[inline]
pub fn mean(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    a.iter().sum::<f64>() / a.len() as f64
}

/// Population variance of a slice.
pub fn variance(a: &[f64]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let m = mean(a);
    a.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / a.len() as f64
}

/// Column-panel width of the blocked `matmul`: 1024 f64 = 8 KiB per
/// streamed row, so the four B-row panels plus the output panel sit in L1.
const MATMUL_COL_BLOCK: usize = 1024;

impl Matrix {
    /// `self * v` for a column vector `v` (backend-dispatched kernel).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_into(v, &mut out);
        out
    }

    /// `self * v` written into a caller-owned buffer (resized to fit) —
    /// the allocation-free variant the solver workspaces use in their hot
    /// loops. Each row is reduced with the backend-dispatched [`dot`].
    pub fn matvec_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.cols(), "matvec: dimension mismatch");
        let be = backend();
        out.clear();
        out.extend((0..self.rows()).map(|i| be.dot(self.row(i), v)));
    }

    /// Sequential reference `self * v` (test oracle for
    /// [`Matrix::matvec`]; left-to-right summation per row, no dispatch).
    pub fn matvec_naive(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols(), "matvec: dimension mismatch");
        (0..self.rows()).map(|i| dot_naive(self.row(i), v)).collect()
    }

    /// `selfᵀ * v` — computed without materializing the transpose
    /// (backend-dispatched kernel).
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        self.matvec_t_into(v, &mut out);
        out
    }

    /// `selfᵀ * v` written into a caller-owned buffer (resized to fit).
    /// Rows are consumed four at a time, fusing four scaled-row updates
    /// into one backend-dispatched [`fused4`] pass over the output — 4×
    /// fewer output-buffer sweeps than the row-at-a-time formulation.
    pub fn matvec_t_into(&self, v: &[f64], out: &mut Vec<f64>) {
        assert_eq!(v.len(), self.rows(), "matvec_t: dimension mismatch");
        let be = backend();
        let p = self.cols();
        out.clear();
        out.resize(p, 0.0);
        let mut i = 0;
        while i + 4 <= self.rows() {
            let (v0, v1, v2, v3) = (v[i], v[i + 1], v[i + 2], v[i + 3]);
            if v0 != 0.0 || v1 != 0.0 || v2 != 0.0 || v3 != 0.0 {
                be.fused4(
                    [v0, v1, v2, v3],
                    self.row(i),
                    self.row(i + 1),
                    self.row(i + 2),
                    self.row(i + 3),
                    out,
                );
            }
            i += 4;
        }
        while i < self.rows() {
            if v[i] != 0.0 {
                be.axpy(v[i], self.row(i), out);
            }
            i += 1;
        }
    }

    /// Sequential reference `selfᵀ * v` (test oracle for
    /// [`Matrix::matvec_t`]; one scaled-row accumulation per row, written
    /// out inline so the oracle never dispatches).
    pub fn matvec_t_naive(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows(), "matvec_t: dimension mismatch");
        let mut out = vec![0.0; self.cols()];
        for (i, &vi) in v.iter().enumerate() {
            if vi != 0.0 {
                for (o, x) in out.iter_mut().zip(self.row(i)) {
                    *o += vi * x;
                }
            }
        }
        out
    }

    /// Matrix product `self * other` (backend-dispatched kernel): ikj
    /// loop order with the k dimension unrolled four-wide (one fused
    /// [`fused4`] pass over the output row per four A-coefficients) and
    /// the output row processed in L1-sized column panels
    /// ([`MATMUL_COL_BLOCK`]).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols(), other.rows(), "matmul: dimension mismatch");
        let be = backend();
        let (m, kdim, n) = (self.rows(), self.cols(), other.cols());
        let mut out = Matrix::zeros(m, n);
        let od = out.data_mut();
        for i in 0..m {
            let a_row = self.row(i);
            let orow = &mut od[i * n..(i + 1) * n];
            let mut jb = 0;
            while jb < n {
                let je = (jb + MATMUL_COL_BLOCK).min(n);
                let opanel = &mut orow[jb..je];
                let mut kk = 0;
                while kk + 4 <= kdim {
                    let (a0, a1, a2, a3) =
                        (a_row[kk], a_row[kk + 1], a_row[kk + 2], a_row[kk + 3]);
                    if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                        be.fused4(
                            [a0, a1, a2, a3],
                            &other.row(kk)[jb..je],
                            &other.row(kk + 1)[jb..je],
                            &other.row(kk + 2)[jb..je],
                            &other.row(kk + 3)[jb..je],
                            opanel,
                        );
                    }
                    kk += 4;
                }
                while kk < kdim {
                    let a = a_row[kk];
                    if a != 0.0 {
                        be.axpy(a, &other.row(kk)[jb..je], opanel);
                    }
                    kk += 1;
                }
                jb = je;
            }
        }
        out
    }

    /// Sequential reference `self * other` (test oracle for
    /// [`Matrix::matmul`]; ikj order, one scaled-row update per k,
    /// written out inline so the oracle never dispatches).
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols(), other.rows(), "matmul: dimension mismatch");
        let (m, k) = (self.rows(), self.cols());
        let mut out = Matrix::zeros(m, other.cols());
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (kk, &a) in a_row.iter().enumerate().take(k) {
                if a != 0.0 {
                    for (o, x) in out_row.iter_mut().zip(other.row(kk)) {
                        *o += a * x;
                    }
                }
            }
        }
        out
    }

    /// Gram matrix `selfᵀ * self` (backend-dispatched kernel): rows are
    /// consumed four at a time as fused rank-4 [`fused4`] updates of the
    /// upper triangle (4× fewer triangle sweeps than the rank-1
    /// formulation), then mirrored.
    pub fn gram(&self) -> Matrix {
        let be = backend();
        let p = self.cols();
        let n = self.rows();
        let mut g = Matrix::zeros(p, p);
        let gd = g.data_mut();
        let mut i = 0;
        while i + 4 <= n {
            let r0 = self.row(i);
            let r1 = self.row(i + 1);
            let r2 = self.row(i + 2);
            let r3 = self.row(i + 3);
            for a in 0..p {
                let (x0, x1, x2, x3) = (r0[a], r1[a], r2[a], r3[a]);
                if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                    continue;
                }
                be.fused4(
                    [x0, x1, x2, x3],
                    &r0[a..],
                    &r1[a..],
                    &r2[a..],
                    &r3[a..],
                    &mut gd[a * p + a..(a + 1) * p],
                );
            }
            i += 4;
        }
        while i < n {
            let row = self.row(i);
            for a in 0..p {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                be.axpy(ra, &row[a..], &mut gd[a * p + a..(a + 1) * p]);
            }
            i += 1;
        }
        // Mirror through the flat buffer (get/set would re-drop the norm
        // memo per element).
        for a in 0..p {
            for b in 0..a {
                gd[a * p + b] = gd[b * p + a];
            }
        }
        g
    }

    /// Sequential reference Gram matrix (test oracle for
    /// [`Matrix::gram`]; rank-1 row updates of the upper triangle, no
    /// dispatch).
    pub fn gram_naive(&self) -> Matrix {
        let p = self.cols();
        let mut g = Matrix::zeros(p, p);
        for i in 0..self.rows() {
            let row = self.row(i);
            for a in 0..p {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = g.row_mut(a);
                for (b, &rb) in row.iter().enumerate().skip(a) {
                    grow[b] += ra * rb;
                }
            }
        }
        for a in 0..p {
            for b in 0..a {
                let v = g.get(b, a);
                g.set(a, b, v);
            }
        }
        g
    }

    /// Fused residual `out[i] = y[i] − offset − rowᵢ·beta`, i.e. the
    /// regression residual `y − Xβ − intercept` in a single pass over the
    /// matrix — no intermediate prediction buffer, one backend-dispatched
    /// [`dot`] per row. `out` is cleared and resized to `rows()`; it must
    /// be a distinct buffer from `y` (the borrow checker enforces this).
    pub fn residual_into(&self, beta: &[f64], y: &[f64], offset: f64, out: &mut Vec<f64>) {
        assert_eq!(beta.len(), self.cols(), "residual_into: beta dimension mismatch");
        assert_eq!(y.len(), self.rows(), "residual_into: y dimension mismatch");
        let be = backend();
        out.clear();
        out.extend(
            y.iter()
                .enumerate()
                .map(|(i, &yi)| yi - offset - be.dot(self.row(i), beta)),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-10
    }

    #[test]
    fn dot_norm_axpy() {
        assert!(approx(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0));
        assert!(approx(norm2(&[3.0, 4.0]), 5.0));
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
    }

    #[test]
    fn dot_matches_naive_across_lengths() {
        for len in 0..19 {
            let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.7).sin()).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64 * 1.3).cos()).collect();
            assert!(approx(dot(&a, &b), dot_naive(&a, &b)), "len={len}");
            assert!(approx(dot_blocked(&a, &b), dot_naive(&a, &b)), "len={len}");
        }
    }

    #[test]
    fn sqdist_blocked_matches_naive_across_lengths() {
        for len in 0..19 {
            let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.9).sin() * 2.0).collect();
            let b: Vec<f64> = (0..len).map(|i| (i as f64 * 0.4).cos() * 3.0).collect();
            assert!(approx(sqdist(&a, &b), sqdist_naive(&a, &b)), "len={len}");
            assert!(approx(sqdist_blocked(&a, &b), sqdist_naive(&a, &b)), "len={len}");
        }
    }

    #[test]
    fn gather_sum_matches_naive_across_lengths() {
        let vals: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
        for len in 0..23 {
            let idx: Vec<usize> = (0..len).map(|i| (i * 17) % vals.len()).collect();
            assert!(
                approx(gather_sum(&vals, &idx), gather_sum_naive(&vals, &idx)),
                "len={len}"
            );
            assert!(
                approx(gather_sum_blocked(&vals, &idx), gather_sum_naive(&vals, &idx)),
                "len={len}"
            );
        }
    }

    #[test]
    fn fused4_matches_explicit_expansion() {
        for len in [0usize, 1, 3, 4, 7, 12] {
            let r0: Vec<f64> = (0..len).map(|i| i as f64 * 0.5).collect();
            let r1: Vec<f64> = (0..len).map(|i| 1.0 - i as f64 * 0.25).collect();
            let r2: Vec<f64> = (0..len).map(|i| (i as f64).cos()).collect();
            let r3: Vec<f64> = (0..len).map(|i| (i as f64).sin()).collect();
            let c = [2.0, -1.0, 0.5, 0.25];
            let mut out = vec![1.0; len];
            fused4(c, &r0, &r1, &r2, &r3, &mut out);
            for j in 0..len {
                let want = 1.0 + c[0] * r0[j] + c[1] * r1[j] + c[2] * r2[j] + c[3] * r3[j];
                assert!(approx(out[j], want), "len={len} j={j}");
            }
        }
    }

    #[test]
    fn centered_accumulate_matches_explicit_loop() {
        let row: Vec<f64> = (0..11).map(|i| i as f64 * 0.7).collect();
        let means: Vec<f64> = (0..11).map(|i| i as f64 * 0.1).collect();
        let (mut num, mut den) = (vec![0.5; 11], vec![0.25; 11]);
        centered_accumulate(&row, &means, 1.5, &mut num, &mut den);
        for j in 0..11 {
            let c = row[j] - means[j];
            assert!(approx(num[j], 0.5 + c * 1.5), "num[{j}]");
            assert!(approx(den[j], 0.25 + c * c), "den[{j}]");
        }
    }

    #[test]
    fn matvec_matches_manual() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0, 11.0]);
        assert_eq!(m.matvec_t(&[1.0, 0.0, 1.0]), vec![6.0, 8.0]);
    }

    #[test]
    fn matvec_t_equals_transpose_matvec() {
        let m = Matrix::from_rows(&[vec![1.0, -2.0, 0.5], vec![0.0, 3.0, 1.0]]);
        let v = vec![2.0, -1.0];
        let a = m.matvec_t(&v);
        let b = m.transpose().matvec(&v);
        for (x, y) in a.iter().zip(&b) {
            assert!(approx(*x, *y), "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn blocked_kernels_match_naive_on_awkward_shapes() {
        // Shapes straddling the 4-wide unroll boundaries.
        for (r, c) in [(1, 1), (3, 5), (4, 4), (5, 3), (7, 9), (8, 8), (9, 2)] {
            let a = Matrix::from_vec(
                r,
                c,
                (0..r * c).map(|i| ((i * 37 % 19) as f64 - 9.0) * 0.25).collect(),
            );
            let v: Vec<f64> = (0..c).map(|i| (i as f64 - 1.5) * 0.5).collect();
            let w: Vec<f64> = (0..r).map(|i| (i as f64 - 2.0) * 0.75).collect();
            for (x, y) in a.matvec(&v).iter().zip(a.matvec_naive(&v)) {
                assert!(approx(*x, y));
            }
            for (x, y) in a.matvec_t(&w).iter().zip(a.matvec_t_naive(&w)) {
                assert!(approx(*x, y));
            }
            let b = Matrix::from_vec(
                c,
                r,
                (0..r * c).map(|i| ((i * 11 % 13) as f64 - 6.0) * 0.5).collect(),
            );
            let fast = a.matmul(&b);
            let slow = a.matmul_naive(&b);
            let gf = a.gram();
            let gs = a.gram_naive();
            for i in 0..r {
                for j in 0..r {
                    assert!(approx(fast.get(i, j), slow.get(i, j)));
                }
            }
            for i in 0..c {
                for j in 0..c {
                    assert!(approx(gf.get(i, j), gs.get(i, j)));
                }
            }
        }
    }

    #[test]
    fn matmul_small() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[2.0, 1.0]);
        assert_eq!(c.row(1), &[4.0, 3.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[vec![1.5, -2.0, 3.0], vec![0.0, 1.0, 2.0]]);
        let i3 = Matrix::eye(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn gram_matches_explicit_transpose_product() {
        let a = Matrix::from_rows(&[
            vec![1.0, 2.0, 0.0],
            vec![-1.0, 0.5, 2.0],
            vec![3.0, 1.0, 1.0],
            vec![0.0, 0.0, 4.0],
        ]);
        let g = a.gram();
        let g2 = a.transpose().matmul(&a);
        for i in 0..3 {
            for j in 0..3 {
                assert!(approx(g.get(i, j), g2.get(i, j)));
            }
        }
    }

    #[test]
    fn residual_into_matches_unfused() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, -1.0], vec![0.5, 0.5]]);
        let beta = vec![2.0, -1.0];
        let y = vec![1.0, 4.0, -2.0];
        let mut out = vec![99.0; 7]; // stale contents must be overwritten
        x.residual_into(&beta, &y, 0.25, &mut out);
        let pred = x.matvec(&beta);
        assert_eq!(out.len(), 3);
        for i in 0..3 {
            assert!(approx(out[i], y[i] - 0.25 - pred[i]));
        }
    }

    #[test]
    fn stats_helpers() {
        assert!(approx(mean(&[1.0, 2.0, 3.0]), 2.0));
        assert!(approx(variance(&[1.0, 2.0, 3.0]), 2.0 / 3.0));
        assert!(approx(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0));
    }
}
