//! Row-major dense matrix.

use std::fmt;
use std::sync::OnceLock;

/// Lazily-computed squared-norm caches ([`Matrix::row_sq_norms`] /
/// [`Matrix::col_sq_norms`]). Invalidated wholesale by every `&mut`
/// accessor; excluded from equality and (being `OnceLock`) safe to share
/// across the parallel scheduler's worker threads.
#[derive(Debug, Default)]
struct NormCache {
    rows: OnceLock<Vec<f64>>,
    cols: OnceLock<Vec<f64>>,
}

impl Clone for NormCache {
    fn clone(&self) -> Self {
        let fresh = NormCache::default();
        if let Some(r) = self.rows.get() {
            let _ = fresh.rows.set(r.clone());
        }
        if let Some(c) = self.cols.get() {
            let _ = fresh.cols.set(c.clone());
        }
        fresh
    }
}

/// Dense row-major `f64` matrix.
///
/// Row-major layout is chosen because the dominant access patterns in this
/// crate are (i) per-sample row scans (tree solvers, k-means) and (ii)
/// column gathers into contiguous sub-matrices (subproblem construction),
/// which we materialize explicitly via [`Matrix::select_columns`].
///
/// Squared row/column norms are memoized on first use (see
/// [`Matrix::row_sq_norms`]); every mutating accessor drops the memo, so
/// cached values can never go stale. Equality and `Debug` ignore the
/// cache.
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
    norms: NormCache,
}

impl Clone for Matrix {
    fn clone(&self) -> Self {
        Self {
            rows: self.rows,
            cols: self.cols,
            data: self.data.clone(),
            norms: self.norms.clone(),
        }
    }

    /// Field-wise `clone_from` so scratch matrices (`Matrix` fields in
    /// solver workspaces) reuse their existing buffer instead of
    /// reallocating per call.
    fn clone_from(&mut self, source: &Self) {
        self.rows = source.rows;
        self.cols = source.cols;
        self.data.clone_from(&source.data);
        self.norms = source.norms.clone();
    }
}

impl PartialEq for Matrix {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.data == other.data
    }
}

impl Default for Matrix {
    /// Empty 0×0 matrix — lets solver workspaces hold reusable matrix
    /// buffers while deriving/implementing `Default`.
    fn default() -> Self {
        Matrix::zeros(0, 0)
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows <= 8 && self.cols <= 8 {
            writeln!(f)?;
            for i in 0..self.rows {
                writeln!(f, "  {:?}", self.row(i))?;
            }
        }
        Ok(())
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols], norms: NormCache::default() }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: buffer size mismatch");
        Self { rows, cols, data, norms: NormCache::default() }
    }

    /// Build from nested rows.
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "from_rows: ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data, norms: NormCache::default() }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.invalidate_norms();
        self.data[i * self.cols + j] = v;
    }

    /// Drop the memoized squared norms (called by every `&mut` accessor;
    /// cheap — no allocation).
    #[inline]
    fn invalidate_norms(&mut self) {
        self.norms = NormCache::default();
    }

    /// Squared Euclidean norm of every row, memoized on first call.
    ///
    /// Caching contract: the memo is dropped by every mutating accessor
    /// (`set`, `row_mut`, `data_mut`, `select_*_into` on the output,
    /// `standardize_columns`), so the returned slice always reflects the
    /// current contents. First call is O(rows·cols); subsequent calls on
    /// an unmutated matrix are O(1). Thread-safe: concurrent first calls
    /// race benignly inside `OnceLock`.
    pub fn row_sq_norms(&self) -> &[f64] {
        self.norms.rows.get_or_init(|| {
            (0..self.rows).map(|i| super::dot(self.row(i), self.row(i))).collect()
        })
    }

    /// Squared Euclidean norm of every column, memoized on first call
    /// (same caching contract as [`Matrix::row_sq_norms`]). Computed in a
    /// single row-major pass.
    pub fn col_sq_norms(&self) -> &[f64] {
        self.norms.cols.get_or_init(|| {
            let mut out = vec![0.0; self.cols];
            for i in 0..self.rows {
                for (o, &v) in out.iter_mut().zip(self.row(i)) {
                    *o += v * v;
                }
            }
            out
        })
    }

    /// Contiguous view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        self.invalidate_norms();
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Flat row-major data.
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Flat mutable row-major data.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        self.invalidate_norms();
        &mut self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// New matrix containing the given columns (in the given order).
    /// This is the subproblem-construction primitive: restrict the design
    /// matrix to a feature subset.
    pub fn select_columns(&self, cols: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, cols.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (jj, &j) in cols.iter().enumerate() {
                dst[jj] = src[j];
            }
        }
        out
    }

    /// Column selection into a caller-owned matrix (reshaped to fit) — the
    /// allocation-free variant the subproblem workspaces use so repeated
    /// fits reuse one design-matrix buffer.
    pub fn select_columns_into(&self, cols: &[usize], out: &mut Matrix) {
        out.invalidate_norms();
        out.rows = self.rows;
        out.cols = cols.len();
        out.data.clear();
        out.data.resize(self.rows * cols.len(), 0.0);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = &mut out.data[i * cols.len()..(i + 1) * cols.len()];
            for (jj, &j) in cols.iter().enumerate() {
                dst[jj] = src[j];
            }
        }
    }

    /// New matrix containing the given rows (in the given order).
    pub fn select_rows(&self, rows: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(rows.len(), self.cols);
        for (ii, &i) in rows.iter().enumerate() {
            out.row_mut(ii).copy_from_slice(self.row(i));
        }
        out
    }

    /// Row selection into a caller-owned matrix (reshaped to fit).
    pub fn select_rows_into(&self, rows: &[usize], out: &mut Matrix) {
        out.invalidate_norms();
        out.rows = rows.len();
        out.cols = self.cols;
        out.data.clear();
        out.data.resize(rows.len() * self.cols, 0.0);
        for (ii, &i) in rows.iter().enumerate() {
            out.row_mut(ii).copy_from_slice(self.row(i));
        }
    }

    /// Pad with zero columns on the right up to `target_cols` (used to fit
    /// shape-bucketed PJRT executables; zero columns are inert for the
    /// correlation/IHT kernels — see runtime tests).
    pub fn pad_columns(&self, target_cols: usize) -> Matrix {
        assert!(target_cols >= self.cols);
        if target_cols == self.cols {
            return self.clone();
        }
        let mut out = Matrix::zeros(self.rows, target_cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Pad with zero rows at the bottom up to `target_rows`.
    pub fn pad_rows(&self, target_rows: usize) -> Matrix {
        assert!(target_rows >= self.rows);
        if target_rows == self.rows {
            return self.clone();
        }
        let mut out = Matrix::zeros(target_rows, self.cols);
        out.data[..self.rows * self.cols].copy_from_slice(&self.data);
        out
    }

    /// Convert to `f32` row-major (PJRT artifacts are compiled in f32).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Column means.
    pub fn col_means(&self) -> Vec<f64> {
        let mut means = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (m, &v) in means.iter_mut().zip(self.row(i)) {
                *m += v;
            }
        }
        let n = self.rows.max(1) as f64;
        means.iter_mut().for_each(|m| *m /= n);
        means
    }

    /// Column standard deviations (population, i.e. divide by n).
    pub fn col_stds(&self) -> Vec<f64> {
        let means = self.col_means();
        let mut vars = vec![0.0; self.cols];
        for i in 0..self.rows {
            for ((v, &m), &x) in vars.iter_mut().zip(&means).zip(self.row(i)) {
                let d = x - m;
                *v += d * d;
            }
        }
        let n = self.rows.max(1) as f64;
        vars.iter_mut().for_each(|v| *v = (*v / n).sqrt());
        vars
    }

    /// Standardize columns to zero mean / unit std in place; returns the
    /// (mean, std) pairs used so predictions can be mapped back. Columns
    /// with zero variance are left centered with std recorded as 1.
    pub fn standardize_columns(&mut self) -> Vec<(f64, f64)> {
        let means = self.col_means();
        let stds = self.col_stds();
        let scale: Vec<f64> =
            stds.iter().map(|&s| if s > 1e-12 { s } else { 1.0 }).collect();
        self.invalidate_norms();
        for i in 0..self.rows {
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for j in 0..row.len() {
                row[j] = (row[j] - means[j]) / scale[j];
            }
        }
        means.into_iter().zip(scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(1, 0), 3.0);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.col(1), vec![2.0, 4.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn select_columns_order_preserved() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let s = m.select_columns(&[2, 0]);
        assert_eq!(s.row(0), &[3.0, 1.0]);
        assert_eq!(s.row(1), &[6.0, 4.0]);
    }

    #[test]
    fn select_rows_subset() {
        let m = Matrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.col(0), vec![3.0, 1.0]);
    }

    #[test]
    fn padding() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0]]);
        let pc = m.pad_columns(4);
        assert_eq!(pc.row(0), &[1.0, 2.0, 0.0, 0.0]);
        let pr = m.pad_rows(3);
        assert_eq!(pr.rows(), 3);
        assert_eq!(pr.row(2), &[0.0, 0.0]);
    }

    #[test]
    fn standardize() {
        let mut m = Matrix::from_rows(&[vec![1.0, 5.0], vec![3.0, 5.0], vec![5.0, 5.0]]);
        let params = m.standardize_columns();
        let means = m.col_means();
        assert!(means.iter().all(|m| m.abs() < 1e-12));
        // First column had std sqrt(8/3); second is constant → scale 1.
        assert!((params[0].0 - 3.0).abs() < 1e-12);
        assert!((params[1].1 - 1.0).abs() < 1e-12);
        assert_eq!(m.get(0, 1), 0.0);
    }

    #[test]
    fn sq_norm_caches_track_mutation() {
        let mut m = Matrix::from_rows(&[vec![3.0, 4.0], vec![0.0, 2.0]]);
        assert_eq!(m.row_sq_norms(), &[25.0, 4.0]);
        assert_eq!(m.col_sq_norms(), &[9.0, 20.0]);
        // Cached: a second call sees the same values.
        assert_eq!(m.row_sq_norms(), &[25.0, 4.0]);
        // Any mutation drops the memo.
        m.set(0, 0, 0.0);
        assert_eq!(m.row_sq_norms(), &[16.0, 4.0]);
        m.row_mut(1)[1] = 1.0;
        assert_eq!(m.col_sq_norms(), &[0.0, 17.0]);
        // select_*_into invalidates the *output* buffer's memo.
        let mut buf = Matrix::from_rows(&[vec![9.0]]);
        let _ = buf.row_sq_norms();
        m.select_columns_into(&[1], &mut buf);
        assert_eq!(buf.row_sq_norms(), &[16.0, 1.0]);
        // Clones keep (an equally valid copy of) the memo; equality
        // ignores it.
        let c = m.clone();
        assert_eq!(c, m);
        assert_eq!(c.row_sq_norms(), m.row_sq_norms());
    }

    #[test]
    fn eye_and_frobenius() {
        let i3 = Matrix::eye(3);
        assert!((i3.frobenius_norm() - 3f64.sqrt()).abs() < 1e-12);
    }
}
