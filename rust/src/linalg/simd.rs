//! AVX2 vectorized kernels — the `ComputeBackend::Simd` implementation.
//!
//! This is the **only module in the crate containing `unsafe` code**. It
//! is compiled on `x86_64` targets only (and excluded under Miri, which
//! cannot interpret vendor intrinsics — see `linalg::backend`); every
//! other target resolves the SIMD backend to the blocked scalar kernels.
//!
//! ## Bit-identity contract
//!
//! Each kernel here is constructed to be **bit-identical** to its blocked
//! scalar counterpart in [`super::ops`], not merely close:
//!
//! - The four SIMD lanes hold exactly the four independent accumulators
//!   `s0..s3` of the blocked scalar kernels, so lane *l* performs the
//!   same sequence of IEEE-754 operations on the same values as scalar
//!   accumulator *l*.
//! - Horizontal reduction combines lanes as `(s0 + s2) + (s1 + s3)` —
//!   the same association the scalar kernels use.
//! - Remainder tails are the same sequential scalar loops.
//! - Only `vmulpd`/`vaddpd`/`vsubpd` are used — **no FMA**. A fused
//!   multiply-add skips the intermediate rounding of the separate
//!   multiply and would produce different (slightly more accurate)
//!   results than the scalar backend, breaking the cross-backend
//!   bit-identity that lets `BACKBONE_BACKEND` be a pure wall-clock
//!   knob. FMA presence is still detected and reported in the bench
//!   hardware fingerprint; using it is future work that would require
//!   relaxing the backend-identity tests to a tolerance.
//!
//! Since every IEEE-754 scalar operation is exactly rounded and the two
//! implementations perform the same operations in the same order, the
//! outputs are bit-for-bit equal — enforced by `tests/prop_linalg.rs`
//! (kernel-level) and `tests/parallel_determinism.rs` (whole-fit level).
//!
//! ## Safety
//!
//! The `unsafe` surface is exactly the `#[target_feature(enable =
//! "avx2")]` kernel bodies. The public wrappers check
//! `is_x86_feature_detected!("avx2")` and fall back to the blocked
//! scalar kernels when AVX2 is absent, so **every public function in
//! this module is safe to call on any x86-64 CPU**. All loads/stores go
//! through `chunks_exact` slices (`loadu`/`storeu` on 4-element chunks),
//! so no out-of-bounds access is possible.

use super::ops;

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// True when the AVX2 kernels below are usable on this CPU.
#[inline]
fn avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Horizontal sum with the blocked-kernel association: lanes
/// `[s0, s1, s2, s3]` → `(s0 + s2) + (s1 + s3)`.
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn hsum_blocked(acc: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(acc); // [s0, s1]
    let hi = _mm256_extractf128_pd(acc, 1); // [s2, s3]
    let pair = _mm_add_pd(lo, hi); // [s0+s2, s1+s3]
    _mm_cvtsd_f64(pair) + _mm_cvtsd_f64(_mm_unpackhi_pd(pair, pair))
}

/// Dot product (AVX2). Bit-identical to [`ops::dot_blocked`].
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if !avx2() {
        return ops::dot_blocked(a, b);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { dot_avx2(a, b) }
}

#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    let split = a.len() - a.len() % 4;
    let (a4, at) = a.split_at(split);
    let (b4, bt) = b.split_at(split);
    let mut acc = _mm256_setzero_pd();
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        let va = _mm256_loadu_pd(ca.as_ptr());
        let vb = _mm256_loadu_pd(cb.as_ptr());
        acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
    }
    let mut s = hsum_blocked(acc);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// `y += alpha * x` (AVX2). Elementwise, so bit-identical to
/// [`ops::axpy_blocked`] by construction.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if !avx2() {
        return ops::axpy_blocked(alpha, x, y);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { axpy_avx2(alpha, x, y) }
}

#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    let split = x.len() - x.len() % 4;
    let (x4, xt) = x.split_at(split);
    let (y4, yt) = y.split_at_mut(split);
    let va = _mm256_set1_pd(alpha);
    for (cy, cx) in y4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        let vx = _mm256_loadu_pd(cx.as_ptr());
        let vy = _mm256_loadu_pd(cy.as_ptr());
        _mm256_storeu_pd(cy.as_mut_ptr(), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
    }
    for (yi, xi) in yt.iter_mut().zip(xt) {
        *yi += alpha * xi;
    }
}

/// Squared Euclidean distance (AVX2). Bit-identical to
/// [`ops::sqdist_blocked`].
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    if !avx2() {
        return ops::sqdist_blocked(a, b);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { sqdist_avx2(a, b) }
}

#[target_feature(enable = "avx2")]
unsafe fn sqdist_avx2(a: &[f64], b: &[f64]) -> f64 {
    let split = a.len() - a.len() % 4;
    let (a4, at) = a.split_at(split);
    let (b4, bt) = b.split_at(split);
    let mut acc = _mm256_setzero_pd();
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        let d = _mm256_sub_pd(_mm256_loadu_pd(ca.as_ptr()), _mm256_loadu_pd(cb.as_ptr()));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
    }
    let mut s = hsum_blocked(acc);
    for (x, y) in at.iter().zip(bt) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Fused rank-4 row update `out[j] += c0·r0[j] + c1·r1[j] + c2·r2[j] +
/// c3·r3[j]` (AVX2) — the inner step of `matvec_t`, `matmul` panels, and
/// `gram`. Elementwise in `j` with the same left-associated sum, so
/// bit-identical to [`ops::fused4_blocked`].
#[inline]
pub fn fused4(c: [f64; 4], r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], out: &mut [f64]) {
    debug_assert!(
        r0.len() >= out.len()
            && r1.len() >= out.len()
            && r2.len() >= out.len()
            && r3.len() >= out.len()
    );
    if !avx2() {
        return ops::fused4_blocked(c, r0, r1, r2, r3, out);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { fused4_avx2(c, r0, r1, r2, r3, out) }
}

#[target_feature(enable = "avx2")]
unsafe fn fused4_avx2(
    c: [f64; 4],
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    out: &mut [f64],
) {
    let m = out.len();
    // Hard bounds guarantee for the unchecked vector loads below (panics
    // on violation even in release builds, unlike a debug_assert).
    let (r0, r1, r2, r3) = (&r0[..m], &r1[..m], &r2[..m], &r3[..m]);
    let split = m - m % 4;
    let (vc0, vc1, vc2, vc3) = (
        _mm256_set1_pd(c[0]),
        _mm256_set1_pd(c[1]),
        _mm256_set1_pd(c[2]),
        _mm256_set1_pd(c[3]),
    );
    let (o4, ot) = out.split_at_mut(split);
    for (j4, co) in o4.chunks_exact_mut(4).enumerate() {
        let j = j4 * 4;
        // Left-associated, matching `c0*r0[j] + c1*r1[j] + c2*r2[j] + c3*r3[j]`.
        let mut t = _mm256_mul_pd(vc0, _mm256_loadu_pd(r0.as_ptr().add(j)));
        t = _mm256_add_pd(t, _mm256_mul_pd(vc1, _mm256_loadu_pd(r1.as_ptr().add(j))));
        t = _mm256_add_pd(t, _mm256_mul_pd(vc2, _mm256_loadu_pd(r2.as_ptr().add(j))));
        t = _mm256_add_pd(t, _mm256_mul_pd(vc3, _mm256_loadu_pd(r3.as_ptr().add(j))));
        let vo = _mm256_loadu_pd(co.as_ptr());
        _mm256_storeu_pd(co.as_mut_ptr(), _mm256_add_pd(vo, t));
    }
    for (j, o) in ot.iter_mut().enumerate() {
        let j = split + j;
        *o += c[0] * r0[j] + c[1] * r1[j] + c[2] * r2[j] + c[3] * r3[j];
    }
}

/// Centered correlation accumulate: `num[j] += (row[j] − means[j])·w`,
/// `den[j] += (row[j] − means[j])²` (AVX2) — the sparse-regression
/// screener's per-row step. Elementwise, bit-identical to
/// [`ops::centered_accumulate_blocked`].
#[inline]
pub fn centered_accumulate(row: &[f64], means: &[f64], w: f64, num: &mut [f64], den: &mut [f64]) {
    debug_assert_eq!(row.len(), means.len());
    debug_assert_eq!(row.len(), num.len());
    debug_assert_eq!(row.len(), den.len());
    if !avx2() {
        return ops::centered_accumulate_blocked(row, means, w, num, den);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { centered_accumulate_avx2(row, means, w, num, den) }
}

#[target_feature(enable = "avx2")]
unsafe fn centered_accumulate_avx2(
    row: &[f64],
    means: &[f64],
    w: f64,
    num: &mut [f64],
    den: &mut [f64],
) {
    let p = num.len();
    // Hard bounds guarantee for the unchecked vector loads below.
    let (row, means) = (&row[..p], &means[..p]);
    let split = p - p % 4;
    let vw = _mm256_set1_pd(w);
    let (n4, nt) = num.split_at_mut(split);
    let (d4, dt) = den.split_at_mut(split);
    for (j4, (cn, cd)) in n4.chunks_exact_mut(4).zip(d4.chunks_exact_mut(4)).enumerate() {
        let j = j4 * 4;
        let c = _mm256_sub_pd(
            _mm256_loadu_pd(row.as_ptr().add(j)),
            _mm256_loadu_pd(means.as_ptr().add(j)),
        );
        let vn = _mm256_loadu_pd(cn.as_ptr());
        _mm256_storeu_pd(cn.as_mut_ptr(), _mm256_add_pd(vn, _mm256_mul_pd(c, vw)));
        let vd = _mm256_loadu_pd(cd.as_ptr());
        _mm256_storeu_pd(cd.as_mut_ptr(), _mm256_add_pd(vd, _mm256_mul_pd(c, c)));
    }
    for (j, (n, d)) in nt.iter_mut().zip(dt).enumerate() {
        let j = split + j;
        let c = row[j] - means[j];
        *n += c * w;
        *d += c * c;
    }
}

/// Indexed gather sum `Σ vals[idx[i]]` (AVX2) — the CART split scan's
/// label-mass reduction. Four gathered lanes mirror the four scalar
/// accumulators; bit-identical to [`ops::gather_sum_blocked`].
#[inline]
pub fn gather_sum(vals: &[f64], idx: &[usize]) -> f64 {
    if !avx2() {
        return ops::gather_sum_blocked(vals, idx);
    }
    // SAFETY: AVX2 presence checked above.
    unsafe { gather_sum_avx2(vals, idx) }
}

#[target_feature(enable = "avx2")]
unsafe fn gather_sum_avx2(vals: &[f64], idx: &[usize]) -> f64 {
    let split = idx.len() - idx.len() % 4;
    let (i4, it) = idx.split_at(split);
    let mut acc = _mm256_setzero_pd();
    for c in i4.chunks_exact(4) {
        // Indexed loads stay bounds-checked; only the vector add is wide.
        let v = _mm256_set_pd(vals[c[3]], vals[c[2]], vals[c[1]], vals[c[0]]);
        acc = _mm256_add_pd(acc, v);
    }
    let mut s = hsum_blocked(acc);
    for &i in it {
        s += vals[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(len: usize) -> (Vec<f64>, Vec<f64>) {
        let a: Vec<f64> = (0..len).map(|i| (i as f64 * 0.7).sin() * 3.0).collect();
        let b: Vec<f64> = (0..len).map(|i| (i as f64 * 1.3).cos() * 2.0).collect();
        (a, b)
    }

    #[test]
    fn simd_kernels_bit_identical_to_blocked_scalar() {
        // On non-AVX2 hardware the wrappers fall back to the blocked
        // kernels, so these hold trivially; on AVX2 hardware they verify
        // the lane-accumulator construction.
        for len in [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 63, 100] {
            let (a, b) = vecs(len);
            assert_eq!(dot(&a, &b).to_bits(), ops::dot_blocked(&a, &b).to_bits(), "dot len={len}");
            assert_eq!(
                sqdist(&a, &b).to_bits(),
                ops::sqdist_blocked(&a, &b).to_bits(),
                "sqdist len={len}"
            );
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            axpy(0.37, &a, &mut y1);
            ops::axpy_blocked(0.37, &a, &mut y2);
            assert_eq!(y1, y2, "axpy len={len}");
        }
    }

    #[test]
    fn simd_fused4_and_accumulators_bit_identical() {
        for len in [0, 1, 3, 4, 6, 8, 11, 32, 50] {
            let (r0, r1) = vecs(len);
            let r2: Vec<f64> = r0.iter().map(|v| v * 0.5 - 1.0).collect();
            let r3: Vec<f64> = r1.iter().map(|v| v * -0.25 + 2.0).collect();
            let c = [1.5, -0.5, 0.25, 2.0];
            let mut o1 = vec![0.125; len];
            let mut o2 = vec![0.125; len];
            fused4(c, &r0, &r1, &r2, &r3, &mut o1);
            ops::fused4_blocked(c, &r0, &r1, &r2, &r3, &mut o2);
            assert_eq!(o1, o2, "fused4 len={len}");

            let (mut n1, mut d1) = (vec![0.5; len], vec![0.25; len]);
            let (mut n2, mut d2) = (vec![0.5; len], vec![0.25; len]);
            centered_accumulate(&r0, &r1, 0.8, &mut n1, &mut d1);
            ops::centered_accumulate_blocked(&r0, &r1, 0.8, &mut n2, &mut d2);
            assert_eq!(n1, n2, "centered num len={len}");
            assert_eq!(d1, d2, "centered den len={len}");

            let idx: Vec<usize> = (0..len).map(|i| (i * 7) % len.max(1)).collect();
            assert_eq!(
                gather_sum(&r0, &idx).to_bits(),
                ops::gather_sum_blocked(&r0, &idx).to_bits(),
                "gather len={len}"
            );
        }
    }
}
