//! Loopback load generator: `cli serve --self-test`.
//!
//! Boots a real [`Server`](super::Server) on an ephemeral localhost port
//! and drives it over real TCP sockets. PR 7 promotes the PR-5 smoke
//! test into a load-test harness:
//!
//! - **Keep-alive phase** — `connections` persistent client
//!   connections, each streaming its share of requests down one socket
//!   (reconnecting only on error). Optional pacing to `--target-rps`
//!   and a wall-clock `--duration` mode.
//! - **Close-mode phase** — the same workload with one connection per
//!   request (`Connection: close`), giving the measured
//!   `keepalive_speedup` ratio (skipped when pacing, which would cap
//!   both phases at the same rate).
//! - **Hot-swap-under-load** — halfway through the keep-alive phase a
//!   coordinator `PUT`s the same artifact back to `/models/default`,
//!   bumping its version while clients hammer it. Every response carries
//!   `model_version`; a version going backwards on any connection is a
//!   boundary violation, and any failed request during the swap is a
//!   drop. Both must be zero. (The server hands every connection its
//!   own handler thread, so parked clients holding keep-alive sockets
//!   can never starve the swap `PUT` out of `accept` — the harness
//!   works at any `connections` count.)
//! - **SLO check** — `--slo-p99-ms` asserts the keep-alive p99.
//! - **Chaos drill** (`--chaos`, PR 9, requires `--features
//!   fault-inject`) — installs a seeded fault schedule (worker panics,
//!   write failures, connection drops, slow reads) and swaps the
//!   benchmark contract for a survival contract: the server stays up,
//!   every failure is a structured JSON error, on-disk artifacts stay
//!   checksum-clean, and `/stats` counters reconcile exactly against
//!   the fired fault counts. See [`run_chaos`] and [`ChaosStats`].
//!
//! Every response is verified against a locally computed prediction for
//! the same batch, so "zero failed requests" means the *served* numbers
//! are bit-identical to the in-process model — not merely that sockets
//! stayed open. (The swapped-in artifact is the same model, so the
//! expectation holds across the version boundary.) CI's `serve-smoke`
//! job runs this end to end and tracks the JSON as `BENCH_PR7.json`.

use super::http::{parse_response, read_response};
use super::{ServeConfig, Server};
use crate::backbone::Predict;
use crate::obs::percentile;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::persist::{LoadedModel, ModelArtifact, Provenance};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct SelfTestConfig {
    /// Total requests across all connections (keep-alive phase);
    /// ignored when `duration_secs` is set.
    pub requests: usize,
    /// Concurrent client connections (each is one OS thread).
    pub connections: usize,
    /// Rows per batched `/predict` request (clustering overrides this
    /// with its transductive row-count contract).
    pub batch_rows: usize,
    /// Server `threads` knob (0 = all cores): sizes online-fit solves
    /// and the report's `threads` field. Serving itself is one handler
    /// thread per connection, so this never limits client concurrency.
    pub threads: usize,
    /// Reuse one connection per client (the keep-alive phase). Off = the
    /// legacy one-connection-per-request behaviour only.
    pub keep_alive: bool,
    /// Also run the close-mode phase and report `keepalive_speedup`.
    pub compare_close: bool,
    /// Hot-swap `/models/default` halfway through the keep-alive phase.
    pub swap_under_load: bool,
    /// Pace the keep-alive phase to this many requests/sec overall.
    pub target_rps: Option<f64>,
    /// Run each phase for this long instead of a fixed request count.
    pub duration_secs: Option<f64>,
    /// Fail the report unless the keep-alive p99 is under this.
    pub slo_p99_ms: Option<f64>,
    /// Chaos mode: install a seeded [`crate::fault::FaultPlan`] and run
    /// a fault-tolerance drill instead of the load benchmark (requires
    /// a build with `--features fault-inject`). Swap-under-load and the
    /// close-mode comparison are skipped — chaos measures survival, not
    /// throughput.
    pub chaos: bool,
    /// Seed for the chaos fault schedule; same seed → same injected
    /// fault sequence.
    pub chaos_seed: u64,
}

impl SelfTestConfig {
    /// CI scale: finishes in seconds on one core.
    pub fn quick() -> Self {
        Self {
            requests: 200,
            connections: 4,
            batch_rows: 16,
            threads: 2,
            keep_alive: true,
            compare_close: true,
            swap_under_load: true,
            target_rps: None,
            duration_secs: None,
            slo_p99_ms: None,
            chaos: false,
            chaos_seed: 42,
        }
    }

    /// Full scale for local benchmarking.
    pub fn full() -> Self {
        Self {
            requests: 2000,
            connections: 8,
            batch_rows: 32,
            threads: 0,
            ..Self::quick()
        }
    }
}

/// Throughput + latency summary of one phase.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub requests: usize,
    /// Connect/write errors, non-200 statuses, or served predictions
    /// that diverged from the local model.
    pub failed: usize,
    /// TCP connections opened (keep-alive phase: `connections` plus any
    /// error reconnects; close phase: one per request).
    pub connections_opened: usize,
    pub elapsed_secs: f64,
    pub req_per_sec: f64,
    pub rows_per_sec: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl PhaseStats {
    fn from_latencies(
        mut latencies_ms: Vec<f64>,
        failed: usize,
        connections_opened: usize,
        elapsed: f64,
        batch_rows: usize,
    ) -> Self {
        latencies_ms.sort_by(|a, b| a.total_cmp(b));
        let requests = latencies_ms.len() + failed;
        let mean_ms = if latencies_ms.is_empty() {
            f64::NAN
        } else {
            latencies_ms.iter().sum::<f64>() / latencies_ms.len() as f64
        };
        Self {
            requests,
            failed,
            connections_opened,
            elapsed_secs: elapsed,
            req_per_sec: if elapsed > 0.0 { requests as f64 / elapsed } else { f64::NAN },
            rows_per_sec: if elapsed > 0.0 {
                (requests * batch_rows) as f64 / elapsed
            } else {
                f64::NAN
            },
            mean_ms,
            p50_ms: percentile(&latencies_ms, 0.50),
            p99_ms: percentile(&latencies_ms, 0.99),
        }
    }

    fn to_json(&self) -> Json {
        let mut lat = BTreeMap::new();
        lat.insert("mean_ms".to_string(), Json::from_f64(self.mean_ms));
        lat.insert("p50_ms".to_string(), Json::from_f64(self.p50_ms));
        lat.insert("p99_ms".to_string(), Json::from_f64(self.p99_ms));
        let mut m = BTreeMap::new();
        m.insert("requests".to_string(), Json::Number(self.requests as f64));
        m.insert("failed".to_string(), Json::Number(self.failed as f64));
        m.insert(
            "connections_opened".to_string(),
            Json::Number(self.connections_opened as f64),
        );
        m.insert("elapsed_secs".to_string(), Json::from_f64(self.elapsed_secs));
        m.insert("req_per_sec".to_string(), Json::from_f64(self.req_per_sec));
        m.insert("rows_per_sec".to_string(), Json::from_f64(self.rows_per_sec));
        m.insert("latency".to_string(), Json::Object(lat));
        Json::Object(m)
    }
}

/// What happened around the mid-run hot swap.
#[derive(Debug, Clone)]
pub struct SwapStats {
    /// HTTP status of the `PUT /models/default` (200 = swap landed).
    pub status: u16,
    /// Responses served from the pre-swap version.
    pub served_old: u64,
    /// Responses served from the post-swap version.
    pub served_new: u64,
    /// Responses whose `model_version` went *backwards* on a connection
    /// — the atomicity contract being broken. Must be zero.
    pub boundary_violations: u64,
}

/// What the chaos drill injected and what the server did about it.
/// "Injected" counts are the *fired* numbers recorded by the fault
/// layer — ground truth for reconciliation, since a seeded schedule can
/// outlive the traffic that would consume it.
#[derive(Debug, Clone, Default)]
pub struct ChaosStats {
    /// Seed of the installed fault schedule.
    pub seed: u64,
    /// Worker panics fired inside subproblem solves.
    pub injected_worker_panics: u64,
    /// I/O failures fired inside `atomic_write` (warm-store saves).
    pub injected_write_failures: u64,
    /// Connections dropped at accept time.
    pub injected_conn_drops: u64,
    /// Handler reads stalled.
    pub injected_slow_reads: u64,
    /// `POST /fit` requests sent (including the deadline probes).
    pub fit_requests: u64,
    /// Fits that returned 200.
    pub fit_ok: u64,
    /// Fits that returned 500 from a caught subproblem panic.
    pub fit_panics: u64,
    /// Fits that returned 503 from the deadline (the deadline probes).
    pub fit_timeouts: u64,
    /// Fits lost to socket errors even after retries. Must be zero.
    pub fit_io_failures: u64,
    /// Client-side retries across both phases (drops + backpressure).
    pub retries: u64,
    /// Non-2xx responses whose body was *not* a JSON object with an
    /// `error` key. Must be zero: every failure is structured.
    pub unstructured_errors: u64,
    /// `/healthz` answered 200 and not degraded after the drill.
    pub server_alive: bool,
    /// The warm-start store on disk reloaded checksum-clean (or was
    /// never written).
    pub store_intact: bool,
    /// Server counters matched the fired-fault ground truth exactly.
    pub counters_reconciled: bool,
    /// The `/metrics` exposition told the same story: its counters also
    /// matched the fired-fault ground truth (it renders from the same
    /// atomics as `/stats`, so any divergence is a bug in the renderer).
    pub metrics_reconciled: bool,
    /// Human-readable reconciliation mismatches (empty on success).
    pub mismatches: Vec<String>,
}

impl ChaosStats {
    /// The chaos gate: survived, structured, reconciled.
    pub fn ok(&self) -> bool {
        self.server_alive
            && self.store_intact
            && self.counters_reconciled
            && self.metrics_reconciled
            && self.unstructured_errors == 0
            && self.fit_io_failures == 0
    }

    fn to_json(&self) -> Json {
        let mut inj = BTreeMap::new();
        inj.insert("worker_panics".to_string(), Json::Number(self.injected_worker_panics as f64));
        inj.insert("write_failures".to_string(), Json::Number(self.injected_write_failures as f64));
        inj.insert("conn_drops".to_string(), Json::Number(self.injected_conn_drops as f64));
        inj.insert("slow_reads".to_string(), Json::Number(self.injected_slow_reads as f64));
        let mut fit = BTreeMap::new();
        fit.insert("requests".to_string(), Json::Number(self.fit_requests as f64));
        fit.insert("ok".to_string(), Json::Number(self.fit_ok as f64));
        fit.insert("panics".to_string(), Json::Number(self.fit_panics as f64));
        fit.insert("timeouts".to_string(), Json::Number(self.fit_timeouts as f64));
        fit.insert("io_failures".to_string(), Json::Number(self.fit_io_failures as f64));
        let mut m = BTreeMap::new();
        m.insert("seed".to_string(), Json::Number(self.seed as f64));
        m.insert("injected".to_string(), Json::Object(inj));
        m.insert("fit".to_string(), Json::Object(fit));
        m.insert("retries".to_string(), Json::Number(self.retries as f64));
        m.insert(
            "unstructured_errors".to_string(),
            Json::Number(self.unstructured_errors as f64),
        );
        m.insert("server_alive".to_string(), Json::Bool(self.server_alive));
        m.insert("store_intact".to_string(), Json::Bool(self.store_intact));
        m.insert(
            "counters_reconciled".to_string(),
            Json::Bool(self.counters_reconciled),
        );
        m.insert(
            "metrics_reconciled".to_string(),
            Json::Bool(self.metrics_reconciled),
        );
        m.insert(
            "mismatches".to_string(),
            Json::Array(self.mismatches.iter().map(|s| Json::String(s.clone())).collect()),
        );
        m.insert("ok".to_string(), Json::Bool(self.ok()));
        Json::Object(m)
    }
}

/// Outcome of a self-test run.
#[derive(Debug, Clone)]
pub struct SelfTestReport {
    pub learner: &'static str,
    pub connections: usize,
    pub batch_rows: usize,
    /// Resolved server worker count.
    pub threads: usize,
    pub keep_alive: PhaseStats,
    pub close_mode: Option<PhaseStats>,
    /// Keep-alive req/s over close-mode req/s (the reuse payoff).
    pub keepalive_speedup: Option<f64>,
    pub swap: Option<SwapStats>,
    pub target_rps: Option<f64>,
    pub slo_p99_ms: Option<f64>,
    /// Present when the run was a chaos drill.
    pub chaos: Option<ChaosStats>,
}

impl SelfTestReport {
    pub fn total_failed(&self) -> usize {
        self.keep_alive.failed + self.close_mode.as_ref().map_or(0, |p| p.failed)
    }

    /// Whether the p99 SLO held (None when no SLO was requested).
    pub fn slo_pass(&self) -> Option<bool> {
        self.slo_p99_ms.map(|slo| self.keep_alive.p99_ms <= slo)
    }

    /// The CI gate: zero failures across phases, a landed swap with a
    /// clean version boundary, the SLO (when requested), and — in chaos
    /// mode — a server that survived the drill with reconciled counters.
    pub fn passed(&self) -> bool {
        self.total_failed() == 0
            && self.swap.as_ref().map_or(true, |s| {
                s.status == 200 && s.boundary_violations == 0 && s.served_new > 0
            })
            && self.slo_pass() != Some(false)
            && self.chaos.as_ref().map_or(true, ChaosStats::ok)
    }

    /// `backbone-serve-selftest/v1` JSON payload (CI artifact). The
    /// pre-PR-7 flat keys (`requests`, `failed`, `req_per_sec`,
    /// `rows_per_sec`, `concurrency`, `latency`) mirror the keep-alive
    /// phase so existing consumers keep working.
    pub fn to_json(&self) -> Json {
        let ka = &self.keep_alive;
        let mut lat = BTreeMap::new();
        lat.insert("mean_ms".to_string(), Json::from_f64(ka.mean_ms));
        lat.insert("p50_ms".to_string(), Json::from_f64(ka.p50_ms));
        lat.insert("p99_ms".to_string(), Json::from_f64(ka.p99_ms));
        let mut m = BTreeMap::new();
        m.insert(
            "schema".to_string(),
            Json::String("backbone-serve-selftest/v1".into()),
        );
        m.insert("learner".to_string(), Json::String(self.learner.into()));
        // Legacy flat mirrors of the keep-alive phase.
        m.insert("requests".to_string(), Json::Number(ka.requests as f64));
        m.insert("failed".to_string(), Json::Number(self.total_failed() as f64));
        m.insert("concurrency".to_string(), Json::Number(self.connections as f64));
        m.insert("batch_rows".to_string(), Json::Number(self.batch_rows as f64));
        m.insert("threads".to_string(), Json::Number(self.threads as f64));
        m.insert("elapsed_secs".to_string(), Json::from_f64(ka.elapsed_secs));
        m.insert("req_per_sec".to_string(), Json::from_f64(ka.req_per_sec));
        m.insert("rows_per_sec".to_string(), Json::from_f64(ka.rows_per_sec));
        m.insert("latency".to_string(), Json::Object(lat));
        // PR-7 structured sections.
        m.insert("connections".to_string(), Json::Number(self.connections as f64));
        m.insert("keep_alive".to_string(), ka.to_json());
        if let Some(close) = &self.close_mode {
            m.insert("close_mode".to_string(), close.to_json());
        }
        if let Some(speedup) = self.keepalive_speedup {
            m.insert("keepalive_speedup".to_string(), Json::from_f64(speedup));
        }
        if let Some(swap) = &self.swap {
            let mut s = BTreeMap::new();
            s.insert("status".to_string(), Json::Number(swap.status as f64));
            s.insert("served_old".to_string(), Json::Number(swap.served_old as f64));
            s.insert("served_new".to_string(), Json::Number(swap.served_new as f64));
            s.insert(
                "boundary_violations".to_string(),
                Json::Number(swap.boundary_violations as f64),
            );
            m.insert("swap".to_string(), Json::Object(s));
        }
        if let Some(rps) = self.target_rps {
            m.insert("target_rps".to_string(), Json::from_f64(rps));
        }
        if let Some(slo) = self.slo_p99_ms {
            let mut s = BTreeMap::new();
            s.insert("p99_ms".to_string(), Json::from_f64(slo));
            s.insert("pass".to_string(), Json::Bool(self.slo_pass() == Some(true)));
            m.insert("slo".to_string(), Json::Object(s));
        }
        if let Some(chaos) = &self.chaos {
            m.insert("chaos".to_string(), chaos.to_json());
        }
        m.insert("passed".to_string(), Json::Bool(self.passed()));
        Json::Object(m)
    }
}

/// Deterministic batch matching the model's input contract: clustering
/// gets exactly its training row count, the supervised learners get
/// `batch_rows` rows of the right width.
fn synth_batch(model: &LoadedModel, batch_rows: usize) -> Vec<Vec<f64>> {
    let rows = model.expected_rows().unwrap_or(batch_rows.max(1));
    let cols = model.num_features().unwrap_or(2).max(1);
    (0..rows)
        .map(|i| (0..cols).map(|j| ((i * cols + j) % 7) as f64 * 0.25 - 0.75).collect())
        .collect()
}

/// Render the predict request once; every client reuses the bytes.
/// `close` controls the `Connection` header.
fn render_request(body: &str, close: bool) -> Vec<u8> {
    format!(
        "POST /predict HTTP/1.1\r\nHost: selftest\r\nContent-Type: application/json\r\n\
         Content-Length: {}{}\r\n\r\n{}",
        body.len(),
        if close { "\r\nConnection: close" } else { "" },
        body
    )
    .into_bytes()
}

/// One connection-per-request exchange (close mode / the swap PUT).
fn exchange(addr: SocketAddr, request: &[u8]) -> std::io::Result<Vec<u8>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    stream.write_all(request)?;
    let mut response = Vec::new();
    std::io::Read::read_to_end(&mut stream, &mut response)?;
    Ok(response)
}

/// Check one response body: predictions bit-identical to the locally
/// computed ones. Returns the served `model_version` on success.
fn verify_body(body: &[u8], expected: &[f64]) -> Option<u64> {
    let text = std::str::from_utf8(body).ok()?;
    let doc = Json::parse(text).ok()?;
    let preds = doc.get("predictions").and_then(Json::as_array)?;
    let ok = preds.len() == expected.len()
        && preds.iter().zip(expected).all(|(p, &e)| {
            p.as_f64_tagged().is_some_and(|v| v.to_bits() == e.to_bits())
        });
    if !ok {
        return None;
    }
    Some(doc.get("model_version").and_then(Json::as_usize).unwrap_or(1) as u64)
}

/// Close-mode check: 200 + verified body.
fn verify_close(response: &[u8], expected: &[f64]) -> bool {
    let Ok((status, body)) = parse_response(response) else { return false };
    status == 200 && verify_body(&body, expected).is_some()
}

struct ClientOutcome {
    latencies_ms: Vec<f64>,
    failed: usize,
    connections_opened: usize,
    served_old: u64,
    served_new: u64,
    boundary_violations: u64,
    /// Request slots that needed at least one retry (chaos mode only —
    /// the benchmark phases run with retries disabled so `failed` keeps
    /// meaning "the server misbehaved", not "the network hiccuped").
    retries: u64,
}

/// `Retry-After` seconds from a parsed response, if the server sent one.
fn retry_after_secs(headers: &[(String, String)]) -> Option<u64> {
    headers
        .iter()
        .find(|(n, _)| n == "retry-after")
        .and_then(|(_, v)| v.trim().parse().ok())
}

/// Capped, jittered retry backoff. Honours the server's `Retry-After`
/// hint but caps the sleep so a loopback chaos drill stays fast; the
/// jitter is derived deterministically from `(seed, slot, attempt)` so
/// retrying clients neither stampede in lockstep nor make the run
/// irreproducible.
fn backoff_sleep(seed: u64, slot: usize, attempt: usize, hint_secs: Option<u64>) {
    const CAP_MS: u64 = 250;
    let base_ms = (5u64 << attempt.min(4)).min(CAP_MS);
    let hinted_ms = hint_secs.map_or(0, |s| (s * 1000).min(CAP_MS));
    let mut h = seed
        ^ (slot as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
        ^ ((attempt as u64) << 32);
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    std::thread::sleep(Duration::from_millis(base_ms.max(hinted_ms) + h % 8));
}

/// One load client. With `reuse` it keeps a single persistent
/// connection (reconnecting only on error); without it (the
/// `--no-keep-alive` mode) it tears the socket down after every
/// request. Either way it paces, verifies each body, and checks that
/// `model_version` never goes backwards from its vantage point.
///
/// `sync` (request-count mode with swap-under-load) is the barrier that
/// makes the swap deterministic: at its halfway request the client
/// parks, the coordinator swaps once every client is parked, and the
/// back half of the workload provably runs against the new version.
///
/// `retry` (`(max_attempts, jitter_seed)`, chaos mode) lets a slot
/// survive injected connection drops and backpressure: socket-level
/// errors and 429/503 responses are retried with capped jittered
/// backoff honouring `Retry-After` before the slot counts as failed.
#[allow(clippy::too_many_arguments)]
fn load_client(
    addr: SocketAddr,
    request: &[u8],
    expected: &[f64],
    reuse: bool,
    quota: usize,
    deadline: Option<Instant>,
    pace: Option<(Instant, f64, usize, usize)>, // (start, rps, client idx, stride)
    sync: Option<(&AtomicU64, &AtomicBool)>,    // (parked count, swap landed)
    retry: Option<(usize, u64)>,                // (max extra attempts, jitter seed)
) -> ClientOutcome {
    let mut out = ClientOutcome {
        latencies_ms: Vec::with_capacity(quota),
        failed: 0,
        connections_opened: 0,
        served_old: 0,
        served_new: 0,
        boundary_violations: 0,
        retries: 0,
    };
    let mut stream: Option<TcpStream> = None;
    let mut max_version: u64 = 0;
    let halfway = quota / 2;
    let mut j = 0usize;
    loop {
        match deadline {
            Some(d) => {
                if Instant::now() >= d {
                    break;
                }
            }
            None => {
                if j >= quota {
                    break;
                }
            }
        }
        if let Some((parked, swap_done)) = sync {
            if j == halfway {
                parked.fetch_add(1, Ordering::Relaxed);
                while !swap_done.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
        if let Some((start, rps, idx, stride)) = pace {
            // Global request slots are interleaved across clients:
            // client idx owns slots idx, idx+stride, idx+2·stride, …
            let due = start + Duration::from_secs_f64((idx + j * stride) as f64 / rps);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        j += 1;
        let sent = Instant::now();
        // One request slot. Without `retry` a connect failure, socket
        // error, or backpressure status consumes the slot as a failure
        // (the benchmark contract); with it the slot is re-attempted
        // after a backoff before giving up.
        let mut attempt = 0usize;
        let slot_body: Option<Vec<u8>> = loop {
            // (Re)connect lazily.
            if stream.is_none() {
                match TcpStream::connect(addr) {
                    Ok(s) => {
                        let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                        out.connections_opened += 1;
                        stream = Some(s);
                    }
                    Err(_) => {
                        if let Some((max, seed)) = retry {
                            if attempt < max {
                                attempt += 1;
                                out.retries += 1;
                                backoff_sleep(seed, j, attempt, None);
                                continue;
                            }
                        }
                        break None;
                    }
                }
            }
            let s = stream.as_mut().unwrap();
            let result = s
                .write_all(request)
                .map_err(super::http::HttpError::Io)
                .and_then(|()| read_response(s));
            match result {
                Ok((200, _headers, body)) => break Some(body),
                Ok((429 | 503, headers, _body)) => {
                    // Backpressure / deadline shed: connection stays
                    // usable; come back when the server asked us to.
                    if let Some((max, seed)) = retry {
                        if attempt < max {
                            attempt += 1;
                            out.retries += 1;
                            backoff_sleep(seed, j, attempt, retry_after_secs(&headers));
                            continue;
                        }
                    }
                    break None;
                }
                Ok((_status, _headers, _body)) => break None,
                Err(_) => {
                    stream = None; // force a reconnect
                    if let Some((max, seed)) = retry {
                        if attempt < max {
                            attempt += 1;
                            out.retries += 1;
                            backoff_sleep(seed, j, attempt, None);
                            continue;
                        }
                    }
                    break None;
                }
            }
        };
        match slot_body {
            Some(body) => match verify_body(&body, expected) {
                Some(version) => {
                    out.latencies_ms.push(sent.elapsed().as_secs_f64() * 1e3);
                    if version < max_version {
                        out.boundary_violations += 1;
                    }
                    max_version = max_version.max(version);
                    if version > 1 {
                        out.served_new += 1;
                    } else {
                        out.served_old += 1;
                    }
                }
                None => {
                    out.failed += 1;
                    // Response was parseable HTTP, connection stays usable.
                }
            },
            None => out.failed += 1,
        }
        if !reuse {
            stream = None; // close-per-request mode
        }
    }
    out
}

/// Boot a server around `model` and run the configured phases.
pub fn run_self_test(model: LoadedModel, cfg: &SelfTestConfig) -> Result<SelfTestReport> {
    if cfg.chaos {
        return run_chaos(model, cfg);
    }
    let learner = model.kind().name();
    let rows = synth_batch(&model, cfg.batch_rows);
    let expected = model
        .try_predict(&Matrix::from_rows(&rows))
        .context("self-test batch rejected by the model")?;

    let body = {
        let rows_json = Json::Array(
            rows.iter()
                .map(|r| Json::Array(r.iter().map(|&v| Json::from_f64(v)).collect()))
                .collect(),
        );
        let mut m = BTreeMap::new();
        m.insert("rows".to_string(), rows_json);
        Json::Object(m).to_string_compact()
    };
    let ka_request = render_request(&body, false);
    let close_request = render_request(&body, true);

    // The swap payload is the same model re-wrapped as an artifact: the
    // version bumps (so the boundary is observable) while the expected
    // predictions stay valid on both sides of it.
    let swap_artifact = ModelArtifact {
        model: model.clone(),
        provenance: Provenance {
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            seed: 0,
            params: Json::Object(BTreeMap::new()),
            config: Json::Object(BTreeMap::new()),
            diagnostics: None,
        },
    }
    .to_json()
    .to_string_compact();
    let swap_request = format!(
        "PUT /models/default HTTP/1.1\r\nHost: selftest\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        swap_artifact.len(),
        swap_artifact
    )
    .into_bytes();

    let total = cfg.requests.max(1);
    let connections = cfg.connections.clamp(1, total);
    // Headroom above the client count so every load connection plus the
    // swap PUT and any reconnects clear admission, and a generous idle
    // timeout so clients parked at the swap barrier are never reaped by
    // a slow CI machine mid-phase.
    let serve_cfg = ServeConfig::builder()
        .threads(cfg.threads)
        .max_connections(connections + 8)
        .idle_timeout(Duration::from_secs(30))
        .build()?;
    let server =
        Server::bind("127.0.0.1:0", model, &serve_cfg).context("binding self-test server")?;
    let addr = server.local_addr()?;
    let shutdown = server.shutdown_handle()?;
    let threads = crate::backbone::resolved_threads(cfg.threads);

    let duration = cfg.duration_secs.map(Duration::from_secs_f64);
    // The close-mode comparison only makes sense unpaced (pacing would
    // cap both phases at the same rate) and against a keep-alive primary
    // phase (otherwise both phases would measure the same thing).
    let do_close = cfg.keep_alive && cfg.compare_close && cfg.target_rps.is_none();

    let mut report: Option<SelfTestReport> = None;
    std::thread::scope(|scope| {
        scope.spawn(|| server.run());

        // -------------------------------------------------- keep-alive
        let parked = AtomicU64::new(0);
        let swap_done = AtomicBool::new(false);
        let phase_over = AtomicBool::new(false);
        let swap_status = AtomicU64::new(0);
        let ka_started = Instant::now();
        // Request-count mode gets the deterministic park/swap/resume
        // barrier; duration mode triggers on wall clock at the midpoint
        // (clients keep running for the whole back half, so the new
        // version is always observed).
        let barrier_mode = cfg.swap_under_load && duration.is_none();
        let ka = {
            let swap_at = match duration {
                Some(d) => SwapTrigger::At(ka_started + d.mul_f64(0.5)),
                None => SwapTrigger::AllParked(connections as u64),
            };
            std::thread::scope(|phase| {
                if cfg.swap_under_load {
                    let parked = &parked;
                    let swap_done = &swap_done;
                    let phase_over = &phase_over;
                    let swap_status = &swap_status;
                    let swap_request = &swap_request;
                    phase.spawn(move || {
                        loop {
                            if phase_over.load(Ordering::Relaxed) {
                                swap_done.store(true, Ordering::Relaxed);
                                return; // phase ended before the trigger
                            }
                            let due = match swap_at {
                                SwapTrigger::At(t) => Instant::now() >= t,
                                SwapTrigger::AllParked(n) => {
                                    parked.load(Ordering::Relaxed) >= n
                                }
                            };
                            if due {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        let status = exchange(addr, swap_request)
                            .ok()
                            .and_then(|resp| parse_response(&resp).ok())
                            .map(|(status, _)| status as u64)
                            .unwrap_or(0);
                        swap_status.store(status, Ordering::Relaxed);
                        // Release parked clients only after the swap
                        // round-tripped: the back half of the workload is
                        // guaranteed to see the new version.
                        swap_done.store(true, Ordering::Relaxed);
                    });
                }
                let reuse = cfg.keep_alive;
                let clients: Vec<_> = (0..connections)
                    .map(|t| {
                        let quota =
                            total / connections + usize::from(t < total % connections);
                        let request = if reuse { &ka_request } else { &close_request };
                        let expected = &expected;
                        let deadline = duration.map(|d| ka_started + d);
                        let pace = cfg
                            .target_rps
                            .map(|rps| (ka_started, rps, t, connections));
                        let sync = barrier_mode.then_some((&parked, &swap_done));
                        phase.spawn(move || {
                            load_client(
                                addr, request, expected, reuse, quota, deadline, pace, sync,
                                None,
                            )
                        })
                    })
                    .collect();
                let mut latencies = Vec::new();
                let mut failed = 0usize;
                let mut opened = 0usize;
                let (mut old, mut new, mut violations) = (0u64, 0u64, 0u64);
                for client in clients {
                    let c = client.join().expect("self-test client panicked");
                    latencies.extend(c.latencies_ms);
                    failed += c.failed;
                    opened += c.connections_opened;
                    old += c.served_old;
                    new += c.served_new;
                    violations += c.boundary_violations;
                }
                phase_over.store(true, Ordering::Relaxed);
                let elapsed = ka_started.elapsed().as_secs_f64();
                (
                    PhaseStats::from_latencies(latencies, failed, opened, elapsed, rows.len()),
                    old,
                    new,
                    violations,
                )
            })
        };
        let (ka_stats, served_old, served_new, violations) = ka;

        // -------------------------------------------------- close mode
        let close_stats = if do_close {
            let close_started = Instant::now();
            let close_deadline = duration.map(|d| close_started + d);
            let clients: Vec<_> = (0..connections)
                .map(|t| {
                    let quota = total / connections + usize::from(t < total % connections);
                    let request = &close_request;
                    let expected = &expected;
                    scope.spawn(move || {
                        let mut lat = Vec::with_capacity(quota);
                        let mut bad = 0usize;
                        let mut sent_count = 0usize;
                        loop {
                            match close_deadline {
                                Some(d) => {
                                    if Instant::now() >= d {
                                        break;
                                    }
                                }
                                None => {
                                    if sent_count >= quota {
                                        break;
                                    }
                                }
                            }
                            sent_count += 1;
                            let sent = Instant::now();
                            match exchange(addr, request) {
                                Ok(resp) if verify_close(&resp, expected) => {
                                    lat.push(sent.elapsed().as_secs_f64() * 1e3);
                                }
                                _ => bad += 1,
                            }
                        }
                        (lat, bad, sent_count)
                    })
                })
                .collect();
            let mut latencies = Vec::new();
            let mut failed = 0usize;
            let mut opened = 0usize;
            for client in clients {
                let (lat, bad, sent) = client.join().expect("close-mode client panicked");
                latencies.extend(lat);
                failed += bad;
                opened += sent;
            }
            let elapsed = close_started.elapsed().as_secs_f64();
            Some(PhaseStats::from_latencies(latencies, failed, opened, elapsed, rows.len()))
        } else {
            None
        };

        shutdown.shutdown();

        let keepalive_speedup = close_stats.as_ref().and_then(|close| {
            if close.req_per_sec > 0.0 && ka_stats.req_per_sec.is_finite() {
                Some(ka_stats.req_per_sec / close.req_per_sec)
            } else {
                None
            }
        });
        let swap = cfg.swap_under_load.then(|| SwapStats {
            status: swap_status.load(Ordering::Relaxed) as u16,
            served_old,
            served_new,
            boundary_violations: violations,
        });
        report = Some(SelfTestReport {
            learner,
            connections,
            batch_rows: rows.len(),
            threads,
            keep_alive: ka_stats,
            close_mode: close_stats,
            keepalive_speedup,
            swap,
            target_rps: cfg.target_rps,
            slo_p99_ms: cfg.slo_p99_ms,
            chaos: None,
        });
    });
    Ok(report.expect("self-test scope completed without a report"))
}

/// Chaos drills need the fault layer compiled in; refuse loudly rather
/// than silently running a fault-free "chaos" pass.
#[cfg(not(feature = "fault-inject"))]
fn run_chaos(_model: LoadedModel, _cfg: &SelfTestConfig) -> Result<SelfTestReport> {
    anyhow::bail!("--chaos requires a build with `--features fault-inject`")
}

/// The chaos drill: boot a fit-enabled server with a scratch warm-start
/// store, install a seeded fault schedule, hammer `/predict` over
/// keep-alive connections (with retry/backoff, since connections get
/// dropped under it) while injected worker panics, write failures,
/// connection drops, and slow reads fire — then stop injecting and
/// audit the wreckage:
///
/// - the server still answers `/healthz` 200 and is not degraded;
/// - the warm-start store on disk reloads checksum-clean (failed saves
///   left the previous version intact — the atomic-write contract);
/// - every failed request carried a structured JSON `error` body;
/// - `/stats` failure counters equal the *fired* fault counts exactly
///   (`panics_caught` == fired worker panics == 500-from-panic fits,
///   `store_save_failures` == fired write failures, and the fit route's
///   failure count == panics + deadline timeouts).
///
/// Two of the `POST /fit` requests are deadline probes (`deadline_ms:
/// 0`) and must come back as structured 503s with `Retry-After`. Fit
/// bodies are all distinct problems so an exact warm-cache hit can
/// never skip the solve a panic was scheduled into.
#[cfg(feature = "fault-inject")]
fn run_chaos(model: LoadedModel, cfg: &SelfTestConfig) -> Result<SelfTestReport> {
    use crate::fault::{self, FaultPlan, FaultPoint};

    let learner = model.kind().name();
    let rows = synth_batch(&model, cfg.batch_rows);
    let expected = model
        .try_predict(&Matrix::from_rows(&rows))
        .context("chaos batch rejected by the model")?;
    let body = {
        let rows_json = Json::Array(
            rows.iter()
                .map(|r| Json::Array(r.iter().map(|&v| Json::from_f64(v)).collect()))
                .collect(),
        );
        let mut m = BTreeMap::new();
        m.insert("rows".to_string(), rows_json);
        Json::Object(m).to_string_compact()
    };
    let ka_request = render_request(&body, false);

    let total = cfg.requests.max(1);
    let connections = cfg.connections.clamp(1, total);
    let store_path = std::env::temp_dir().join(format!(
        "backbone_chaos_store_{}_{}.json",
        std::process::id(),
        cfg.chaos_seed
    ));
    let _ = std::fs::remove_file(&store_path);
    let store_path_str = store_path.display().to_string();

    let serve_cfg = ServeConfig::builder()
        .threads(cfg.threads)
        .max_connections(connections + 8)
        .idle_timeout(Duration::from_secs(30))
        .enable_fit(true)
        .warm_cache_path(Some(store_path_str.clone()))
        .fit_timeout(Some(Duration::from_secs(30)))
        .build()?;
    let server =
        Server::bind("127.0.0.1:0", model, &serve_cfg).context("binding chaos server")?;
    let addr = server.local_addr()?;
    let shutdown = server.shutdown_handle()?;
    let threads = crate::backbone::resolved_threads(cfg.threads);

    // Serialize against any other fault-plan user (the fault/corruption
    // test suites), then install the schedule. The server booted above,
    // so the plan only ever sees chaos traffic — never the bind-time
    // warm-store load. Callers must NOT hold the guard themselves.
    let _serial = fault::serial_guard();
    fault::install(FaultPlan::seeded(cfg.chaos_seed, 4, 16));

    let mut chaos = ChaosStats { seed: cfg.chaos_seed, ..ChaosStats::default() };
    let mut ka_stats: Option<PhaseStats> = None;
    std::thread::scope(|scope| {
        scope.spawn(|| server.run());

        // ------------------------------------- predict phase (chaotic)
        let ka_started = Instant::now();
        let ka = std::thread::scope(|phase| {
            let clients: Vec<_> = (0..connections)
                .map(|t| {
                    let quota = total / connections + usize::from(t < total % connections);
                    let request = &ka_request;
                    let expected = &expected;
                    let seed = cfg.chaos_seed.wrapping_add(t as u64);
                    phase.spawn(move || {
                        load_client(
                            addr,
                            request,
                            expected,
                            true,
                            quota,
                            None,
                            None,
                            None,
                            Some((3, seed)),
                        )
                    })
                })
                .collect();
            let mut latencies = Vec::new();
            let mut failed = 0usize;
            let mut opened = 0usize;
            for client in clients {
                let c = client.join().expect("chaos client panicked");
                latencies.extend(c.latencies_ms);
                failed += c.failed;
                opened += c.connections_opened;
                chaos.retries += c.retries;
            }
            let elapsed = ka_started.elapsed().as_secs_f64();
            PhaseStats::from_latencies(latencies, failed, opened, elapsed, rows.len())
        });

        // ----------------------------------------- fit phase (chaotic)
        // Sequential, one fresh connection per fit: panics scheduled in
        // the solver land in exactly one fit, which is what makes the
        // fired-panic == failed-fit reconciliation exact.
        let normal_fits = 12u64;
        let deadline_fits = 2u64;
        for i in 0..normal_fits + deadline_fits {
            let probe = i >= normal_fits;
            let fit_body = chaos_fit_body(i, probe);
            let request = format!(
                "POST /fit HTTP/1.1\r\nHost: chaos\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                fit_body.len(),
                fit_body
            )
            .into_bytes();
            chaos.fit_requests += 1;
            // Injected accept-time drops look like socket errors here;
            // retry those (they are the fault being drilled), never a
            // served status.
            let mut response = None;
            for attempt in 0..4 {
                match exchange(addr, &request) {
                    Ok(resp) => {
                        response = Some(resp);
                        break;
                    }
                    Err(_) if attempt < 3 => {
                        chaos.retries += 1;
                        backoff_sleep(cfg.chaos_seed, i as usize, attempt + 1, None);
                    }
                    Err(_) => {}
                }
            }
            let Some(resp) = response else {
                chaos.fit_io_failures += 1;
                continue;
            };
            let Ok((status, headers, resp_body)) = read_response(&mut &resp[..]) else {
                chaos.unstructured_errors += 1;
                continue;
            };
            let structured = || {
                std::str::from_utf8(&resp_body)
                    .ok()
                    .and_then(|t| Json::parse(t).ok())
                    .is_some_and(|doc| doc.get("error").is_some())
            };
            match status {
                200 => chaos.fit_ok += 1,
                500 => {
                    chaos.fit_panics += 1;
                    if !structured() {
                        chaos.unstructured_errors += 1;
                    }
                }
                503 => {
                    chaos.fit_timeouts += 1;
                    if !structured() || retry_after_secs(&headers).is_none() {
                        chaos.unstructured_errors += 1;
                    }
                }
                _ => chaos.unstructured_errors += 1,
            }
        }

        // ------------------------------------------- audit (fault-free)
        fault::clear();
        chaos.injected_worker_panics = fault::fired_count(FaultPoint::WorkerPanic);
        chaos.injected_write_failures = fault::fired_count(FaultPoint::WriteFail);
        chaos.injected_conn_drops = fault::fired_count(FaultPoint::ConnDrop);
        chaos.injected_slow_reads = fault::fired_count(FaultPoint::SlowRead);

        let get = |path: &str| -> Option<Json> {
            let request = format!(
                "GET {path} HTTP/1.1\r\nHost: chaos\r\nConnection: close\r\n\r\n"
            );
            let resp = exchange(addr, request.as_bytes()).ok()?;
            let (status, body) = parse_response(&resp).ok()?;
            if status != 200 {
                return None;
            }
            Json::parse(std::str::from_utf8(&body).ok()?).ok()
        };
        chaos.server_alive = get("/healthz")
            .is_some_and(|doc| doc.get("degraded").and_then(Json::as_bool) == Some(false));

        fn check(mismatches: &mut Vec<String>, name: &str, got: Option<u64>, want: u64) {
            if got != Some(want) {
                mismatches.push(format!("{name}: got {got:?}, want {want}"));
            }
        }
        if let Some(stats) = get("/stats") {
            let counter = |doc: &Json, key: &str| {
                doc.get(key).and_then(Json::as_usize).map(|v| v as u64)
            };
            check(
                &mut chaos.mismatches,
                "stats.panics_caught vs fired worker panics",
                counter(&stats, "panics_caught"),
                chaos.injected_worker_panics,
            );
            check(
                &mut chaos.mismatches,
                "stats.store_save_failures vs fired write failures",
                counter(&stats, "store_save_failures"),
                chaos.injected_write_failures,
            );
            check(
                &mut chaos.mismatches,
                "client-observed 500s vs fired worker panics",
                Some(chaos.fit_panics),
                chaos.injected_worker_panics,
            );
            check(
                &mut chaos.mismatches,
                "deadline probes vs 503s",
                Some(chaos.fit_timeouts),
                deadline_fits,
            );
            let fit_failures = stats
                .get("routes")
                .and_then(|r| r.get("fit"))
                .and_then(|f| counter(f, "failures"));
            check(
                &mut chaos.mismatches,
                "routes.fit.failures vs panics+timeouts",
                fit_failures,
                chaos.fit_panics + chaos.fit_timeouts,
            );
            check(
                &mut chaos.mismatches,
                "fit accounting (ok+panics+timeouts vs sent)",
                Some(chaos.fit_ok + chaos.fit_panics + chaos.fit_timeouts),
                chaos.fit_requests - chaos.fit_io_failures,
            );
        } else {
            chaos.mismatches.push("/stats unreachable after the drill".into());
        }
        chaos.counters_reconciled = chaos.mismatches.is_empty();

        // The Prometheus exposition must tell the same story as /stats:
        // it renders from the same atomics, so the fired-fault ground
        // truth reconciles there too. Only server-derived series are
        // audited — the process-global registry is shared across
        // in-process tests and would make exact equality flaky.
        let get_text = |path: &str| -> Option<String> {
            let request = format!(
                "GET {path} HTTP/1.1\r\nHost: chaos\r\nConnection: close\r\n\r\n"
            );
            let resp = exchange(addr, request.as_bytes()).ok()?;
            let (status, body) = parse_response(&resp).ok()?;
            if status != 200 {
                return None;
            }
            std::str::from_utf8(&body).ok().map(str::to_string)
        };
        let stats_mismatches = chaos.mismatches.len();
        if let Some(text) = get_text("/metrics") {
            let metric = |name: &str, labels: &[(&str, &str)]| {
                crate::obs::metric_value(&text, name, labels).map(|v| v as u64)
            };
            check(
                &mut chaos.mismatches,
                "metrics backbone_serve_panics_caught_total vs fired worker panics",
                metric("backbone_serve_panics_caught_total", &[]),
                chaos.injected_worker_panics,
            );
            check(
                &mut chaos.mismatches,
                "metrics backbone_warmstart_store_save_failures_total vs fired write failures",
                metric("backbone_warmstart_store_save_failures_total", &[]),
                chaos.injected_write_failures,
            );
            check(
                &mut chaos.mismatches,
                "metrics backbone_route_failures_total{route=fit} vs panics+timeouts",
                metric("backbone_route_failures_total", &[("route", "fit")]),
                chaos.fit_panics + chaos.fit_timeouts,
            );
        } else {
            chaos.mismatches.push("/metrics unreachable after the drill".into());
        }
        chaos.metrics_reconciled = chaos.mismatches.len() == stats_mismatches;

        // Atomic-write contract: whatever is on disk (if anything got
        // written at all) must reload checksum-clean.
        chaos.store_intact = if store_path.exists() {
            let (_, err) = crate::warmstart::WarmStartStore::load_or_empty(&store_path_str, 64);
            err.is_none()
        } else {
            true
        };

        shutdown.shutdown();
        ka_stats = Some(ka);
    });
    let _ = std::fs::remove_file(&store_path);

    Ok(SelfTestReport {
        learner,
        connections,
        batch_rows: rows.len(),
        threads,
        keep_alive: ka_stats.expect("chaos scope completed without phase stats"),
        close_mode: None,
        keepalive_speedup: None,
        swap: None,
        target_rps: None,
        slo_p99_ms: None,
        chaos: Some(chaos),
    })
}

/// A distinct well-posed regression problem per fit request: 8 rows of
/// 3 features, `y = 2·x₀ + i/8` so no two requests share a warm-cache
/// key. `probe` adds `deadline_ms: 0` — an already-expired deadline the
/// server must answer with a structured 503.
#[cfg(feature = "fault-inject")]
fn chaos_fit_body(i: u64, probe: bool) -> String {
    let offset = i as f64 * 0.125;
    let x: Vec<Vec<f64>> = (0..8)
        .map(|r| vec![(r + 1) as f64, (r % 2) as f64, ((r / 2) % 2) as f64])
        .collect();
    let y: Vec<f64> = x.iter().map(|row| 2.0 * row[0] + offset).collect();
    let mut m = BTreeMap::new();
    m.insert(
        "x".to_string(),
        Json::Array(
            x.iter()
                .map(|row| Json::Array(row.iter().map(|&v| Json::from_f64(v)).collect()))
                .collect(),
        ),
    );
    m.insert("y".to_string(), Json::Array(y.iter().map(|&v| Json::from_f64(v)).collect()));
    m.insert("k".to_string(), Json::Number(1.0));
    m.insert("m".to_string(), Json::Number(2.0));
    if probe {
        m.insert("deadline_ms".to_string(), Json::Number(0.0));
    }
    Json::Object(m).to_string_compact()
}

/// When the mid-run hot swap fires.
#[derive(Clone, Copy)]
enum SwapTrigger {
    /// Wall-clock trigger (duration mode): the phase midpoint.
    At(Instant),
    /// Barrier trigger (request-count mode): once this many clients
    /// parked at their halfway request.
    AllParked(u64),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SolveStatus;

    fn toy_model() -> LoadedModel {
        LoadedModel::SparseRegression(
            crate::backbone::sparse_regression::SparseRegressionModel {
                beta: vec![1.0, -2.0, 0.5],
                intercept: 0.25,
                support: vec![0, 1, 2],
                objective: 1.0,
                gap: 0.0,
                status: SolveStatus::Optimal,
            },
        )
    }

    #[test]
    fn self_test_round_trips_with_zero_failures_and_clean_swap() {
        let report = run_self_test(
            toy_model(),
            &SelfTestConfig {
                requests: 24,
                connections: 3,
                batch_rows: 4,
                threads: 2,
                ..SelfTestConfig::quick()
            },
        )
        .unwrap();
        assert_eq!(report.keep_alive.requests, 24);
        assert_eq!(report.total_failed(), 0, "loopback self-test had failures");
        assert!(report.keep_alive.req_per_sec > 0.0);
        assert!(report.keep_alive.p99_ms >= report.keep_alive.p50_ms);
        // Keep-alive means connections, not requests, opened sockets.
        assert!(
            report.keep_alive.connections_opened <= 3,
            "keep-alive phase opened {} sockets for 24 requests",
            report.keep_alive.connections_opened
        );
        let swap = report.swap.as_ref().expect("swap phase ran");
        assert_eq!(swap.status, 200, "hot swap did not land");
        assert_eq!(swap.boundary_violations, 0, "version went backwards");
        assert!(swap.served_new > 0, "no request observed the swapped version");
        assert!(report.passed(), "report must pass its own gate");

        let doc = report.to_json();
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some("backbone-serve-selftest/v1")
        );
        // Legacy flat mirrors stay.
        assert_eq!(doc.get("failed").and_then(Json::as_usize), Some(0));
        assert_eq!(doc.get("requests").and_then(Json::as_usize), Some(24));
        assert!(doc.get("req_per_sec").is_some());
        // New sections present.
        assert!(doc.get("keep_alive").is_some());
        assert!(doc.get("close_mode").is_some());
        assert!(doc.get("keepalive_speedup").is_some());
        assert_eq!(
            doc.get("swap").unwrap().get("boundary_violations").and_then(Json::as_usize),
            Some(0)
        );
        assert_eq!(doc.get("passed").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn slo_miss_fails_the_report_gate() {
        let report = run_self_test(
            toy_model(),
            &SelfTestConfig {
                requests: 8,
                connections: 2,
                batch_rows: 2,
                threads: 1,
                compare_close: false,
                swap_under_load: false,
                slo_p99_ms: Some(0.0), // impossible SLO
                ..SelfTestConfig::quick()
            },
        )
        .unwrap();
        assert_eq!(report.total_failed(), 0);
        assert_eq!(report.slo_pass(), Some(false));
        assert!(!report.passed());
        let doc = report.to_json();
        assert_eq!(
            doc.get("slo").unwrap().get("pass").and_then(Json::as_bool),
            Some(false)
        );
    }

    // The chaos drill's end-to-end tests live in `tests/corruption.rs`:
    // an installed fault plan is process-global, so they must not run
    // concurrently with other library tests that touch fire sites.

    #[test]
    fn synth_batch_respects_model_contracts() {
        let batch = synth_batch(&toy_model(), 8);
        assert_eq!(batch.len(), 8);
        assert!(batch.iter().all(|r| r.len() == 3));

        let cl = LoadedModel::Clustering(crate::backbone::clustering::ClusteringModel {
            labels: vec![0, 1, 0],
            objective: 0.0,
            gap: 0.0,
            status: SolveStatus::Optimal,
        });
        let batch = synth_batch(&cl, 8);
        assert_eq!(batch.len(), 3, "clustering batch must match training rows");
    }
}
