//! Loopback load generator: `cli serve --self-test`.
//!
//! Boots a real [`Server`](super::Server) on an ephemeral localhost port,
//! drives it with concurrent client threads over real TCP sockets, and
//! reports throughput + latency percentiles in `backbone-bench/v1`-style
//! JSON (`backbone-serve-selftest/v1`). Every response is verified
//! against a locally computed prediction for the same batch, so "zero
//! failed requests" means the *served* numbers are bit-identical to the
//! in-process model — not merely that sockets stayed open. CI's
//! `serve-smoke` job runs this end to end.

use super::http::parse_response;
use super::{ServeConfig, Server};
use crate::backbone::Predict;
use crate::bench_support::percentile;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::persist::LoadedModel;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct SelfTestConfig {
    /// Total requests to issue across all client threads.
    pub requests: usize,
    /// Concurrent client threads.
    pub concurrency: usize,
    /// Rows per batched `/predict` request (clustering overrides this
    /// with its transductive row-count contract).
    pub batch_rows: usize,
    /// Server worker threads (0 = all cores).
    pub threads: usize,
}

impl SelfTestConfig {
    /// CI scale: finishes in seconds on one core.
    pub fn quick() -> Self {
        Self { requests: 200, concurrency: 4, batch_rows: 16, threads: 2 }
    }

    /// Full scale for local benchmarking.
    pub fn full() -> Self {
        Self { requests: 2000, concurrency: 8, batch_rows: 32, threads: 0 }
    }
}

/// Outcome of a self-test run.
#[derive(Debug, Clone)]
pub struct SelfTestReport {
    pub learner: &'static str,
    pub requests: usize,
    /// Requests that failed: connect/write errors, non-200 statuses, or
    /// served predictions that diverged from the local model.
    pub failed: usize,
    pub concurrency: usize,
    pub batch_rows: usize,
    /// Resolved server worker count.
    pub threads: usize,
    pub elapsed_secs: f64,
    pub req_per_sec: f64,
    pub rows_per_sec: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl SelfTestReport {
    /// `backbone-serve-selftest/v1` JSON payload (CI artifact).
    pub fn to_json(&self) -> Json {
        let mut lat = BTreeMap::new();
        lat.insert("mean_ms".to_string(), Json::from_f64(self.mean_ms));
        lat.insert("p50_ms".to_string(), Json::from_f64(self.p50_ms));
        lat.insert("p99_ms".to_string(), Json::from_f64(self.p99_ms));
        let mut m = BTreeMap::new();
        m.insert(
            "schema".to_string(),
            Json::String("backbone-serve-selftest/v1".into()),
        );
        m.insert("learner".to_string(), Json::String(self.learner.into()));
        m.insert("requests".to_string(), Json::Number(self.requests as f64));
        m.insert("failed".to_string(), Json::Number(self.failed as f64));
        m.insert("concurrency".to_string(), Json::Number(self.concurrency as f64));
        m.insert("batch_rows".to_string(), Json::Number(self.batch_rows as f64));
        m.insert("threads".to_string(), Json::Number(self.threads as f64));
        m.insert("elapsed_secs".to_string(), Json::from_f64(self.elapsed_secs));
        m.insert("req_per_sec".to_string(), Json::from_f64(self.req_per_sec));
        m.insert("rows_per_sec".to_string(), Json::from_f64(self.rows_per_sec));
        m.insert("latency".to_string(), Json::Object(lat));
        Json::Object(m)
    }
}

/// Deterministic batch matching the model's input contract: clustering
/// gets exactly its training row count, the supervised learners get
/// `batch_rows` rows of the right width.
fn synth_batch(model: &LoadedModel, batch_rows: usize) -> Vec<Vec<f64>> {
    let rows = model.expected_rows().unwrap_or(batch_rows.max(1));
    let cols = model.num_features().unwrap_or(2).max(1);
    (0..rows)
        .map(|i| (0..cols).map(|j| ((i * cols + j) % 7) as f64 * 0.25 - 0.75).collect())
        .collect()
}

/// One raw HTTP exchange; returns the response bytes.
fn exchange(addr: SocketAddr, request: &[u8]) -> std::io::Result<Vec<u8>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    stream.write_all(request)?;
    let mut response = Vec::new();
    stream.read_to_end(&mut response)?;
    Ok(response)
}

/// Check one response: 200, JSON body, predictions bit-identical to the
/// locally computed ones.
fn verify(response: &[u8], expected: &[f64]) -> bool {
    let Ok((status, body)) = parse_response(response) else { return false };
    if status != 200 {
        return false;
    }
    let Ok(text) = std::str::from_utf8(&body) else { return false };
    let Ok(doc) = Json::parse(text) else { return false };
    let Some(preds) = doc.get("predictions").and_then(Json::as_array) else {
        return false;
    };
    preds.len() == expected.len()
        && preds.iter().zip(expected).all(|(p, &e)| {
            p.as_f64_tagged().is_some_and(|v| v.to_bits() == e.to_bits())
        })
}

/// Boot a server around `model`, hammer it from `cfg.concurrency` client
/// threads, verify every response, and summarize.
pub fn run_self_test(model: LoadedModel, cfg: &SelfTestConfig) -> Result<SelfTestReport> {
    let learner = model.kind().name();
    let rows = synth_batch(&model, cfg.batch_rows);
    let expected = model
        .try_predict(&Matrix::from_rows(&rows))
        .context("self-test batch rejected by the model")?;

    // Pre-render the request bytes once; every client reuses them.
    let rows_json = Json::Array(
        rows.iter()
            .map(|r| Json::Array(r.iter().map(|&v| Json::from_f64(v)).collect()))
            .collect(),
    );
    let body = {
        let mut m = BTreeMap::new();
        m.insert("rows".to_string(), rows_json);
        Json::Object(m).to_string_compact()
    };
    let request = format!(
        "POST /predict HTTP/1.1\r\nHost: selftest\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes();

    let server = Server::bind(
        "127.0.0.1:0",
        model,
        &ServeConfig { threads: cfg.threads, ..ServeConfig::default() },
    )
    .context("binding self-test server")?;
    let addr = server.local_addr()?;
    let shutdown = server.shutdown_handle()?;
    let threads = crate::backbone::resolved_threads(cfg.threads);

    let total = cfg.requests.max(1);
    let concurrency = cfg.concurrency.clamp(1, total);

    let mut latencies_ms: Vec<f64> = Vec::with_capacity(total);
    let mut failed = 0usize;
    let started = Instant::now();
    let elapsed = std::thread::scope(|scope| {
        scope.spawn(move || server.run());
        let clients: Vec<_> = (0..concurrency)
            .map(|t| {
                // Spread the remainder over the first threads.
                let quota = total / concurrency + usize::from(t < total % concurrency);
                let request = &request;
                let expected = &expected;
                scope.spawn(move || {
                    let mut lat = Vec::with_capacity(quota);
                    let mut bad = 0usize;
                    for _ in 0..quota {
                        let sent = Instant::now();
                        match exchange(addr, request) {
                            Ok(resp) if verify(&resp, expected) => {
                                lat.push(sent.elapsed().as_secs_f64() * 1e3);
                            }
                            _ => bad += 1,
                        }
                    }
                    (lat, bad)
                })
            })
            .collect();
        for client in clients {
            let (lat, bad) = client.join().expect("self-test client panicked");
            latencies_ms.extend(lat);
            failed += bad;
        }
        let elapsed = started.elapsed().as_secs_f64();
        shutdown.shutdown();
        elapsed
    });

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ms = if latencies_ms.is_empty() {
        f64::NAN
    } else {
        latencies_ms.iter().sum::<f64>() / latencies_ms.len() as f64
    };
    Ok(SelfTestReport {
        learner,
        requests: total,
        failed,
        concurrency,
        batch_rows: rows.len(),
        threads,
        elapsed_secs: elapsed,
        req_per_sec: if elapsed > 0.0 { total as f64 / elapsed } else { f64::NAN },
        rows_per_sec: if elapsed > 0.0 {
            (total * rows.len()) as f64 / elapsed
        } else {
            f64::NAN
        },
        mean_ms,
        p50_ms: percentile(&latencies_ms, 0.50),
        p99_ms: percentile(&latencies_ms, 0.99),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::SolveStatus;

    fn toy_model() -> LoadedModel {
        LoadedModel::SparseRegression(
            crate::backbone::sparse_regression::SparseRegressionModel {
                beta: vec![1.0, -2.0, 0.5],
                intercept: 0.25,
                support: vec![0, 1, 2],
                objective: 1.0,
                gap: 0.0,
                status: SolveStatus::Optimal,
            },
        )
    }

    #[test]
    fn self_test_round_trips_with_zero_failures() {
        let report = run_self_test(
            toy_model(),
            &SelfTestConfig { requests: 24, concurrency: 3, batch_rows: 4, threads: 2 },
        )
        .unwrap();
        assert_eq!(report.requests, 24);
        assert_eq!(report.failed, 0, "loopback self-test had failures");
        assert!(report.req_per_sec > 0.0);
        assert!(report.p50_ms >= 0.0 && report.p99_ms >= report.p50_ms);
        let doc = report.to_json();
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some("backbone-serve-selftest/v1")
        );
        assert_eq!(doc.get("failed").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn synth_batch_respects_model_contracts() {
        let batch = synth_batch(&toy_model(), 8);
        assert_eq!(batch.len(), 8);
        assert!(batch.iter().all(|r| r.len() == 3));

        let cl = LoadedModel::Clustering(crate::backbone::clustering::ClusteringModel {
            labels: vec![0, 1, 0],
            objective: 0.0,
            gap: 0.0,
            status: SolveStatus::Optimal,
        });
        let batch = synth_batch(&cl, 8);
        assert_eq!(batch.len(), 3, "clustering batch must match training rows");
    }
}
