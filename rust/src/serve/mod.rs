//! The prediction server: a std-only, batched HTTP/1.1 inference service
//! over a loaded `backbone-model/v1` artifact.
//!
//! The ROADMAP's north star is serving backbone models under heavy
//! traffic; the backbone output is exactly the compact artifact that
//! makes that cheap. This module is the serving half of the persistence
//! subsystem (`cli serve --model m.json --port P --threads N`):
//!
//! - **No new dependencies** — `std::net::TcpListener` + scoped worker
//!   threads (`std::thread::scope`), mirroring the PR-2 subproblem
//!   scheduler idiom: shared immutable state behind an `Arc`, per-worker
//!   connection handling, atomics for the counters.
//! - **Batched** — one `POST /predict` carries any number of rows
//!   (`{"rows": [[...], ...]}`); inference is a single
//!   [`LoadedModel::predict_scores`] pass over the whole batch (the
//!   prediction view is derived from it, bit-identical to
//!   `try_predict`).
//! - **Observable** — `GET /healthz` for liveness, `GET /stats` for
//!   request/failure counters and a windowed latency profile
//!   (mean/p50/p99 over the most recent requests).
//!
//! The loopback load generator lives in [`selftest`]
//! (`cli serve --self-test`), which drives a real server over real
//! sockets and reports p50/p99/req-s in `backbone-bench/v1`-style JSON.

pub mod http;
pub mod selftest;

use crate::backbone::resolved_threads;
use crate::bench_support::percentile;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::persist::{LoadedModel, MODEL_SCHEMA};
use http::{read_request, write_json, Request};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server tunables.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads accepting and handling connections (0 = all cores).
    pub threads: usize,
    /// Cap on a request body (the batched rows payload).
    pub max_body_bytes: usize,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 2,
            max_body_bytes: 8 * 1024 * 1024,
            io_timeout: Duration::from_secs(10),
        }
    }
}

/// Sliding window of recent request latencies (microseconds). Bounded so
/// `/stats` stays O(window) regardless of uptime; the lifetime request
/// count is exact, the latency profile covers the most recent window.
struct LatencyWindow {
    samples: Vec<u64>,
    next: usize,
    count: u64,
}

const LATENCY_WINDOW: usize = 4096;

impl LatencyWindow {
    fn new() -> Self {
        Self { samples: Vec::with_capacity(LATENCY_WINDOW), next: 0, count: 0 }
    }

    fn record(&mut self, us: u64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
        self.count += 1;
    }

    /// `(lifetime count, unsorted window copy)` — a plain O(n) memcpy so
    /// the stats mutex is never held through a sort; callers order the
    /// samples after the lock is released.
    fn snapshot(&self) -> (u64, Vec<f64>) {
        (self.count, self.samples.iter().map(|&u| u as f64).collect())
    }
}

/// Request/latency counters surfaced by `GET /stats`.
pub struct ServerStats {
    requests: AtomicU64,
    predict_requests: AtomicU64,
    rows_predicted: AtomicU64,
    failures: AtomicU64,
    latency: Mutex<LatencyWindow>,
}

impl ServerStats {
    fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            predict_requests: AtomicU64::new(0),
            rows_predicted: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            latency: Mutex::new(LatencyWindow::new()),
        }
    }

    fn record_predict(&self, rows: usize, latency_us: u64) {
        self.predict_requests.fetch_add(1, Ordering::Relaxed);
        self.rows_predicted.fetch_add(rows as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().record(latency_us);
    }

    fn to_json(&self, uptime_secs: f64, threads: usize) -> Json {
        // The lock guard lives only for the snapshot statement; sorting
        // happens outside it so /stats polls never stall predict workers.
        let (count, mut window) = self.latency.lock().unwrap().snapshot();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = if window.is_empty() {
            f64::NAN
        } else {
            window.iter().sum::<f64>() / window.len() as f64
        };
        let mut latency = BTreeMap::new();
        latency.insert("count".into(), Json::Number(count as f64));
        // mean/p50/p99 summarize only the most recent `window` samples;
        // `count` is lifetime — surface the window size so consumers
        // can't conflate the two.
        latency.insert("window".into(), Json::Number(window.len() as f64));
        latency.insert("mean_us".into(), Json::from_f64(mean));
        latency.insert("p50_us".into(), Json::from_f64(percentile(&window, 0.50)));
        latency.insert("p99_us".into(), Json::from_f64(percentile(&window, 0.99)));
        let mut m = BTreeMap::new();
        m.insert(
            "requests_total".into(),
            Json::Number(self.requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "predict_requests".into(),
            Json::Number(self.predict_requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "rows_predicted".into(),
            Json::Number(self.rows_predicted.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "failures".into(),
            Json::Number(self.failures.load(Ordering::Relaxed) as f64),
        );
        m.insert("latency".into(), Json::Object(latency));
        m.insert("uptime_secs".into(), Json::from_f64(uptime_secs));
        m.insert("threads".into(), Json::Number(threads as f64));
        Json::Object(m)
    }
}

/// Shared state of a running server: the model plus observability.
pub struct ServerState {
    model: LoadedModel,
    stats: ServerStats,
    started: Instant,
    shutdown: AtomicBool,
    threads: usize,
    max_body: usize,
    io_timeout: Duration,
}

/// A bound (but not yet running) prediction server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

/// Handle for stopping a running server from another thread: sets the
/// shutdown flag, then pokes the listener once per worker so every
/// blocked `accept` wakes up and observes it.
pub struct ShutdownHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        for _ in 0..self.state.threads {
            let _ = TcpStream::connect(self.addr);
        }
    }
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:8000"`; port 0 for an ephemeral
    /// port) and prepare to serve `model`.
    pub fn bind(addr: &str, model: LoadedModel, cfg: &ServeConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let state = Arc::new(ServerState {
            model,
            stats: ServerStats::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            threads: resolved_threads(cfg.threads),
            max_body: cfg.max_body_bytes,
            io_timeout: cfg.io_timeout,
        });
        Ok(Server { listener, state })
    }

    /// Address the server is listening on (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Shutdown handle usable from other threads while `run` blocks.
    pub fn shutdown_handle(&self) -> std::io::Result<ShutdownHandle> {
        Ok(ShutdownHandle { addr: self.local_addr()?, state: Arc::clone(&self.state) })
    }

    /// Accept and serve connections on the configured worker threads
    /// until the shutdown flag is raised. Blocks the calling thread.
    pub fn run(self) {
        let listener = &self.listener;
        let state = &self.state;
        std::thread::scope(|scope| {
            for _ in 0..state.threads {
                scope.spawn(move || {
                    loop {
                        if state.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok((stream, _peer)) = listener.accept() else {
                            // Persistent accept failures (e.g. fd
                            // exhaustion) must not become a busy-spin
                            // that starves the connections already open.
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        };
                        // Serve whatever was accepted even if shutdown
                        // raced in — a real client that won the race gets
                        // its response; a ShutdownHandle poke reads as an
                        // instant EOF and is dropped without counters.
                        handle_connection(stream, state);
                        if state.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                });
            }
        });
    }
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(state.io_timeout));
    let _ = stream.set_write_timeout(Some(state.io_timeout));
    let request = match read_request(&mut stream, state.max_body) {
        Ok(req) => req,
        Err(e) => {
            // Only connections we actually answer enter the counters; a
            // bare connect-then-close (TCP health probe, shutdown poke)
            // is an Io error and stays invisible, so /stats failure
            // rates reflect served traffic, not probing.
            if let Some((status, reason)) = e.status() {
                state.stats.requests.fetch_add(1, Ordering::Relaxed);
                state.stats.failures.fetch_add(1, Ordering::Relaxed);
                let _ = write_json(&mut stream, status, reason, &error_body(&e.message()));
            }
            return;
        }
    };
    state.stats.requests.fetch_add(1, Ordering::Relaxed);
    let outcome = route(&request, state);
    let failed = !(200..300).contains(&outcome.status);
    if failed {
        state.stats.failures.fetch_add(1, Ordering::Relaxed);
    }
    let _ = write_json(&mut stream, outcome.status, outcome.reason, &outcome.body);
}

struct Outcome {
    status: u16,
    reason: &'static str,
    body: String,
}

fn ok(body: Json) -> Outcome {
    Outcome { status: 200, reason: "OK", body: body.to_string_compact() }
}

fn error(status: u16, reason: &'static str, message: &str) -> Outcome {
    Outcome { status, reason, body: error_body(message) }
}

fn error_body(message: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::String(message.into()));
    Json::Object(m).to_string_compact()
}

fn route(request: &Request, state: &ServerState) -> Outcome {
    match (request.method.as_str(), request.path.as_str()) {
        ("GET", "/healthz") => ok(health_json(state)),
        ("GET", "/stats") => ok(state
            .stats
            .to_json(state.started.elapsed().as_secs_f64(), state.threads)),
        ("POST", "/predict") => predict(request, state),
        ("GET" | "HEAD", "/predict") => {
            error(405, "Method Not Allowed", "use POST /predict with a JSON body")
        }
        _ => error(404, "Not Found", "routes: POST /predict, GET /healthz, GET /stats"),
    }
}

fn health_json(state: &ServerState) -> Json {
    let mut m = BTreeMap::new();
    m.insert("status".into(), Json::String("ok".into()));
    m.insert("schema".into(), Json::String(MODEL_SCHEMA.into()));
    m.insert("learner".into(), Json::String(state.model.kind().name().into()));
    if let Some(p) = state.model.num_features() {
        m.insert("num_features".into(), Json::Number(p as f64));
    }
    if let Some(n) = state.model.expected_rows() {
        m.insert("expected_rows".into(), Json::Number(n as f64));
    }
    m.insert(
        "uptime_secs".into(),
        Json::from_f64(state.started.elapsed().as_secs_f64()),
    );
    Json::Object(m)
}

/// `POST /predict`: parse the batched rows, run one batch inference,
/// answer with predictions (plus scores for the classifiers).
fn predict(request: &Request, state: &ServerState) -> Outcome {
    let started = Instant::now();
    let text = match std::str::from_utf8(&request.body) {
        Ok(t) => t,
        Err(_) => return error(400, "Bad Request", "body is not UTF-8"),
    };
    let doc = match Json::parse(text) {
        Ok(d) => d,
        Err(e) => return error(400, "Bad Request", &format!("body is not JSON: {e:#}")),
    };
    let rows = match parse_rows(&doc) {
        Ok(r) => r,
        Err(message) => return error(400, "Bad Request", &message),
    };
    let x = Matrix::from_rows(&rows);
    // One inference per request: scores are the expensive pass, the
    // prediction view is derived from them (bit-identical to
    // try_predict by the predictions_from_scores contract).
    let scores = match state.model.predict_scores(&x) {
        Ok(s) => s,
        Err(e) => return error(400, "Bad Request", &e.to_string()),
    };
    let predictions = state.model.predictions_from_scores(&scores);
    let latency_us = started.elapsed().as_micros() as u64;
    state.stats.record_predict(rows.len(), latency_us);

    let mut m = BTreeMap::new();
    m.insert(
        "predictions".into(),
        Json::Array(predictions.iter().map(|&p| Json::from_f64(p)).collect()),
    );
    if state.model.kind().is_classifier() {
        m.insert(
            "scores".into(),
            Json::Array(scores.iter().map(|&s| Json::from_f64(s)).collect()),
        );
    }
    m.insert("rows".into(), Json::Number(rows.len() as f64));
    m.insert("latency_us".into(), Json::Number(latency_us as f64));
    ok(Json::Object(m))
}

/// Extract `{"rows": [[...], ...]}` as a rectangular f64 batch.
fn parse_rows(doc: &Json) -> Result<Vec<Vec<f64>>, String> {
    let rows = doc
        .get("rows")
        .and_then(Json::as_array)
        .ok_or("body must be an object with a `rows` array of arrays")?;
    if rows.is_empty() {
        return Err("`rows` must contain at least one row".into());
    }
    let mut out = Vec::with_capacity(rows.len());
    let mut width: Option<usize> = None;
    for (i, row) in rows.iter().enumerate() {
        let cells = row
            .as_array()
            .ok_or_else(|| format!("rows[{i}] is not an array"))?;
        let mut values = Vec::with_capacity(cells.len());
        for (j, cell) in cells.iter().enumerate() {
            values.push(
                cell.as_f64_tagged()
                    .filter(|v| v.is_finite())
                    .ok_or_else(|| format!("rows[{i}][{j}] is not a finite number"))?,
            );
        }
        match width {
            None => width = Some(values.len()),
            Some(w) if w != values.len() => {
                return Err(format!(
                    "rows[{i}] has {} values but rows[0] has {w}",
                    values.len()
                ));
            }
            Some(_) => {}
        }
        out.push(values);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::LoadedModel;
    use crate::solvers::SolveStatus;

    fn toy_model() -> LoadedModel {
        LoadedModel::SparseRegression(crate::backbone::sparse_regression::SparseRegressionModel {
            beta: vec![2.0, 0.0, -1.0],
            intercept: 0.5,
            support: vec![0, 2],
            objective: 1.0,
            gap: 0.0,
            status: SolveStatus::Optimal,
        })
    }

    fn toy_state() -> ServerState {
        ServerState {
            model: toy_model(),
            stats: ServerStats::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            threads: 1,
            max_body: 1024,
            io_timeout: Duration::from_secs(1),
        }
    }

    fn post_predict(body: &str) -> Request {
        Request { method: "POST".into(), path: "/predict".into(), body: body.into() }
    }

    #[test]
    fn predict_route_computes_batch() {
        let state = toy_state();
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0], [0, 0, 1]]}"#), &state);
        assert_eq!(out.status, 200);
        let doc = Json::parse(&out.body).unwrap();
        let preds = doc.get("predictions").unwrap().as_array().unwrap();
        assert_eq!(preds[0].as_f64(), Some(2.5)); // 2*1 + 0.5
        assert_eq!(preds[1].as_f64(), Some(-0.5)); // -1*1 + 0.5
        assert_eq!(doc.get("rows").and_then(Json::as_usize), Some(2));
        assert_eq!(state.stats.rows_predicted.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn predict_route_rejects_bad_payloads() {
        let state = toy_state();
        for (body, hint) in [
            ("not json", "not JSON"),
            (r#"{"cols": []}"#, "`rows`"),
            (r#"{"rows": []}"#, "at least one"),
            (r#"{"rows": [[1, 2]]}"#, "incompatible"),
            (r#"{"rows": [[1, 2, 3], [1]]}"#, "rows[1]"),
            (r#"{"rows": [["a", 2, 3]]}"#, "finite number"),
        ] {
            let out = route(&post_predict(body), &state);
            assert_eq!(out.status, 400, "{body}");
            assert!(out.body.contains(hint), "{body} → {}", out.body);
        }
        assert_eq!(state.stats.predict_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let state = toy_state();
        let req = Request { method: "GET".into(), path: "/nope".into(), body: vec![] };
        assert_eq!(route(&req, &state).status, 404);
        let req = Request { method: "GET".into(), path: "/predict".into(), body: vec![] };
        assert_eq!(route(&req, &state).status, 405);
    }

    #[test]
    fn stats_json_reflects_recorded_latencies() {
        let state = toy_state();
        for us in [100, 200, 300] {
            state.stats.record_predict(1, us);
        }
        let doc = state.stats.to_json(1.0, 4);
        let lat = doc.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(3));
        assert_eq!(lat.get("p50_us").and_then(Json::as_f64), Some(200.0));
        assert_eq!(doc.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(doc.get("threads").and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn latency_window_stays_bounded() {
        let mut w = LatencyWindow::new();
        for i in 0..(LATENCY_WINDOW as u64 + 100) {
            w.record(i);
        }
        let (count, window) = w.snapshot();
        assert_eq!(count, LATENCY_WINDOW as u64 + 100);
        assert_eq!(window.len(), LATENCY_WINDOW);
        // The ring keeps the most recent LATENCY_WINDOW samples: the 100
        // oldest (0..100) were overwritten.
        assert_eq!(window.iter().copied().fold(f64::INFINITY, f64::min), 100.0);
        assert_eq!(
            window.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            (LATENCY_WINDOW + 99) as f64
        );
    }
}
