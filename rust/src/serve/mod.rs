//! The prediction server: a std-only, batched HTTP/1.1 inference service
//! over a loaded `backbone-model/v1` artifact.
//!
//! The ROADMAP's north star is serving backbone models under heavy
//! traffic; the backbone output is exactly the compact artifact that
//! makes that cheap. This module is the serving half of the persistence
//! subsystem (`cli serve --model m.json --port P --threads N`):
//!
//! - **No new dependencies** — `std::net::TcpListener` + scoped worker
//!   threads (`std::thread::scope`), mirroring the PR-2 subproblem
//!   scheduler idiom: shared immutable state behind an `Arc`, per-worker
//!   connection handling, atomics for the counters.
//! - **Batched** — one `POST /predict` carries any number of rows
//!   (`{"rows": [[...], ...]}`); inference is a single
//!   [`LoadedModel::predict_scores`] pass over the whole batch (the
//!   prediction view is derived from it, bit-identical to
//!   `try_predict`).
//! - **Observable** — `GET /healthz` for liveness, `GET /stats` for
//!   request/failure counters and a windowed latency profile
//!   (mean/p50/p99 over the most recent requests).
//!
//! The loopback load generator lives in [`selftest`]
//! (`cli serve --self-test`), which drives a real server over real
//! sockets and reports p50/p99/req-s in `backbone-bench/v1`-style JSON.

pub mod http;
pub mod selftest;

use crate::backbone::resolved_threads;
use crate::backbone::Backbone;
use crate::bench_support::percentile;
use crate::json::Json;
use crate::linalg::Matrix;
use crate::persist::{LoadedModel, MODEL_SCHEMA};
use crate::warmstart::{featurize, suggested_alpha, WarmStartStore};
use http::{read_request, write_json, Request};
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Server tunables.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads accepting and handling connections (0 = all cores).
    pub threads: usize,
    /// Cap on a request body (the batched rows payload).
    pub max_body_bytes: usize,
    /// Per-connection socket read/write timeout.
    pub io_timeout: Duration,
    /// Enable `POST /fit` (the online fit path). Off by default: fitting
    /// is orders of magnitude heavier than inference, so it must be an
    /// explicit opt-in (`cli serve --fit`).
    pub enable_fit: bool,
    /// Bounded queueing for `POST /fit`: at most this many fits run at
    /// once; excess requests are answered `429` immediately instead of
    /// occupying a worker thread behind a long solve.
    pub max_concurrent_fits: usize,
    /// Bound on models fitted online and held for `/predict` lookup by
    /// id; the oldest model is evicted first (deterministic FIFO).
    pub registry_capacity: usize,
    /// Bound on the warm-start store consulted/updated by `POST /fit`.
    pub warm_capacity: usize,
    /// Optional path of a `backbone-warmstart-store/v1` document: loaded
    /// at bind time (corrupt/missing degrades to an empty store) and
    /// written back after every successful fit.
    pub warm_cache_path: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 2,
            max_body_bytes: 8 * 1024 * 1024,
            io_timeout: Duration::from_secs(10),
            enable_fit: false,
            max_concurrent_fits: 1,
            registry_capacity: 16,
            warm_capacity: crate::warmstart::DEFAULT_STORE_CAPACITY,
            warm_cache_path: None,
        }
    }
}

/// Sliding window of recent request latencies (microseconds). Bounded so
/// `/stats` stays O(window) regardless of uptime; the lifetime request
/// count is exact, the latency profile covers the most recent window.
struct LatencyWindow {
    samples: Vec<u64>,
    next: usize,
    count: u64,
}

const LATENCY_WINDOW: usize = 4096;

impl LatencyWindow {
    fn new() -> Self {
        Self { samples: Vec::with_capacity(LATENCY_WINDOW), next: 0, count: 0 }
    }

    fn record(&mut self, us: u64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
        self.count += 1;
    }

    /// `(lifetime count, unsorted window copy)` — a plain O(n) memcpy so
    /// the stats mutex is never held through a sort; callers order the
    /// samples after the lock is released.
    fn snapshot(&self) -> (u64, Vec<f64>) {
        (self.count, self.samples.iter().map(|&u| u as f64).collect())
    }
}

/// Per-route request/failure/latency accounting. `/predict` and `/fit`
/// each own one of these so they are independently observable in
/// `GET /stats` — a slow fit queue can never hide in the predict
/// latency profile (and vice versa).
struct RouteStats {
    /// Requests routed here (attempts, including ones answered 4xx).
    requests: AtomicU64,
    /// Attempts answered with a non-2xx status.
    failures: AtomicU64,
    /// Work units completed: rows predicted / models fitted.
    units: AtomicU64,
    /// Latency of *successful* requests only.
    latency: Mutex<LatencyWindow>,
}

impl RouteStats {
    fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            units: AtomicU64::new(0),
            latency: Mutex::new(LatencyWindow::new()),
        }
    }

    fn record_ok(&self, units: usize, latency_us: u64) {
        self.units.fetch_add(units as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().record(latency_us);
    }

    /// `{requests, failures, <units_key>, latency: {...}}`.
    fn to_json(&self, units_key: &str) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "requests".into(),
            Json::Number(self.requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "failures".into(),
            Json::Number(self.failures.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            units_key.into(),
            Json::Number(self.units.load(Ordering::Relaxed) as f64),
        );
        m.insert("latency".into(), self.latency_json());
        Json::Object(m)
    }

    fn latency_json(&self) -> Json {
        // The lock guard lives only for the snapshot statement; sorting
        // happens outside it so /stats polls never stall the workers.
        let (count, mut window) = self.latency.lock().unwrap().snapshot();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = if window.is_empty() {
            f64::NAN
        } else {
            window.iter().sum::<f64>() / window.len() as f64
        };
        let mut latency = BTreeMap::new();
        latency.insert("count".into(), Json::Number(count as f64));
        // mean/p50/p99 summarize only the most recent `window` samples;
        // `count` is lifetime — surface the window size so consumers
        // can't conflate the two.
        latency.insert("window".into(), Json::Number(window.len() as f64));
        latency.insert("mean_us".into(), Json::from_f64(mean));
        latency.insert("p50_us".into(), Json::from_f64(percentile(&window, 0.50)));
        latency.insert("p99_us".into(), Json::from_f64(percentile(&window, 0.99)));
        Json::Object(latency)
    }
}

/// Request/latency counters surfaced by `GET /stats`.
pub struct ServerStats {
    requests: AtomicU64,
    failures: AtomicU64,
    predict: RouteStats,
    fit: RouteStats,
}

impl ServerStats {
    fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            predict: RouteStats::new(),
            fit: RouteStats::new(),
        }
    }

    fn record_predict(&self, rows: usize, latency_us: u64) {
        self.predict.record_ok(rows, latency_us);
    }

    fn to_json(&self, uptime_secs: f64, threads: usize) -> Json {
        let mut routes = BTreeMap::new();
        routes.insert("fit".into(), self.fit.to_json("models_fitted"));
        routes.insert("predict".into(), self.predict.to_json("rows_predicted"));
        let mut m = BTreeMap::new();
        m.insert(
            "requests_total".into(),
            Json::Number(self.requests.load(Ordering::Relaxed) as f64),
        );
        // Pre-split consumers read the predict route's numbers at the
        // top level; keep those keys as mirrors of `routes.predict`.
        let (predict_ok, _) = self.predict.latency.lock().unwrap().snapshot();
        m.insert("predict_requests".into(), Json::Number(predict_ok as f64));
        m.insert(
            "rows_predicted".into(),
            Json::Number(self.predict.units.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "failures".into(),
            Json::Number(self.failures.load(Ordering::Relaxed) as f64),
        );
        m.insert("latency".into(), self.predict.latency_json());
        m.insert("routes".into(), Json::Object(routes));
        m.insert("uptime_secs".into(), Json::from_f64(uptime_secs));
        m.insert("threads".into(), Json::Number(threads as f64));
        Json::Object(m)
    }
}

/// Models fitted online through `POST /fit`, addressable from
/// `/predict` by id. Bounded: the oldest model is evicted first, so a
/// long-running fit service cannot grow without limit. Ids are assigned
/// from a monotone counter (`m1`, `m2`, …) — deterministic for a given
/// request order, never wall clock.
struct ModelRegistry {
    models: BTreeMap<String, Arc<LoadedModel>>,
    order: VecDeque<String>,
    next_id: u64,
    capacity: usize,
}

impl ModelRegistry {
    fn new(capacity: usize) -> Self {
        Self {
            models: BTreeMap::new(),
            order: VecDeque::new(),
            next_id: 0,
            capacity: capacity.max(1),
        }
    }

    fn insert(&mut self, model: LoadedModel) -> String {
        self.next_id += 1;
        let id = format!("m{}", self.next_id);
        self.models.insert(id.clone(), Arc::new(model));
        self.order.push_back(id.clone());
        while self.models.len() > self.capacity {
            if let Some(old) = self.order.pop_front() {
                self.models.remove(&old);
            }
        }
        id
    }

    fn get(&self, id: &str) -> Option<Arc<LoadedModel>> {
        self.models.get(id).cloned()
    }

    fn len(&self) -> usize {
        self.models.len()
    }
}

/// Shared state of a running server: the model plus observability and
/// (when `--fit` is enabled) the online-fit machinery.
pub struct ServerState {
    model: LoadedModel,
    stats: ServerStats,
    started: Instant,
    shutdown: AtomicBool,
    threads: usize,
    max_body: usize,
    io_timeout: Duration,
    fit_enabled: bool,
    /// Fits currently executing; the admission gate for bounded queueing.
    fits_in_flight: AtomicU64,
    max_concurrent_fits: u64,
    registry: Mutex<ModelRegistry>,
    warm: Mutex<WarmStartStore>,
    /// Typed load failure of the warm cache at bind time (the store
    /// degraded to empty; fits stay cold until it repopulates).
    warm_error: Option<String>,
    warm_cache_path: Option<String>,
}

/// A bound (but not yet running) prediction server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

/// Handle for stopping a running server from another thread: sets the
/// shutdown flag, then pokes the listener once per worker so every
/// blocked `accept` wakes up and observes it.
pub struct ShutdownHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        for _ in 0..self.state.threads {
            let _ = TcpStream::connect(self.addr);
        }
    }
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:8000"`; port 0 for an ephemeral
    /// port) and prepare to serve `model`.
    pub fn bind(addr: &str, model: LoadedModel, cfg: &ServeConfig) -> std::io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let (warm, warm_error) = match &cfg.warm_cache_path {
            Some(path) => {
                let (store, err) = WarmStartStore::load_or_empty(path, cfg.warm_capacity);
                (store, err.map(|e| e.to_string()))
            }
            None => (WarmStartStore::new(cfg.warm_capacity), None),
        };
        let state = Arc::new(ServerState {
            model,
            stats: ServerStats::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            threads: resolved_threads(cfg.threads),
            max_body: cfg.max_body_bytes,
            io_timeout: cfg.io_timeout,
            fit_enabled: cfg.enable_fit,
            fits_in_flight: AtomicU64::new(0),
            max_concurrent_fits: cfg.max_concurrent_fits.max(1) as u64,
            registry: Mutex::new(ModelRegistry::new(cfg.registry_capacity)),
            warm: Mutex::new(warm),
            warm_error,
            warm_cache_path: cfg.warm_cache_path.clone(),
        });
        Ok(Server { listener, state })
    }

    /// Address the server is listening on (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Typed load error from the warm-start store, if the configured
    /// `warm_cache_path` existed but could not be parsed (the server
    /// still starts, degraded to cold fits).
    pub fn warm_store_error(&self) -> Option<&str> {
        self.state.warm_error.as_deref()
    }

    /// Shutdown handle usable from other threads while `run` blocks.
    pub fn shutdown_handle(&self) -> std::io::Result<ShutdownHandle> {
        Ok(ShutdownHandle { addr: self.local_addr()?, state: Arc::clone(&self.state) })
    }

    /// Accept and serve connections on the configured worker threads
    /// until the shutdown flag is raised. Blocks the calling thread.
    pub fn run(self) {
        let listener = &self.listener;
        let state = &self.state;
        std::thread::scope(|scope| {
            for _ in 0..state.threads {
                scope.spawn(move || {
                    loop {
                        if state.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        let Ok((stream, _peer)) = listener.accept() else {
                            // Persistent accept failures (e.g. fd
                            // exhaustion) must not become a busy-spin
                            // that starves the connections already open.
                            std::thread::sleep(Duration::from_millis(10));
                            continue;
                        };
                        // Serve whatever was accepted even if shutdown
                        // raced in — a real client that won the race gets
                        // its response; a ShutdownHandle poke reads as an
                        // instant EOF and is dropped without counters.
                        handle_connection(stream, state);
                        if state.shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                });
            }
        });
    }
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(state.io_timeout));
    let _ = stream.set_write_timeout(Some(state.io_timeout));
    let request = match read_request(&mut stream, state.max_body) {
        Ok(req) => req,
        Err(e) => {
            // Only connections we actually answer enter the counters; a
            // bare connect-then-close (TCP health probe, shutdown poke)
            // is an Io error and stays invisible, so /stats failure
            // rates reflect served traffic, not probing.
            if let Some((status, reason)) = e.status() {
                state.stats.requests.fetch_add(1, Ordering::Relaxed);
                state.stats.failures.fetch_add(1, Ordering::Relaxed);
                let _ = write_json(&mut stream, status, reason, &error_body(&e.message()));
            }
            return;
        }
    };
    state.stats.requests.fetch_add(1, Ordering::Relaxed);
    let outcome = route(&request, state);
    let failed = !(200..300).contains(&outcome.status);
    if failed {
        state.stats.failures.fetch_add(1, Ordering::Relaxed);
    }
    let _ = write_json(&mut stream, outcome.status, outcome.reason, &outcome.body);
}

struct Outcome {
    status: u16,
    reason: &'static str,
    body: String,
}

fn ok(body: Json) -> Outcome {
    Outcome { status: 200, reason: "OK", body: body.to_string_compact() }
}

fn error(status: u16, reason: &'static str, message: &str) -> Outcome {
    Outcome { status, reason, body: error_body(message) }
}

fn error_body(message: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::String(message.into()));
    Json::Object(m).to_string_compact()
}

fn route(request: &Request, state: &ServerState) -> Outcome {
    match (request.method.as_str(), request.path.as_str()) {
        ("GET", "/healthz") => ok(health_json(state)),
        ("GET", "/stats") => ok(state
            .stats
            .to_json(state.started.elapsed().as_secs_f64(), state.threads)),
        ("POST", "/predict") => noted(&state.stats.predict, predict(request, state)),
        ("POST", "/fit") if state.fit_enabled => noted(&state.stats.fit, fit(request, state)),
        ("POST", "/fit") => error(
            403,
            "Forbidden",
            "fit endpoint disabled; start the server with --fit",
        ),
        ("GET" | "HEAD", "/predict") => {
            error(405, "Method Not Allowed", "use POST /predict with a JSON body")
        }
        ("GET" | "HEAD", "/fit") => {
            error(405, "Method Not Allowed", "use POST /fit with a JSON body")
        }
        _ => error(
            404,
            "Not Found",
            "routes: POST /predict, POST /fit, GET /healthz, GET /stats",
        ),
    }
}

/// Enter `outcome` into a route's attempt/failure counters (success
/// latency/units were already recorded by the handler itself).
fn noted(route_stats: &RouteStats, outcome: Outcome) -> Outcome {
    route_stats.requests.fetch_add(1, Ordering::Relaxed);
    if !(200..300).contains(&outcome.status) {
        route_stats.failures.fetch_add(1, Ordering::Relaxed);
    }
    outcome
}

fn health_json(state: &ServerState) -> Json {
    let mut m = BTreeMap::new();
    m.insert("status".into(), Json::String("ok".into()));
    m.insert("schema".into(), Json::String(MODEL_SCHEMA.into()));
    m.insert("learner".into(), Json::String(state.model.kind().name().into()));
    if let Some(p) = state.model.num_features() {
        m.insert("num_features".into(), Json::Number(p as f64));
    }
    if let Some(n) = state.model.expected_rows() {
        m.insert("expected_rows".into(), Json::Number(n as f64));
    }
    m.insert("fit_enabled".into(), Json::Bool(state.fit_enabled));
    if state.fit_enabled {
        m.insert(
            "models_online".into(),
            Json::Number(state.registry.lock().unwrap().len() as f64),
        );
        m.insert(
            "warm_store_entries".into(),
            Json::Number(state.warm.lock().unwrap().len() as f64),
        );
        if let Some(err) = &state.warm_error {
            m.insert("warm_store_error".into(), Json::String(err.clone()));
        }
    }
    m.insert(
        "uptime_secs".into(),
        Json::from_f64(state.started.elapsed().as_secs_f64()),
    );
    Json::Object(m)
}

/// `POST /predict`: parse the batched rows, run one batch inference,
/// answer with predictions (plus scores for the classifiers). An
/// optional `"model"` field addresses a model fitted online through
/// `POST /fit`; without it, the model the server was started with.
fn predict(request: &Request, state: &ServerState) -> Outcome {
    let started = Instant::now();
    let text = match std::str::from_utf8(&request.body) {
        Ok(t) => t,
        Err(_) => return error(400, "Bad Request", "body is not UTF-8"),
    };
    let doc = match Json::parse(text) {
        Ok(d) => d,
        Err(e) => return error(400, "Bad Request", &format!("body is not JSON: {e:#}")),
    };
    let rows = match parse_matrix(&doc, "rows") {
        Ok(r) => r,
        Err(message) => return error(400, "Bad Request", &message),
    };
    let online = match doc.get("model").and_then(Json::as_str) {
        Some(id) => match state.registry.lock().unwrap().get(id) {
            Some(m) => Some(m),
            None => {
                return error(
                    404,
                    "Not Found",
                    &format!("unknown model id `{id}` (evicted or never fitted)"),
                );
            }
        },
        None => None,
    };
    let model: &LoadedModel = online.as_deref().unwrap_or(&state.model);
    let x = Matrix::from_rows(&rows);
    // One inference per request: scores are the expensive pass, the
    // prediction view is derived from them (bit-identical to
    // try_predict by the predictions_from_scores contract).
    let scores = match model.predict_scores(&x) {
        Ok(s) => s,
        Err(e) => return error(400, "Bad Request", &e.to_string()),
    };
    let predictions = model.predictions_from_scores(&scores);
    let latency_us = started.elapsed().as_micros() as u64;
    state.stats.record_predict(rows.len(), latency_us);

    let mut m = BTreeMap::new();
    m.insert(
        "predictions".into(),
        Json::Array(predictions.iter().map(|&p| Json::from_f64(p)).collect()),
    );
    if model.kind().is_classifier() {
        m.insert(
            "scores".into(),
            Json::Array(scores.iter().map(|&s| Json::from_f64(s)).collect()),
        );
    }
    m.insert("rows".into(), Json::Number(rows.len() as f64));
    m.insert("latency_us".into(), Json::Number(latency_us as f64));
    ok(Json::Object(m))
}

/// `POST /fit`: fit a sparse-regression model online and register it
/// for `/predict` by id. Body:
///
/// ```json
/// {"x": [[...], ...], "y": [...], "k": 5,
///  "alpha": 0.5, "beta": 0.5, "m": 5, "seed": 0, "warm": true}
/// ```
///
/// Only `x`, `y`, `k` are required. With `"warm"` (default true) the
/// warm-start store is consulted first: an exact feature match serves
/// the cached solution immediately (no solve), a near neighbor
/// warm-starts the backbone with a shrunk screening fraction, and every
/// solved fit is written back to the store.
fn fit(request: &Request, state: &ServerState) -> Outcome {
    // Bounded queueing: admission is a single atomic increment; a full
    // queue is answered 429 immediately instead of parking a worker
    // thread behind someone else's solve.
    let in_flight = state.fits_in_flight.fetch_add(1, Ordering::SeqCst);
    let outcome = if in_flight >= state.max_concurrent_fits {
        error(
            429,
            "Too Many Requests",
            "fit queue is full; retry after the running fit completes",
        )
    } else {
        fit_inner(request, state)
    };
    state.fits_in_flight.fetch_sub(1, Ordering::SeqCst);
    outcome
}

fn fit_inner(request: &Request, state: &ServerState) -> Outcome {
    let started = Instant::now();
    let text = match std::str::from_utf8(&request.body) {
        Ok(t) => t,
        Err(_) => return error(400, "Bad Request", "body is not UTF-8"),
    };
    let doc = match Json::parse(text) {
        Ok(d) => d,
        Err(e) => return error(400, "Bad Request", &format!("body is not JSON: {e:#}")),
    };
    let rows = match parse_matrix(&doc, "x") {
        Ok(r) => r,
        Err(message) => return error(400, "Bad Request", &message),
    };
    let y: Vec<f64> = match doc.get("y").and_then(Json::as_array) {
        Some(arr) => {
            let mut out = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                match v.as_f64_tagged().filter(|v| v.is_finite()) {
                    Some(v) => out.push(v),
                    None => {
                        return error(
                            400,
                            "Bad Request",
                            &format!("y[{i}] is not a finite number"),
                        );
                    }
                }
            }
            out
        }
        None => return error(400, "Bad Request", "body must have a `y` array"),
    };
    if y.len() != rows.len() {
        return error(
            400,
            "Bad Request",
            &format!("x has {} rows but y has {} values", rows.len(), y.len()),
        );
    }
    let Some(k) = doc.get("k").and_then(Json::as_usize).filter(|&k| k >= 1) else {
        return error(400, "Bad Request", "body must have an integer `k` ≥ 1");
    };
    let x = Matrix::from_rows(&rows);
    if k > x.cols() {
        return error(400, "Bad Request", "`k` exceeds the number of columns in `x`");
    }
    let alpha = doc.get("alpha").and_then(Json::as_f64_tagged).unwrap_or(0.5);
    let beta = doc.get("beta").and_then(Json::as_f64_tagged).unwrap_or(0.5);
    let m_sub = doc.get("m").and_then(Json::as_usize).unwrap_or(5);
    let seed = doc.get("seed").and_then(Json::as_usize).unwrap_or(0) as u64;
    let warm_wanted = doc.get("warm").and_then(Json::as_bool).unwrap_or(true);

    let features = featurize(&x, &y, k);
    let suggestion = if warm_wanted {
        state.warm.lock().unwrap().suggest(&features)
    } else {
        None
    };

    let mut warm_info = BTreeMap::new();
    warm_info.insert("enabled".into(), Json::Bool(warm_wanted));
    if let Some(err) = &state.warm_error {
        warm_info.insert("store_error".into(), Json::String(err.clone()));
    }

    // Exact feature match: the instance was fitted before, so the cached
    // solution *is* the solution — serve it immediately (mlopt-style
    // "online MIO in milliseconds") through the same registry path.
    if let Some(w) = suggestion.as_ref().filter(|w| w.exact && w.beta.len() == x.cols()) {
        let model = crate::backbone::sparse_regression::SparseRegressionModel {
            beta: w.beta.clone(),
            intercept: w.intercept,
            support: w.support.clone(),
            objective: w.objective,
            gap: f64::NAN,
            status: crate::solvers::SolveStatus::Optimal,
        };
        let model_id =
            state.registry.lock().unwrap().insert(LoadedModel::SparseRegression(model));
        warm_info.insert("hit".into(), Json::String("exact".into()));
        warm_info.insert("distance".into(), Json::from_f64(0.0));
        let latency_us = started.elapsed().as_micros() as u64;
        state.stats.fit.record_ok(1, latency_us);
        return ok(fit_response(
            model_id,
            &w.support,
            w.objective,
            w.support.len(),
            latency_us,
            warm_info,
            state,
        ));
    }

    // Cold or neighbor-warm solve. A neighbor supplies the warm iterate
    // and a shrunk screening fraction; its support is seeded into the
    // universe so the small alpha cannot screen it out.
    let (fit_alpha, warm_beta) = match &suggestion {
        Some(w) if w.beta.len() == x.cols() => {
            warm_info.insert("hit".into(), Json::String("neighbor".into()));
            warm_info.insert("distance".into(), Json::from_f64(w.distance));
            (suggested_alpha(x.cols(), k), Some(w.beta.clone()))
        }
        _ => {
            warm_info.insert("hit".into(), Json::String("none".into()));
            (alpha, None)
        }
    };
    let mut builder = Backbone::sparse_regression()
        .alpha(fit_alpha)
        .beta(beta)
        .num_subproblems(m_sub)
        .max_nonzeros(k)
        .seed(seed);
    if let Some(w) = warm_beta {
        builder = builder.warm_start(w);
    }
    let mut bb = match builder.build() {
        Ok(bb) => bb,
        Err(e) => return error(400, "Bad Request", &e.to_string()),
    };
    let model = match bb.fit(&x, &y) {
        Ok(m) => m.clone(),
        Err(e) => return error(400, "Bad Request", &e.to_string()),
    };

    // Write-through: remember this fit for future instances, and persist
    // the store when the server was given a cache path.
    {
        let mut store = state.warm.lock().unwrap();
        let coefficients: Vec<f64> =
            model.support.iter().map(|&j| model.beta[j]).collect();
        store.record(
            &features,
            &model.support,
            &coefficients,
            model.intercept,
            model.objective,
            fit_alpha,
        );
        if let Some(path) = &state.warm_cache_path {
            if let Err(e) = store.save(path) {
                eprintln!("warning: {e}");
            }
        }
    }

    let support = model.support.clone();
    let objective = model.objective;
    let backbone_size =
        bb.last_diagnostics.as_ref().map(|d| d.backbone_size).unwrap_or(support.len());
    let model_id =
        state.registry.lock().unwrap().insert(LoadedModel::SparseRegression(model));
    let latency_us = started.elapsed().as_micros() as u64;
    state.stats.fit.record_ok(1, latency_us);
    ok(fit_response(
        model_id,
        &support,
        objective,
        backbone_size,
        latency_us,
        warm_info,
        state,
    ))
}

fn fit_response(
    model_id: String,
    support: &[usize],
    objective: f64,
    backbone_size: usize,
    latency_us: u64,
    mut warm_info: BTreeMap<String, Json>,
    state: &ServerState,
) -> Json {
    warm_info.insert(
        "store_entries".into(),
        Json::Number(state.warm.lock().unwrap().len() as f64),
    );
    let mut m = BTreeMap::new();
    m.insert("model_id".into(), Json::String(model_id));
    m.insert(
        "support".into(),
        Json::Array(support.iter().map(|&j| Json::Number(j as f64)).collect()),
    );
    m.insert("objective".into(), Json::from_f64(objective));
    m.insert("backbone_size".into(), Json::Number(backbone_size as f64));
    m.insert("latency_us".into(), Json::Number(latency_us as f64));
    m.insert("warm".into(), Json::Object(warm_info));
    Json::Object(m)
}

/// Extract `{"<key>": [[...], ...]}` as a rectangular f64 batch.
fn parse_matrix(doc: &Json, key: &str) -> Result<Vec<Vec<f64>>, String> {
    let rows = doc
        .get(key)
        .and_then(Json::as_array)
        .ok_or_else(|| format!("body must be an object with a `{key}` array of arrays"))?;
    if rows.is_empty() {
        return Err(format!("`{key}` must contain at least one row"));
    }
    let mut out = Vec::with_capacity(rows.len());
    let mut width: Option<usize> = None;
    for (i, row) in rows.iter().enumerate() {
        let cells = row
            .as_array()
            .ok_or_else(|| format!("{key}[{i}] is not an array"))?;
        let mut values = Vec::with_capacity(cells.len());
        for (j, cell) in cells.iter().enumerate() {
            values.push(
                cell.as_f64_tagged()
                    .filter(|v| v.is_finite())
                    .ok_or_else(|| format!("{key}[{i}][{j}] is not a finite number"))?,
            );
        }
        match width {
            None => width = Some(values.len()),
            Some(w) if w != values.len() => {
                return Err(format!(
                    "{key}[{i}] has {} values but {key}[0] has {w}",
                    values.len()
                ));
            }
            Some(_) => {}
        }
        out.push(values);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::LoadedModel;
    use crate::solvers::SolveStatus;

    fn toy_model() -> LoadedModel {
        LoadedModel::SparseRegression(crate::backbone::sparse_regression::SparseRegressionModel {
            beta: vec![2.0, 0.0, -1.0],
            intercept: 0.5,
            support: vec![0, 2],
            objective: 1.0,
            gap: 0.0,
            status: SolveStatus::Optimal,
        })
    }

    fn toy_state() -> ServerState {
        toy_state_with(false)
    }

    fn toy_state_with(fit_enabled: bool) -> ServerState {
        ServerState {
            model: toy_model(),
            stats: ServerStats::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            threads: 1,
            max_body: 1024,
            io_timeout: Duration::from_secs(1),
            fit_enabled,
            fits_in_flight: AtomicU64::new(0),
            max_concurrent_fits: 1,
            registry: Mutex::new(ModelRegistry::new(4)),
            warm: Mutex::new(WarmStartStore::new(8)),
            warm_error: None,
            warm_cache_path: None,
        }
    }

    fn post_predict(body: &str) -> Request {
        Request { method: "POST".into(), path: "/predict".into(), body: body.into() }
    }

    fn post_fit(body: &str) -> Request {
        Request { method: "POST".into(), path: "/fit".into(), body: body.into() }
    }

    #[test]
    fn predict_route_computes_batch() {
        let state = toy_state();
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0], [0, 0, 1]]}"#), &state);
        assert_eq!(out.status, 200);
        let doc = Json::parse(&out.body).unwrap();
        let preds = doc.get("predictions").unwrap().as_array().unwrap();
        assert_eq!(preds[0].as_f64(), Some(2.5)); // 2*1 + 0.5
        assert_eq!(preds[1].as_f64(), Some(-0.5)); // -1*1 + 0.5
        assert_eq!(doc.get("rows").and_then(Json::as_usize), Some(2));
        assert_eq!(state.stats.predict.units.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn predict_route_rejects_bad_payloads() {
        let state = toy_state();
        for (body, hint) in [
            ("not json", "not JSON"),
            (r#"{"cols": []}"#, "`rows`"),
            (r#"{"rows": []}"#, "at least one"),
            (r#"{"rows": [[1, 2]]}"#, "incompatible"),
            (r#"{"rows": [[1, 2, 3], [1]]}"#, "rows[1]"),
            (r#"{"rows": [["a", 2, 3]]}"#, "finite number"),
        ] {
            let out = route(&post_predict(body), &state);
            assert_eq!(out.status, 400, "{body}");
            assert!(out.body.contains(hint), "{body} → {}", out.body);
        }
        // Six attempts, six failures, zero completed predictions.
        assert_eq!(state.stats.predict.requests.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.predict.failures.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.predict.units.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let state = toy_state();
        let req = Request { method: "GET".into(), path: "/nope".into(), body: vec![] };
        assert_eq!(route(&req, &state).status, 404);
        let req = Request { method: "GET".into(), path: "/predict".into(), body: vec![] };
        assert_eq!(route(&req, &state).status, 405);
    }

    #[test]
    fn stats_json_reflects_recorded_latencies() {
        let state = toy_state();
        for us in [100, 200, 300] {
            state.stats.record_predict(1, us);
        }
        let doc = state.stats.to_json(1.0, 4);
        let lat = doc.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(3));
        assert_eq!(lat.get("p50_us").and_then(Json::as_f64), Some(200.0));
        assert_eq!(doc.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(doc.get("threads").and_then(Json::as_usize), Some(4));
        // Per-route split: predict and fit are independently observable.
        let routes = doc.get("routes").unwrap();
        let predict = routes.get("predict").unwrap();
        assert_eq!(predict.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(
            predict.get("latency").unwrap().get("count").and_then(Json::as_usize),
            Some(3)
        );
        let fit = routes.get("fit").unwrap();
        assert_eq!(fit.get("models_fitted").and_then(Json::as_usize), Some(0));
        assert_eq!(fit.get("requests").and_then(Json::as_usize), Some(0));
        assert_eq!(
            fit.get("latency").unwrap().get("count").and_then(Json::as_usize),
            Some(0)
        );
    }

    /// Tiny deterministic fit body: y = 2·x₀ on 8 rows of 3 features.
    fn fit_body() -> &'static str {
        r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1], [5, 0, 0], [6, 1, 0], [7, 0, 1], [8, 1, 1]],
            "y": [2, 4, 6, 8, 10, 12, 14, 16], "k": 1, "m": 2}"#
    }

    #[test]
    fn fit_route_is_gated_behind_enable_fit() {
        let state = toy_state_with(false);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 403);
        assert!(out.body.contains("--fit"), "{}", out.body);
        assert_eq!(state.stats.fit.requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fit_route_fits_registers_and_serves_the_model() {
        let state = toy_state_with(true);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        let model_id = doc.get("model_id").and_then(Json::as_str).unwrap().to_string();
        let support = doc.get("support").unwrap().as_array().unwrap();
        assert_eq!(support.len(), 1);
        assert_eq!(support[0].as_usize(), Some(0));
        let warm = doc.get("warm").unwrap();
        assert_eq!(warm.get("hit").and_then(Json::as_str), Some("none"));
        assert_eq!(warm.get("store_entries").and_then(Json::as_usize), Some(1));
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 1);

        // The fitted model serves /predict by id...
        let body = format!(r#"{{"rows": [[10, 0, 0]], "model": "{model_id}"}}"#);
        let out = route(&post_predict(&body), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        let pred = doc.get("predictions").unwrap().as_array().unwrap()[0].as_f64().unwrap();
        // Small ridge penalty (λ₂ default) shrinks the slope slightly.
        assert!((pred - 20.0).abs() < 0.1, "pred={pred}");
        // ...and an unknown id is a clean 404, not the default model.
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0]], "model": "m999"}"#), &state);
        assert_eq!(out.status, 404);
    }

    #[test]
    fn repeat_fit_is_an_exact_warm_hit_with_identical_objective() {
        let state = toy_state_with(true);
        let cold = route(&post_fit(fit_body()), &state);
        assert_eq!(cold.status, 200, "{}", cold.body);
        let cold_doc = Json::parse(&cold.body).unwrap();
        let warm = route(&post_fit(fit_body()), &state);
        assert_eq!(warm.status, 200, "{}", warm.body);
        let warm_doc = Json::parse(&warm.body).unwrap();
        assert_eq!(
            warm_doc.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
        // Bit-identical objective: the cached solution is served as-is.
        let cold_obj = cold_doc.get("objective").unwrap().as_f64_tagged().unwrap();
        let warm_obj = warm_doc.get("objective").unwrap().as_f64_tagged().unwrap();
        assert_eq!(cold_obj.to_bits(), warm_obj.to_bits());
        // Both fits got distinct registry ids.
        assert_ne!(
            cold_doc.get("model_id").and_then(Json::as_str),
            warm_doc.get("model_id").and_then(Json::as_str)
        );
    }

    #[test]
    fn fit_route_rejects_bad_payloads_with_400() {
        let state = toy_state_with(true);
        for (body, hint) in [
            ("nope", "not JSON"),
            (r#"{"y": [1], "k": 1}"#, "`x`"),
            (r#"{"x": [[1, 2]], "k": 1}"#, "`y`"),
            (r#"{"x": [[1, 2]], "y": [1, 2], "k": 1}"#, "rows but y"),
            (r#"{"x": [[1, 2]], "y": [1]}"#, "`k`"),
            (r#"{"x": [[1, 2]], "y": [1], "k": 3}"#, "exceeds"),
        ] {
            let out = route(&post_fit(body), &state);
            assert_eq!(out.status, 400, "{body}");
            assert!(out.body.contains(hint), "{body} → {}", out.body);
        }
        assert_eq!(state.stats.fit.failures.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fit_queue_overflow_returns_429() {
        let state = toy_state_with(true);
        // Simulate a fit already in flight; the gate must bounce us.
        state.fits_in_flight.store(1, Ordering::SeqCst);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 429, "{}", out.body);
        state.fits_in_flight.store(0, Ordering::SeqCst);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 200, "{}", out.body);
    }

    #[test]
    fn model_registry_evicts_oldest_deterministically() {
        let mut reg = ModelRegistry::new(2);
        let a = reg.insert(toy_model());
        let b = reg.insert(toy_model());
        let c = reg.insert(toy_model());
        assert_eq!((a.as_str(), b.as_str(), c.as_str()), ("m1", "m2", "m3"));
        assert_eq!(reg.len(), 2);
        assert!(reg.get("m1").is_none(), "oldest model must be evicted first");
        assert!(reg.get("m2").is_some());
        assert!(reg.get("m3").is_some());
    }

    #[test]
    fn latency_window_stays_bounded() {
        let mut w = LatencyWindow::new();
        for i in 0..(LATENCY_WINDOW as u64 + 100) {
            w.record(i);
        }
        let (count, window) = w.snapshot();
        assert_eq!(count, LATENCY_WINDOW as u64 + 100);
        assert_eq!(window.len(), LATENCY_WINDOW);
        // The ring keeps the most recent LATENCY_WINDOW samples: the 100
        // oldest (0..100) were overwritten.
        assert_eq!(window.iter().copied().fold(f64::INFINITY, f64::min), 100.0);
        assert_eq!(
            window.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            (LATENCY_WINDOW + 99) as f64
        );
    }
}
