//! The serving tier: a std-only, multi-model HTTP/1.1 inference service
//! over `backbone-model/v1` artifacts.
//!
//! The ROADMAP's north star is serving backbone models under heavy
//! traffic; PR 7 grows PR 5/6's one-model, one-request-per-connection
//! server into a production-grade tier:
//!
//! - **Keep-alive** — each accepted connection runs a request loop
//!   (HTTP/1.1 semantics; `Connection: close` opts out) with separate
//!   read and idle timeouts, so a client paying one TCP handshake can
//!   stream thousands of predict calls. One acceptor hands each
//!   connection to a dedicated handler thread (bounded by
//!   `max_connections`; excess connections get an immediate `503` +
//!   `Retry-After`), so long-lived clients can never starve new
//!   connections, health probes, or the hot-swap `PUT` out of `accept`.
//! - **Multi-model, path-routed** — a versioned [`registry`] holds
//!   named models (`--model name=path`, pinned) next to online-fitted
//!   ones (`m1`, `m2`, … bounded FIFO); `POST /models/<id>/predict`
//!   routes by path, `GET /models` lists the namespace, and
//!   `PUT /models/<id>` hot-swaps an artifact behind an `Arc` so
//!   in-flight requests finish on the old version and zero drop.
//! - **Explicit backpressure** — both fit and predict admission are
//!   bounded atomic gates answering `429` + `Retry-After` (header and
//!   structured body) instead of queueing without bound.
//! - **Redesigned API** — [`ServeConfig::builder()`] with typed
//!   [`ServeError`]s replaces the public-field bag (kept one release as
//!   the deprecated `ServeConfigFields` shim), and dispatch is a
//!   [`router::Route`] trait + registration table instead of an
//!   if-chain; handlers live in [`routes`].
//!
//! The loopback load generator lives in [`selftest`]
//! (`cli serve --self-test`): keep-alive vs close-mode phases, optional
//! paced target-RPS, hot-swap-under-load, and SLO checks, reported as
//! `backbone-serve-selftest/v1` JSON.

pub mod config;
pub mod http;
pub mod registry;
pub mod router;
pub mod routes;
pub mod selftest;

pub use config::{parse_model_spec, validate_model_name, ServeConfig, ServeError};
#[allow(deprecated)]
pub use config::ServeConfigFields;

use crate::backbone::resolved_threads;
use crate::obs::percentile;
use crate::json::Json;
use crate::persist::LoadedModel;
use crate::warmstart::WarmStartStore;
use http::{read_request, write_json, WriteOptions};
use registry::ModelRegistry;
use router::Router;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Schema tag of the `GET /stats` payload.
pub const STATS_SCHEMA: &str = "backbone-serve-stats/v1";

/// How long the acceptor waits to drain a rejected connection's request
/// bytes before answering 503. Bounds acceptor stall at saturation.
const REJECT_DRAIN_MS: u64 = 50;

/// Sliding window of recent request latencies (microseconds). Bounded so
/// `/stats` stays O(window) regardless of uptime; the lifetime request
/// count is exact, the latency profile covers the most recent window.
pub(crate) struct LatencyWindow {
    samples: Vec<u64>,
    next: usize,
    count: u64,
}

const LATENCY_WINDOW: usize = 4096;

impl LatencyWindow {
    fn new() -> Self {
        Self { samples: Vec::with_capacity(LATENCY_WINDOW), next: 0, count: 0 }
    }

    fn record(&mut self, us: u64) {
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(us);
        } else {
            self.samples[self.next] = us;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
        self.count += 1;
    }

    /// `(lifetime count, unsorted window copy)` — a plain O(n) memcpy so
    /// the stats mutex is never held through a sort; callers order the
    /// samples after the lock is released.
    fn snapshot(&self) -> (u64, Vec<f64>) {
        (self.count, self.samples.iter().map(|&u| u as f64).collect())
    }
}

/// Per-route (and per-model) request/failure/latency accounting. Each
/// endpoint and each registry entry owns one of these so they are
/// independently observable in `GET /stats` — a slow fit queue can never
/// hide in the predict latency profile (and vice versa).
pub struct RouteStats {
    /// Requests routed here (attempts, including ones answered 4xx).
    pub(crate) requests: AtomicU64,
    /// Attempts answered with a non-2xx status.
    pub(crate) failures: AtomicU64,
    /// Work units completed: rows predicted / models fitted.
    pub(crate) units: AtomicU64,
    /// Latency of *successful* requests only.
    pub(crate) latency: Mutex<LatencyWindow>,
}

impl Default for RouteStats {
    fn default() -> Self {
        Self::new()
    }
}

impl RouteStats {
    pub(crate) fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            units: AtomicU64::new(0),
            latency: Mutex::new(LatencyWindow::new()),
        }
    }

    pub(crate) fn record_ok(&self, units: usize, latency_us: u64) {
        self.units.fetch_add(units as u64, Ordering::Relaxed);
        self.latency.lock().unwrap().record(latency_us);
    }

    /// `{requests, failures, <units_key>, latency: {...}}`.
    fn to_json(&self, units_key: &str) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "requests".into(),
            Json::Number(self.requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "failures".into(),
            Json::Number(self.failures.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            units_key.into(),
            Json::Number(self.units.load(Ordering::Relaxed) as f64),
        );
        m.insert("latency".into(), self.latency_json());
        Json::Object(m)
    }

    fn latency_json(&self) -> Json {
        // The lock guard lives only for the snapshot statement; sorting
        // happens outside it so /stats polls never stall the workers.
        let (count, mut window) = self.latency.lock().unwrap().snapshot();
        window.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = if window.is_empty() {
            f64::NAN
        } else {
            window.iter().sum::<f64>() / window.len() as f64
        };
        let mut latency = BTreeMap::new();
        latency.insert("count".into(), Json::Number(count as f64));
        // mean/p50/p99 summarize only the most recent `window` samples;
        // `count` is lifetime — surface the window size so consumers
        // can't conflate the two.
        latency.insert("window".into(), Json::Number(window.len() as f64));
        latency.insert("mean_us".into(), Json::from_f64(mean));
        latency.insert("p50_us".into(), Json::from_f64(percentile(&window, 0.50)));
        latency.insert("p99_us".into(), Json::from_f64(percentile(&window, 0.99)));
        Json::Object(latency)
    }
}

/// Whole-server counters surfaced by `GET /stats`.
pub struct ServerStats {
    pub(crate) requests: AtomicU64,
    pub(crate) failures: AtomicU64,
    /// Connections that delivered at least one parseable request — the
    /// keep-alive reuse denominator (requests_total / connections).
    pub(crate) connections: AtomicU64,
    /// Connections turned away with `503` because `max_connections`
    /// handlers were already live (admission happens before any request
    /// is read, so these never enter the request counters).
    pub(crate) rejected_connections: AtomicU64,
    /// Panics caught and converted to structured errors instead of
    /// killing the process: handler panics answered `500`, and solver
    /// panics surfaced as `BackboneError::SubproblemPanicked` by
    /// `POST /fit`. The chaos harness reconciles this against the
    /// injected `worker_panic` fault count.
    pub(crate) panics_caught: AtomicU64,
    /// Warm-start store write-through failures during `POST /fit`. The
    /// fit itself still succeeds (log-and-continue); this counter is how
    /// operators notice the cache is not persisting.
    pub(crate) store_save_failures: AtomicU64,
    pub(crate) predict: RouteStats,
    pub(crate) fit: RouteStats,
}

impl ServerStats {
    fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            failures: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            rejected_connections: AtomicU64::new(0),
            panics_caught: AtomicU64::new(0),
            store_save_failures: AtomicU64::new(0),
            predict: RouteStats::new(),
            fit: RouteStats::new(),
        }
    }
}

/// Shared state of a running server: the model registry plus
/// observability and (when `--fit` is enabled) the online-fit machinery.
pub struct ServerState {
    pub(crate) cfg: ServeConfig,
    pub(crate) stats: ServerStats,
    pub(crate) started: Instant,
    pub(crate) shutdown: AtomicBool,
    /// Resolved solver thread count used by online fits (`POST /fit`);
    /// serving concurrency is per-connection, not thread-pool-sized.
    pub(crate) threads: usize,
    /// Live connection handlers; the `max_connections` admission gate.
    pub(crate) open_connections: AtomicU64,
    /// Fits currently executing; the admission gate for bounded queueing.
    pub(crate) fits_in_flight: AtomicU64,
    /// Predicts currently executing; gate when `max_inflight_predicts`>0.
    pub(crate) predicts_in_flight: AtomicU64,
    pub(crate) registry: Mutex<ModelRegistry>,
    pub(crate) warm: Mutex<WarmStartStore>,
    /// Typed load failure of the warm cache at bind time (the store
    /// degraded to empty; fits stay cold until it repopulates).
    pub(crate) warm_error: Option<String>,
}

impl ServerState {
    /// Build server state from named startup models (the first name is
    /// the default) and a validated config. Typed errors for an empty
    /// model list or invalid/duplicate names.
    pub fn new(
        models: Vec<(String, LoadedModel)>,
        cfg: ServeConfig,
    ) -> Result<ServerState, ServeError> {
        if models.is_empty() {
            return Err(ServeError::NoModels);
        }
        let mut registry = ModelRegistry::new(cfg.registry_capacity());
        for (name, model) in models {
            registry.register_named(&name, model)?;
        }
        let (warm, warm_error) = match cfg.warm_cache_path() {
            Some(path) => {
                let (store, err) = WarmStartStore::load_or_empty(path, cfg.warm_capacity());
                (store, err.map(|e| e.to_string()))
            }
            None => (WarmStartStore::new(cfg.warm_capacity()), None),
        };
        let threads = resolved_threads(cfg.threads());
        Ok(ServerState {
            cfg,
            stats: ServerStats::new(),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            threads,
            open_connections: AtomicU64::new(0),
            fits_in_flight: AtomicU64::new(0),
            predicts_in_flight: AtomicU64::new(0),
            registry: Mutex::new(registry),
            warm: Mutex::new(warm),
            warm_error,
        })
    }

    /// The `backbone-serve-stats/v1` payload. Pre-PR-7 consumers read
    /// the predict route's numbers at the top level
    /// (`predict_requests`, `rows_predicted`, `failures`, `latency`);
    /// those keys are kept as mirrors of `routes.predict` one release
    /// (see the README deprecation note) next to the versioned layout.
    pub fn stats_json(&self) -> Json {
        let mut routes = BTreeMap::new();
        routes.insert("fit".into(), self.stats.fit.to_json("models_fitted"));
        routes.insert("predict".into(), self.stats.predict.to_json("rows_predicted"));

        let registry = self.registry.lock().unwrap();
        let mut models = BTreeMap::new();
        for (id, entry) in registry.iter() {
            let mut section = entry.stats.to_json("rows_predicted").as_object().cloned().unwrap();
            section.insert("version".into(), Json::Number(entry.version as f64));
            section.insert("source".into(), Json::String(entry.source.name().into()));
            models.insert(id.clone(), Json::Object(section));
        }
        let swaps = registry.swaps();
        drop(registry);

        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::String(STATS_SCHEMA.into()));
        m.insert(
            "requests_total".into(),
            Json::Number(self.stats.requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "connections".into(),
            Json::Number(self.stats.connections.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "open_connections".into(),
            Json::Number(self.open_connections.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "connections_rejected".into(),
            Json::Number(self.stats.rejected_connections.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "panics_caught".into(),
            Json::Number(self.stats.panics_caught.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "store_save_failures".into(),
            Json::Number(self.stats.store_save_failures.load(Ordering::Relaxed) as f64),
        );
        // Legacy top-level mirrors of `routes.predict` (deprecated).
        // `predict_requests` mirrors `routes.predict.requests` exactly —
        // attempts including 4xx — so pre-PR-7 consumers keep the
        // semantics the key always had.
        m.insert(
            "predict_requests".into(),
            Json::Number(self.stats.predict.requests.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "rows_predicted".into(),
            Json::Number(self.stats.predict.units.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "failures".into(),
            Json::Number(self.stats.failures.load(Ordering::Relaxed) as f64),
        );
        m.insert("latency".into(), self.stats.predict.latency_json());
        m.insert("routes".into(), Json::Object(routes));
        m.insert("models".into(), Json::Object(models));
        m.insert("swaps".into(), Json::Number(swaps as f64));
        m.insert(
            "fits_in_flight".into(),
            Json::Number(self.fits_in_flight.load(Ordering::Relaxed) as f64),
        );
        m.insert(
            "uptime_secs".into(),
            Json::from_f64(self.started.elapsed().as_secs_f64()),
        );
        m.insert("threads".into(), Json::Number(self.threads as f64));
        Json::Object(m)
    }

    /// The server-derived half of `GET /metrics`: Prometheus text
    /// rendered straight from the same `ServerStats`/`RouteStats`
    /// atomics `/stats` reads, so the two endpoints reconcile exactly
    /// (the chaos audit and the serve tests assert this). The
    /// process-global `obs::registry()` half is concatenated by the
    /// route handler.
    pub fn metrics_text(&self) -> String {
        use crate::obs::{write_help_type, write_series};
        let mut out = String::with_capacity(4096);
        let no_labels: &[(String, String)] = &[];

        let server_counters: &[(&str, &str, u64)] = &[
            (
                "backbone_http_requests_total",
                "Requests dispatched to any route.",
                self.stats.requests.load(Ordering::Relaxed),
            ),
            (
                "backbone_http_failures_total",
                "Requests answered with a non-2xx status.",
                self.stats.failures.load(Ordering::Relaxed),
            ),
            (
                "backbone_http_connections_total",
                "Connections that delivered at least one parseable request.",
                self.stats.connections.load(Ordering::Relaxed),
            ),
            (
                "backbone_http_connections_rejected_total",
                "Connections turned away at the max_connections admission gate.",
                self.stats.rejected_connections.load(Ordering::Relaxed),
            ),
            (
                "backbone_serve_panics_caught_total",
                "Handler/solver panics caught and converted to structured errors.",
                self.stats.panics_caught.load(Ordering::Relaxed),
            ),
            (
                "backbone_warmstart_store_save_failures_total",
                "Warm-start store write-through failures during POST /fit.",
                self.stats.store_save_failures.load(Ordering::Relaxed),
            ),
        ];
        for (name, help, value) in server_counters {
            write_help_type(&mut out, name, help, "counter");
            write_series(&mut out, name, no_labels, *value as f64);
        }

        write_help_type(
            &mut out,
            "backbone_route_requests_total",
            "Requests routed to each accounted route (attempts, including 4xx).",
            "counter",
        );
        let route_label = |route: &str| vec![("route".to_string(), route.to_string())];
        let routes: &[(&str, &RouteStats)] =
            &[("fit", &self.stats.fit), ("predict", &self.stats.predict)];
        for (route, stats) in routes {
            write_series(
                &mut out,
                "backbone_route_requests_total",
                &route_label(route),
                stats.requests.load(Ordering::Relaxed) as f64,
            );
        }
        write_help_type(
            &mut out,
            "backbone_route_failures_total",
            "Requests per route answered with a non-2xx status.",
            "counter",
        );
        for (route, stats) in routes {
            write_series(
                &mut out,
                "backbone_route_failures_total",
                &route_label(route),
                stats.failures.load(Ordering::Relaxed) as f64,
            );
        }
        write_help_type(
            &mut out,
            "backbone_route_units_total",
            "Work units completed per route: rows predicted / models fitted.",
            "counter",
        );
        for (route, stats) in routes {
            write_series(
                &mut out,
                "backbone_route_units_total",
                &route_label(route),
                stats.units.load(Ordering::Relaxed) as f64,
            );
        }

        // Per-model series render under the registry lock (BTreeMap
        // order, so the exposition is deterministic).
        let registry = self.registry.lock().unwrap();
        let models_loaded = registry.len();
        let swaps = registry.swaps();
        let mut model_rows: Vec<(Vec<(String, String)>, u64, u64, u64, u64)> = Vec::new();
        for (id, entry) in registry.iter() {
            model_rows.push((
                vec![("model".to_string(), id.clone())],
                entry.stats.requests.load(Ordering::Relaxed),
                entry.stats.failures.load(Ordering::Relaxed),
                entry.stats.units.load(Ordering::Relaxed),
                entry.version,
            ));
        }
        drop(registry);
        write_help_type(
            &mut out,
            "backbone_model_requests_total",
            "Predict requests per model (attempts, including 4xx).",
            "counter",
        );
        for (labels, requests, ..) in &model_rows {
            write_series(&mut out, "backbone_model_requests_total", labels, *requests as f64);
        }
        write_help_type(
            &mut out,
            "backbone_model_failures_total",
            "Predict requests per model answered with a non-2xx status.",
            "counter",
        );
        for (labels, _, failures, ..) in &model_rows {
            write_series(&mut out, "backbone_model_failures_total", labels, *failures as f64);
        }
        write_help_type(
            &mut out,
            "backbone_model_rows_predicted_total",
            "Rows predicted per model.",
            "counter",
        );
        for (labels, _, _, units, _) in &model_rows {
            write_series(&mut out, "backbone_model_rows_predicted_total", labels, *units as f64);
        }
        write_help_type(
            &mut out,
            "backbone_model_version",
            "Current version of each registered model (bumped on hot swap).",
            "gauge",
        );
        for (labels, .., version) in &model_rows {
            write_series(&mut out, "backbone_model_version", labels, *version as f64);
        }

        let gauges: &[(&str, &str, f64)] = &[
            (
                "backbone_models_loaded",
                "Models currently in the registry.",
                models_loaded as f64,
            ),
            (
                "backbone_http_open_connections",
                "Connection handlers currently live.",
                self.open_connections.load(Ordering::Relaxed) as f64,
            ),
            (
                "backbone_fits_in_flight",
                "Online fits currently executing.",
                self.fits_in_flight.load(Ordering::Relaxed) as f64,
            ),
            (
                "backbone_predicts_in_flight",
                "Predict requests currently executing.",
                self.predicts_in_flight.load(Ordering::Relaxed) as f64,
            ),
            (
                "backbone_serve_threads",
                "Resolved solver thread count used by online fits.",
                self.threads as f64,
            ),
            (
                "backbone_serve_uptime_seconds",
                "Seconds since the server started.",
                self.started.elapsed().as_secs_f64(),
            ),
        ];
        for (name, help, value) in gauges {
            write_help_type(&mut out, name, help, "gauge");
            write_series(&mut out, name, no_labels, *value);
        }
        write_help_type(
            &mut out,
            "backbone_model_swaps_total",
            "Lifetime hot swaps across the registry.",
            "counter",
        );
        write_series(&mut out, "backbone_model_swaps_total", no_labels, swaps as f64);
        write_help_type(
            &mut out,
            "backbone_build_info",
            "Constant 1, labeled with the active linear-algebra backend.",
            "gauge",
        );
        write_series(
            &mut out,
            "backbone_build_info",
            &[("backend".to_string(), crate::linalg::backend_name().to_string())],
            1.0,
        );
        out
    }
}

/// Structured JSON error body shared by every non-2xx path.
pub(crate) fn error_body(message: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("error".to_string(), Json::String(message.into()));
    Json::Object(m).to_string_compact()
}

/// A bound (but not yet running) server.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    router: Arc<Router>,
}

/// Handle for stopping a running server from another thread: sets the
/// shutdown flag, then pokes the listener so the blocked `accept` wakes
/// up and observes it. Handlers inside a keep-alive request loop exit at
/// the next request boundary (or when their client hangs up / the idle
/// timeout fires); `run` returns once the acceptor and every live
/// handler have finished.
pub struct ShutdownHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.state.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:8000"`; port 0 for an ephemeral
    /// port) and serve `model` under the name `default`.
    pub fn bind(addr: &str, model: LoadedModel, cfg: &ServeConfig) -> std::io::Result<Server> {
        Self::bind_registry(addr, vec![("default".to_string(), model)], cfg)
    }

    /// Bind with a named model registry; the first name is the default
    /// for unqualified `/predict`. Config/name errors arrive as
    /// `ErrorKind::InvalidInput` with the typed [`ServeError`]
    /// downcastable from the error source.
    pub fn bind_registry(
        addr: &str,
        models: Vec<(String, LoadedModel)>,
        cfg: &ServeConfig,
    ) -> std::io::Result<Server> {
        let state = ServerState::new(models, cfg.clone())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        let listener = TcpListener::bind(addr)?;
        Ok(Server {
            listener,
            state: Arc::new(state),
            router: Arc::new(routes::standard_router()),
        })
    }

    /// Address the server is listening on (resolves port 0).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Typed load error from the warm-start store, if the configured
    /// `warm_cache_path` existed but could not be parsed (the server
    /// still starts, degraded to cold fits).
    pub fn warm_store_error(&self) -> Option<&str> {
        self.state.warm_error.as_deref()
    }

    /// Shutdown handle usable from other threads while `run` blocks.
    pub fn shutdown_handle(&self) -> std::io::Result<ShutdownHandle> {
        Ok(ShutdownHandle { addr: self.local_addr()?, state: Arc::clone(&self.state) })
    }

    /// Accept connections and serve each on its own handler thread until
    /// the shutdown flag is raised. Blocks the calling thread.
    ///
    /// A single acceptor never does request work, so a full set of
    /// long-lived keep-alive clients cannot stop new connections (health
    /// probes, the hot-swap `PUT`) from being accepted. Concurrency is
    /// bounded by `max_connections`: once that many handlers are live,
    /// further connections are answered `503` + `Retry-After` and closed
    /// instead of queueing invisibly in the accept backlog. Returns once
    /// every live handler has finished after shutdown.
    pub fn run(self) {
        let listener = &self.listener;
        let state = &self.state;
        let router = &self.router;
        std::thread::scope(|scope| {
            loop {
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let Ok((mut stream, _peer)) = listener.accept() else {
                    // Persistent accept failures (e.g. fd exhaustion)
                    // must not become a busy-spin that starves the
                    // connections already open.
                    std::thread::sleep(std::time::Duration::from_millis(10));
                    continue;
                };
                // Chaos hook: drop a just-accepted connection on the
                // floor (client sees a reset and must retry). Compiles
                // to a constant `false` without `fault-inject`.
                if crate::fault::fire(crate::fault::FaultPoint::ConnDrop) {
                    drop(stream);
                    continue;
                }
                // Admission check before any request is read: only the
                // acceptor touches the gate going up, so load-then-spawn
                // cannot over-admit (handler exits only decrement).
                let cap = state.cfg.max_connections() as u64;
                if state.open_connections.load(Ordering::SeqCst) >= cap {
                    state.stats.rejected_connections.fetch_add(1, Ordering::Relaxed);
                    let extra =
                        [("Retry-After", state.cfg.retry_after_secs().to_string())];
                    let _ = stream.set_write_timeout(Some(state.cfg.read_timeout()));
                    // Best-effort drain of the request the client already
                    // sent: closing a socket with unread bytes RSTs the
                    // connection and can destroy the 503 before the
                    // client reads it.
                    let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(
                        REJECT_DRAIN_MS,
                    )));
                    let mut scratch = [0u8; 1024];
                    let _ = std::io::Read::read(&mut stream, &mut scratch);
                    let _ = write_json(
                        &mut stream,
                        503,
                        "Service Unavailable",
                        &error_body("server at connection capacity; retry shortly"),
                        &WriteOptions { extra_headers: &extra, ..WriteOptions::default() },
                    );
                    continue;
                }
                state.open_connections.fetch_add(1, Ordering::SeqCst);
                // Serve whatever was accepted even if shutdown raced in —
                // a real client that won the race gets its response; a
                // ShutdownHandle poke reads as an instant EOF and is
                // dropped without counters.
                scope.spawn(move || {
                    // Isolate the handler: a panic that escapes the
                    // per-request catch in `handle_connection` (read or
                    // write layer) must not unwind into the scope — that
                    // would tear down the acceptor and every sibling
                    // connection. Either way the admission gate is
                    // released, so a panicking handler can never leak a
                    // connection slot.
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || handle_connection(stream, state, router),
                    ));
                    state.open_connections.fetch_sub(1, Ordering::SeqCst);
                    if result.is_err() {
                        state.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
                    }
                });
                if state.shutdown.load(Ordering::SeqCst) {
                    return;
                }
            }
        });
    }
}

/// One connection's request loop. With keep-alive on, a worker stays
/// with the connection until the client closes, the idle timeout fires,
/// a parse error forces a close, shutdown is raised, or the per-conn
/// request cap is hit — whichever comes first.
fn handle_connection(mut stream: TcpStream, state: &ServerState, router: &Router) {
    let cfg = &state.cfg;
    let _ = stream.set_write_timeout(Some(cfg.read_timeout()));
    let mut served: usize = 0;
    loop {
        // First request gets the (longer) read timeout; between requests
        // the idle timeout decides how long the worker waits for reuse.
        let timeout = if served == 0 { cfg.read_timeout() } else { cfg.idle_timeout() };
        let _ = stream.set_read_timeout(Some(timeout));
        // Chaos hook: stall this handler briefly before its next read,
        // simulating a slow client/disk. Constant `false` without
        // `fault-inject`.
        if crate::fault::fire(crate::fault::FaultPoint::SlowRead) {
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        let request = match read_request(&mut stream, cfg.max_body_bytes()) {
            Ok(req) => req,
            Err(e) => {
                // Only connections we actually answer enter the
                // counters; a bare connect-then-close (TCP health probe,
                // shutdown poke, keep-alive peer hanging up between
                // requests, idle timeout) is an Io error and stays
                // invisible, so /stats failure rates reflect served
                // traffic, not probing.
                if let Some((status, reason)) = e.status() {
                    state.stats.requests.fetch_add(1, Ordering::Relaxed);
                    state.stats.failures.fetch_add(1, Ordering::Relaxed);
                    if served == 0 {
                        state.stats.connections.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = write_json(
                        &mut stream,
                        status,
                        reason,
                        &error_body(&e.message()),
                        &WriteOptions::default(),
                    );
                }
                return;
            }
        };
        state.stats.requests.fetch_add(1, Ordering::Relaxed);
        if served == 0 {
            state.stats.connections.fetch_add(1, Ordering::Relaxed);
        }
        let request_id = crate::obs::next_request_id();
        let request_watch = crate::util::Stopwatch::start();
        let (outcome, panicked) = dispatch_or_500(router, &request, state);
        if outcome.failed() {
            state.stats.failures.fetch_add(1, Ordering::Relaxed);
        }
        // Structured request log: one JSON line per served request on
        // stderr, filtered by BACKBONE_LOG (successes at info, failures
        // at warn). The disabled path is one relaxed load.
        {
            use crate::obs::{log, log_enabled, Level};
            let level = if outcome.failed() { Level::Warn } else { Level::Info };
            if log_enabled(level) {
                log(
                    level,
                    "request",
                    &[
                        ("request_id", Json::Number(request_id as f64)),
                        ("method", Json::String(request.method.clone())),
                        ("route", Json::String(request.path.clone())),
                        ("status", Json::Number(outcome.status as f64)),
                        (
                            "duration_ms",
                            Json::Number(request_watch.elapsed_secs() * 1e3),
                        ),
                    ],
                );
            }
        }
        served += 1;
        // A panicked handler may have left no coherent request framing;
        // answer the structured 500, then force-close the connection.
        let keep = !panicked
            && cfg.keep_alive()
            && request.keep_alive
            && !state.shutdown.load(Ordering::SeqCst)
            && (cfg.max_requests_per_conn() == 0 || served < cfg.max_requests_per_conn());
        let mut extra: Vec<(&'static str, String)> = Vec::new();
        if let Some(secs) = outcome.retry_after_secs {
            extra.push(("Retry-After", secs.to_string()));
        }
        let opts = WriteOptions {
            keep_alive: keep,
            idle_timeout_secs: cfg.idle_timeout().as_secs(),
            extra_headers: &extra,
        };
        if http::write_response(
            &mut stream,
            outcome.status,
            outcome.reason,
            outcome.content_type,
            outcome.body.as_bytes(),
            &opts,
        )
        .is_err()
            || !keep
        {
            return;
        }
    }
}

/// Dispatch through the router with panic isolation: a handler panic is
/// caught here, counted in `panics_caught`, and answered as a structured
/// `500` — the connection thread (and the process) survive. Returns
/// `(outcome, panicked)` so the caller can force-close the connection
/// after a caught panic.
fn dispatch_or_500(
    router: &Router,
    request: &http::Request,
    state: &ServerState,
) -> (router::Outcome, bool) {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        router.dispatch(request, state)
    })) {
        Ok(outcome) => (outcome, false),
        Err(_) => {
            state.stats.panics_caught.fetch_add(1, Ordering::Relaxed);
            (
                router::Outcome::error(
                    500,
                    "Internal Server Error",
                    "internal error: request handler panicked (caught; connection will close)",
                ),
                true,
            )
        }
    }
}

/// Extract `{"<key>": [[...], ...]}` as a rectangular f64 batch.
pub(crate) fn parse_matrix(doc: &Json, key: &str) -> Result<Vec<Vec<f64>>, String> {
    let rows = doc
        .get(key)
        .and_then(Json::as_array)
        .ok_or_else(|| format!("body must be an object with a `{key}` array of arrays"))?;
    if rows.is_empty() {
        return Err(format!("`{key}` must contain at least one row"));
    }
    let mut out = Vec::with_capacity(rows.len());
    let mut width: Option<usize> = None;
    for (i, row) in rows.iter().enumerate() {
        let cells = row
            .as_array()
            .ok_or_else(|| format!("{key}[{i}] is not an array"))?;
        let mut values = Vec::with_capacity(cells.len());
        for (j, cell) in cells.iter().enumerate() {
            values.push(
                cell.as_f64_tagged()
                    .filter(|v| v.is_finite())
                    .ok_or_else(|| format!("{key}[{i}][{j}] is not a finite number"))?,
            );
        }
        match width {
            None => width = Some(values.len()),
            Some(w) if w != values.len() => {
                return Err(format!(
                    "{key}[{i}] has {} values but {key}[0] has {w}",
                    values.len()
                ));
            }
            Some(_) => {}
        }
        out.push(values);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::http::Request;
    use super::router::Outcome;
    use super::*;
    use crate::persist::{LoadedModel, ModelArtifact, Provenance};
    use crate::solvers::SolveStatus;

    fn toy_model_with_intercept(intercept: f64) -> LoadedModel {
        LoadedModel::SparseRegression(
            crate::backbone::sparse_regression::SparseRegressionModel {
                beta: vec![2.0, 0.0, -1.0],
                intercept,
                support: vec![0, 2],
                objective: 1.0,
                gap: 0.0,
                status: SolveStatus::Optimal,
            },
        )
    }

    fn toy_model() -> LoadedModel {
        toy_model_with_intercept(0.5)
    }

    fn toy_state() -> ServerState {
        toy_state_with(false)
    }

    fn toy_state_with(fit_enabled: bool) -> ServerState {
        let cfg = ServeConfig::builder()
            .threads(1)
            .max_body_bytes(64 * 1024)
            .enable_fit(fit_enabled)
            .registry_capacity(4)
            .warm_capacity(8)
            .build()
            .unwrap();
        ServerState::new(vec![("default".to_string(), toy_model())], cfg).unwrap()
    }

    fn route(request: &Request, state: &ServerState) -> Outcome {
        routes::standard_router().dispatch(request, state)
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            body: body.into(),
            keep_alive: true,
        }
    }

    fn post_predict(body: &str) -> Request {
        req("POST", "/predict", body)
    }

    fn post_fit(body: &str) -> Request {
        req("POST", "/fit", body)
    }

    #[test]
    fn predict_route_computes_batch() {
        let state = toy_state();
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0], [0, 0, 1]]}"#), &state);
        assert_eq!(out.status, 200);
        let doc = Json::parse(&out.body).unwrap();
        let preds = doc.get("predictions").unwrap().as_array().unwrap();
        assert_eq!(preds[0].as_f64(), Some(2.5)); // 2*1 + 0.5
        assert_eq!(preds[1].as_f64(), Some(-0.5)); // -1*1 + 0.5
        assert_eq!(doc.get("rows").and_then(Json::as_usize), Some(2));
        assert_eq!(doc.get("model").and_then(Json::as_str), Some("default"));
        assert_eq!(doc.get("model_version").and_then(Json::as_usize), Some(1));
        assert_eq!(state.stats.predict.units.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn path_routed_predict_addresses_models_by_name() {
        let state = toy_state();
        let out = route(&req("POST", "/models/default/predict", r#"{"rows": [[1, 0, 0]]}"#), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("model").and_then(Json::as_str), Some("default"));
        // Unknown ids are a clean 404.
        let out = route(&req("POST", "/models/nope/predict", r#"{"rows": [[1, 0, 0]]}"#), &state);
        assert_eq!(out.status, 404, "{}", out.body);
        // Per-model stats recorded under the entry.
        let entry = state.registry.lock().unwrap().get("default").unwrap();
        assert_eq!(entry.stats.units.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn predict_route_rejects_bad_payloads() {
        let state = toy_state();
        for (body, hint) in [
            ("not json", "not JSON"),
            (r#"{"cols": []}"#, "`rows`"),
            (r#"{"rows": []}"#, "at least one"),
            (r#"{"rows": [[1, 2]]}"#, "incompatible"),
            (r#"{"rows": [[1, 2, 3], [1]]}"#, "rows[1]"),
            (r#"{"rows": [["a", 2, 3]]}"#, "finite number"),
        ] {
            let out = route(&post_predict(body), &state);
            assert_eq!(out.status, 400, "{body}");
            assert!(out.body.contains(hint), "{body} → {}", out.body);
        }
        // Six attempts, six failures, zero completed predictions.
        assert_eq!(state.stats.predict.requests.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.predict.failures.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.predict.units.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn unknown_routes_and_methods() {
        let state = toy_state();
        let out = route(&req("GET", "/nope", ""), &state);
        assert_eq!(out.status, 404);
        assert!(out.body.contains("POST /predict"), "404 lists routes: {}", out.body);
        let out = route(&req("GET", "/predict", ""), &state);
        assert_eq!(out.status, 405);
        assert!(out.body.contains("POST"), "405 names the allowed method: {}", out.body);
        let out = route(&req("GET", "/models/default/predict", ""), &state);
        assert_eq!(out.status, 405);
    }

    #[test]
    fn stats_json_is_versioned_with_legacy_mirrors() {
        let state = toy_state();
        for us in [100, 200, 300] {
            // Mimic the router: every attempt bumps `requests`, only
            // successes enter the latency window.
            state.stats.predict.requests.fetch_add(1, Ordering::Relaxed);
            state.stats.predict.record_ok(1, us);
        }
        // One failed attempt: counted in `requests` (and so in the
        // legacy `predict_requests` mirror), absent from the profile.
        state.stats.predict.requests.fetch_add(1, Ordering::Relaxed);
        state.stats.predict.failures.fetch_add(1, Ordering::Relaxed);
        let doc = state.stats_json();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(STATS_SCHEMA));
        // Legacy top-level mirrors (pre-PR-7 consumers).
        let lat = doc.get("latency").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(3));
        assert_eq!(lat.get("p50_us").and_then(Json::as_f64), Some(200.0));
        assert_eq!(doc.get("rows_predicted").and_then(Json::as_usize), Some(3));
        // The legacy mirror carries routes.predict.requests verbatim:
        // attempts (4 here, one of them a failure), not successes.
        assert_eq!(doc.get("predict_requests").and_then(Json::as_usize), Some(4));
        assert_eq!(doc.get("threads").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("open_connections").and_then(Json::as_usize), Some(0));
        assert_eq!(doc.get("connections_rejected").and_then(Json::as_usize), Some(0));
        // Per-route split: predict and fit are independently observable.
        let routes = doc.get("routes").unwrap();
        let predict = routes.get("predict").unwrap();
        assert_eq!(predict.get("requests").and_then(Json::as_usize), Some(4));
        assert_eq!(predict.get("rows_predicted").and_then(Json::as_usize), Some(3));
        assert_eq!(
            predict.get("latency").unwrap().get("count").and_then(Json::as_usize),
            Some(3)
        );
        let fit = routes.get("fit").unwrap();
        assert_eq!(fit.get("models_fitted").and_then(Json::as_usize), Some(0));
        assert_eq!(fit.get("requests").and_then(Json::as_usize), Some(0));
        // Per-model sections with version + source.
        let models = doc.get("models").unwrap();
        let default = models.get("default").unwrap();
        assert_eq!(default.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(default.get("source").and_then(Json::as_str), Some("startup"));
        assert_eq!(doc.get("swaps").and_then(Json::as_usize), Some(0));
        assert_eq!(doc.get("connections").and_then(Json::as_usize), Some(0));
    }

    fn artifact_body(intercept: f64) -> String {
        ModelArtifact {
            model: toy_model_with_intercept(intercept),
            provenance: Provenance {
                crate_version: "test".into(),
                seed: 0,
                params: Json::Object(BTreeMap::new()),
                config: Json::Object(BTreeMap::new()),
                diagnostics: None,
            },
        }
        .to_json()
        .to_string_compact()
    }

    #[test]
    fn hot_swap_bumps_version_and_switches_predictions() {
        let state = toy_state();
        let out = route(&req("PUT", "/models/default", &artifact_body(100.5)), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("version").and_then(Json::as_usize), Some(2));
        // Predictions now come from the swapped model at version 2.
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0]]}"#), &state);
        let doc = Json::parse(&out.body).unwrap();
        let pred = doc.get("predictions").unwrap().as_array().unwrap()[0].as_f64().unwrap();
        assert_eq!(pred, 102.5); // 2*1 + 100.5
        assert_eq!(doc.get("model_version").and_then(Json::as_usize), Some(2));
        // Swapping a brand-new name creates it at version 1.
        let out = route(&req("PUT", "/models/canary", &artifact_body(0.0)), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        assert_eq!(
            Json::parse(&out.body).unwrap().get("version").and_then(Json::as_usize),
            Some(1)
        );
        // Fitted m{n} ids are read-only swap targets.
        let out = route(&req("PUT", "/models/m1", &artifact_body(0.0)), &state);
        assert_eq!(out.status, 409, "{}", out.body);
        // Garbage bodies are a 400, not a swap.
        let out = route(&req("PUT", "/models/default", r#"{"schema": "nope"}"#), &state);
        assert_eq!(out.status, 400, "{}", out.body);
        assert_eq!(
            state.registry.lock().unwrap().get("default").unwrap().version,
            2,
            "failed swap must not bump the version"
        );
    }

    #[test]
    fn models_listing_reports_the_namespace() {
        let state = toy_state();
        let out = route(&req("GET", "/models", ""), &state);
        assert_eq!(out.status, 200);
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(routes::MODELS_SCHEMA));
        assert_eq!(doc.get("default").and_then(Json::as_str), Some("default"));
        assert_eq!(doc.get("count").and_then(Json::as_usize), Some(1));
        let models = doc.get("models").unwrap().as_array().unwrap();
        assert_eq!(models[0].get("id").and_then(Json::as_str), Some("default"));
        assert_eq!(models[0].get("source").and_then(Json::as_str), Some("startup"));
    }

    #[test]
    fn predict_gate_returns_429_with_retry_after() {
        let cfg = ServeConfig::builder()
            .max_inflight_predicts(1)
            .retry_after_secs(3)
            .build()
            .unwrap();
        let state =
            ServerState::new(vec![("default".to_string(), toy_model())], cfg).unwrap();
        // Simulate a predict already in flight; the gate must bounce us.
        state.predicts_in_flight.store(1, Ordering::SeqCst);
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0]]}"#), &state);
        assert_eq!(out.status, 429, "{}", out.body);
        assert_eq!(out.retry_after_secs, Some(3));
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("retry_after_secs").and_then(Json::as_usize), Some(3));
        state.predicts_in_flight.store(0, Ordering::SeqCst);
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0]]}"#), &state);
        assert_eq!(out.status, 200, "{}", out.body);
    }

    /// Tiny deterministic fit body: y = 2·x₀ on 8 rows of 3 features.
    fn fit_body() -> &'static str {
        r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1], [5, 0, 0], [6, 1, 0], [7, 0, 1], [8, 1, 1]],
            "y": [2, 4, 6, 8, 10, 12, 14, 16], "k": 1, "m": 2}"#
    }

    #[test]
    fn fit_route_is_gated_behind_enable_fit() {
        let state = toy_state_with(false);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 403);
        assert!(out.body.contains("--fit"), "{}", out.body);
        assert_eq!(state.stats.fit.requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fit_route_fits_registers_and_serves_the_model() {
        let state = toy_state_with(true);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        let model_id = doc.get("model_id").and_then(Json::as_str).unwrap().to_string();
        let support = doc.get("support").unwrap().as_array().unwrap();
        assert_eq!(support.len(), 1);
        assert_eq!(support[0].as_usize(), Some(0));
        let warm = doc.get("warm").unwrap();
        assert_eq!(warm.get("hit").and_then(Json::as_str), Some("none"));
        assert_eq!(warm.get("store_entries").and_then(Json::as_usize), Some(1));
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 1);

        // The fitted model serves by path route...
        let body = r#"{"rows": [[10, 0, 0]]}"#;
        let out = route(&req("POST", &format!("/models/{model_id}/predict"), body), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        let pred = doc.get("predictions").unwrap().as_array().unwrap()[0].as_f64().unwrap();
        // Small ridge penalty (λ₂ default) shrinks the slope slightly.
        assert!((pred - 20.0).abs() < 0.1, "pred={pred}");
        // ...and through the PR-6 body-field back-compat path.
        let body = format!(r#"{{"rows": [[10, 0, 0]], "model": "{model_id}"}}"#);
        let out = route(&post_predict(&body), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        // An unknown id is a clean 404, not the default model.
        let out = route(&post_predict(r#"{"rows": [[1, 0, 0]], "model": "m999"}"#), &state);
        assert_eq!(out.status, 404);
    }

    #[test]
    fn repeat_fit_is_an_exact_warm_hit_with_identical_objective() {
        let state = toy_state_with(true);
        let cold = route(&post_fit(fit_body()), &state);
        assert_eq!(cold.status, 200, "{}", cold.body);
        let cold_doc = Json::parse(&cold.body).unwrap();
        let warm = route(&post_fit(fit_body()), &state);
        assert_eq!(warm.status, 200, "{}", warm.body);
        let warm_doc = Json::parse(&warm.body).unwrap();
        assert_eq!(
            warm_doc.get("warm").unwrap().get("hit").and_then(Json::as_str),
            Some("exact")
        );
        // Bit-identical objective: the cached solution is served as-is.
        let cold_obj = cold_doc.get("objective").unwrap().as_f64_tagged().unwrap();
        let warm_obj = warm_doc.get("objective").unwrap().as_f64_tagged().unwrap();
        assert_eq!(cold_obj.to_bits(), warm_obj.to_bits());
        // Both fits got distinct registry ids.
        assert_ne!(
            cold_doc.get("model_id").and_then(Json::as_str),
            warm_doc.get("model_id").and_then(Json::as_str)
        );
    }

    #[test]
    fn fit_route_rejects_bad_payloads_with_400() {
        let state = toy_state_with(true);
        for (body, hint) in [
            ("nope", "not JSON"),
            (r#"{"y": [1], "k": 1}"#, "`x`"),
            (r#"{"x": [[1, 2]], "k": 1}"#, "`y`"),
            (r#"{"x": [[1, 2]], "y": [1, 2], "k": 1}"#, "rows but y"),
            (r#"{"x": [[1, 2]], "y": [1]}"#, "`k`"),
            (r#"{"x": [[1, 2]], "y": [1], "k": 3}"#, "exceeds"),
        ] {
            let out = route(&post_fit(body), &state);
            assert_eq!(out.status, 400, "{body}");
            assert!(out.body.contains(hint), "{body} → {}", out.body);
        }
        assert_eq!(state.stats.fit.failures.load(Ordering::Relaxed), 6);
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fit_queue_overflow_returns_429_with_retry_after() {
        let state = toy_state_with(true);
        // Simulate a fit already in flight; the gate must bounce us.
        state.fits_in_flight.store(1, Ordering::SeqCst);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 429, "{}", out.body);
        // The PR-6 bug: no Retry-After, bare body. Pinned fixed here.
        assert_eq!(out.retry_after_secs, Some(1));
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("retry_after_secs").and_then(Json::as_usize), Some(1));
        assert!(doc.get("error").and_then(Json::as_str).is_some());
        state.fits_in_flight.store(0, Ordering::SeqCst);
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 200, "{}", out.body);
    }

    #[test]
    fn fit_deadline_zero_returns_structured_timeout() {
        let state = toy_state_with(true);
        // deadline_ms: 0 is an already-expired budget — deterministic on
        // any machine: the solve is cancelled before the first
        // subproblem and answered as a structured timeout.
        let body = r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1]],
            "y": [2, 4, 6, 8], "k": 1, "m": 2, "warm": false, "deadline_ms": 0}"#;
        let out = route(&post_fit(body), &state);
        assert_eq!(out.status, 503, "{}", out.body);
        assert_eq!(out.retry_after_secs, Some(1));
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("timeout").and_then(Json::as_bool), Some(true));
        assert_eq!(doc.get("deadline_ms").and_then(Json::as_usize), Some(0));
        assert_eq!(doc.get("retry_after_secs").and_then(Json::as_usize), Some(1));
        assert!(doc.get("error").and_then(Json::as_str).unwrap().contains("deadline"));
        // A timed-out fit is a failed attempt; nothing entered the store
        // or the registry.
        assert_eq!(state.stats.fit.failures.load(Ordering::Relaxed), 1);
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 0);
        assert_eq!(state.warm.lock().unwrap().len(), 0);
        // The same instance without a deadline solves fine.
        let body = r#"{"x": [[1, 0, 0], [2, 1, 0], [3, 0, 1], [4, 1, 1]],
            "y": [2, 4, 6, 8], "k": 1, "m": 2, "warm": false}"#;
        let out = route(&post_fit(body), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        // Garbage deadlines are a 400, not a crash or a silent default.
        let body = r#"{"x": [[1, 0, 0]], "y": [2], "k": 1, "deadline_ms": "soon"}"#;
        let out = route(&post_fit(body), &state);
        assert_eq!(out.status, 400, "{}", out.body);
        assert!(out.body.contains("deadline_ms"), "{}", out.body);
    }

    #[test]
    fn healthz_reports_degraded_when_the_warm_store_is_corrupt() {
        let state = toy_state_with(true);
        let out = route(&req("GET", "/healthz", ""), &state);
        assert_eq!(out.status, 200);
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("degraded").and_then(Json::as_bool), Some(false));

        // A corrupt warm cache on disk: the server still starts (cold
        // fits), but /healthz flags the degradation for operators.
        let path = std::env::temp_dir()
            .join(format!("backbone_serve_degraded_{}.json", std::process::id()));
        std::fs::write(&path, "{ not json").unwrap();
        let cfg = ServeConfig::builder()
            .threads(1)
            .enable_fit(true)
            .warm_cache_path(Some(path.display().to_string()))
            .build()
            .unwrap();
        let degraded =
            ServerState::new(vec![("default".to_string(), toy_model())], cfg).unwrap();
        assert!(degraded.warm_error.is_some());
        let out = route(&req("GET", "/healthz", ""), &degraded);
        assert_eq!(out.status, 200, "degraded is not dead: {}", out.body);
        let doc = Json::parse(&out.body).unwrap();
        assert_eq!(doc.get("degraded").and_then(Json::as_bool), Some(true));
        assert!(doc.get("warm_store_error").and_then(Json::as_str).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn failed_store_write_through_never_fails_the_fit() {
        // Point the warm cache into a directory that does not exist: the
        // crash-safe writer cannot even create its temp file, so every
        // write-through fails — and every fit must still succeed.
        let cfg = ServeConfig::builder()
            .threads(1)
            .enable_fit(true)
            .warm_cache_path(Some(
                "/nonexistent-backbone-dir/warm_store.json".to_string(),
            ))
            .build()
            .unwrap();
        let state =
            ServerState::new(vec![("default".to_string(), toy_model())], cfg).unwrap();
        let out = route(&post_fit(fit_body()), &state);
        assert_eq!(out.status, 200, "{}", out.body);
        assert_eq!(state.stats.store_save_failures.load(Ordering::Relaxed), 1);
        assert_eq!(state.stats.fit.units.load(Ordering::Relaxed), 1);
        assert_eq!(
            state.stats_json().get("store_save_failures").and_then(Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn server_state_rejects_empty_and_duplicate_registrations() {
        let cfg = ServeConfig::default();
        assert_eq!(
            ServerState::new(vec![], cfg.clone()).unwrap_err(),
            ServeError::NoModels
        );
        let models = vec![
            ("a".to_string(), toy_model()),
            ("a".to_string(), toy_model()),
        ];
        assert_eq!(
            ServerState::new(models, cfg).unwrap_err(),
            ServeError::DuplicateModelName { name: "a".into() }
        );
    }

    #[test]
    fn handler_panic_is_caught_as_structured_500() {
        struct Kaboom;
        impl router::Route for Kaboom {
            fn method(&self) -> &'static str {
                "GET"
            }
            fn pattern(&self) -> &'static str {
                "/kaboom"
            }
            fn handle(
                &self,
                _r: &Request,
                _p: &router::PathParams,
                _s: &ServerState,
            ) -> Outcome {
                panic!("route exploded");
            }
        }
        let mut panicking_router = Router::new();
        panicking_router.register(Box::new(Kaboom));
        let state = toy_state();
        let (out, panicked) =
            dispatch_or_500(&panicking_router, &req("GET", "/kaboom", ""), &state);
        assert!(panicked);
        assert_eq!(out.status, 500);
        let doc = Json::parse(&out.body).unwrap();
        assert!(
            doc.get("error").and_then(Json::as_str).unwrap().contains("panicked"),
            "{}",
            out.body
        );
        assert_eq!(state.stats.panics_caught.load(Ordering::Relaxed), 1);
        assert_eq!(
            state.stats_json().get("panics_caught").and_then(Json::as_usize),
            Some(1)
        );
        // A healthy dispatch reports no panic and leaves the counter alone.
        let (out, panicked) = dispatch_or_500(
            &routes::standard_router(),
            &req("GET", "/healthz", ""),
            &state,
        );
        assert!(!panicked);
        assert_eq!(out.status, 200);
        assert_eq!(state.stats.panics_caught.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn latency_window_stays_bounded() {
        let mut w = LatencyWindow::new();
        for i in 0..(LATENCY_WINDOW as u64 + 100) {
            w.record(i);
        }
        let (count, window) = w.snapshot();
        assert_eq!(count, LATENCY_WINDOW as u64 + 100);
        assert_eq!(window.len(), LATENCY_WINDOW);
        // The ring keeps the most recent LATENCY_WINDOW samples: the 100
        // oldest (0..100) were overwritten.
        assert_eq!(window.iter().copied().fold(f64::INFINITY, f64::min), 100.0);
        assert_eq!(
            window.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            (LATENCY_WINDOW + 99) as f64
        );
    }
}
